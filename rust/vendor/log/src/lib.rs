//! Minimal vendored `log` facade: the `Level`/`LevelFilter`/`Record`/`Log`
//! types and the `error!`..`trace!` macros, enough for this workspace's
//! stderr logger backend. API mirrors the real crate for the used subset.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log verbosity levels, ordered `Error < Warn < Info < Debug < Trace`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.pad(s)
    }
}

/// Maximum-level filter, `Off` disabling everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a log invocation.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log invocation: metadata + preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }
    fn log(&self, _: &Record) {}
    fn flush(&self) {}
}

static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

/// Install the global logger. Fails if one is already set.
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// The installed logger (a no-op logger if none was set).
pub fn logger() -> &'static dyn Log {
    static NOP: NopLogger = NopLogger;
    match LOGGER.get() {
        Some(l) => l.as_ref(),
        None => &NOP,
    }
}

/// Macro plumbing — not public API.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize <= MAX_LEVEL.load(Ordering::Relaxed) {
        let record = Record { metadata: Metadata { level, target }, args };
        logger().log(&record);
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info <= Level::Info);
    }

    #[test]
    fn display_pads() {
        assert_eq!(format!("[{:5}]", Level::Warn), "[WARN ]");
        assert_eq!(format!("{}", Level::Error), "ERROR");
    }

    #[test]
    fn nop_logger_by_default_is_silent() {
        // must not panic even with no logger installed
        super::__log(Level::Error, "t", format_args!("dropped"));
    }
}
