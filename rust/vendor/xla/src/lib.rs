//! API stub for the `xla` (PJRT) bindings used by the `pjrt` feature.
//!
//! This crate type-checks the PJRT-backed model runtime without linking
//! the native XLA toolchain: `PjRtClient::cpu()` fails gracefully, and the
//! handle types are uninhabited so every downstream method is dead code.
//! To actually execute HLO artifacts, replace this path dependency with a
//! real xla-rs checkout exposing the same surface.

/// Uninhabited marker: values of types embedding it cannot exist.
enum Never {}

/// Error type matching the real bindings' usage (`{e:?}` formatting).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (never constructible in the stub).
pub struct PjRtClient {
    _n: Never,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(
            "xla stub: native PJRT/XLA toolchain not linked (vendor a real xla crate)".into(),
        ))
    }

    pub fn platform_name(&self) -> String {
        match self._n {}
    }

    pub fn device_count(&self) -> usize {
        match self._n {}
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self._n {}
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _n: Never,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error(format!("xla stub: cannot parse '{path}' without the native toolchain")))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _n: Never,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto._n {}
    }
}

/// Compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _n: Never,
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self._n {}
    }
}

/// Device buffer handle (never constructible in the stub).
pub struct PjRtBuffer {
    _n: Never,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self._n {}
    }
}

/// Host literal. Constructible (inputs are staged before execution), but
/// every consuming operation fails in the stub.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error("xla stub: reshape unavailable".into()))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error("xla stub: to_tuple unavailable".into()))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error("xla stub: to_vec unavailable".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_gracefully() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("stub"));
    }

    #[test]
    fn hlo_parse_fails_gracefully() {
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }
}
