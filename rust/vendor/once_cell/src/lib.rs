//! Minimal vendored `once_cell` compatible with the subset this workspace
//! uses (`once_cell::sync::Lazy` in statics). Backed by `std::sync::OnceLock`.

pub mod sync {
    use std::cell::Cell;
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access, usable in `static` items.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: Cell<Option<F>>,
    }

    // SAFETY: `init` is only ever taken inside `OnceLock::get_or_init`,
    // which guarantees the closure runs at most once across all threads,
    // so the `Cell` is never accessed concurrently. This mirrors the
    // upstream once_cell / std `LazyLock` impls.
    unsafe impl<T, F: Send> Sync for Lazy<T, F> where OnceLock<T>: Sync {}

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Lazy<T, F> {
            Lazy { cell: OnceLock::new(), init: Cell::new(Some(init)) }
        }
    }

    impl<T, F: FnOnce() -> T> Lazy<T, F> {
        pub fn force(this: &Lazy<T, F>) -> &T {
            this.cell.get_or_init(|| match this.init.take() {
                Some(f) => f(),
                None => panic!("Lazy instance has previously been poisoned"),
            })
        }
    }

    impl<T, F: FnOnce() -> T> Deref for Lazy<T, F> {
        type Target = T;
        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }

    /// A cell which can be written to only once.
    pub struct OnceCell<T>(OnceLock<T>);

    impl<T> OnceCell<T> {
        pub const fn new() -> OnceCell<T> {
            OnceCell(OnceLock::new())
        }

        pub fn get(&self) -> Option<&T> {
            self.0.get()
        }

        pub fn set(&self, value: T) -> Result<(), T> {
            self.0.set(value)
        }

        pub fn get_or_init<F: FnOnce() -> T>(&self, f: F) -> &T {
            self.0.get_or_init(f)
        }
    }

    impl<T> Default for OnceCell<T> {
        fn default() -> Self {
            OnceCell::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;
    use std::sync::atomic::{AtomicU32, Ordering};

    static INITS: AtomicU32 = AtomicU32::new(0);
    static VALUE: Lazy<u32> = Lazy::new(|| {
        INITS.fetch_add(1, Ordering::SeqCst);
        42
    });

    #[test]
    fn lazy_initializes_once_across_threads() {
        let handles: Vec<_> = (0..8)
            .map(|_| std::thread::spawn(|| assert_eq!(*VALUE, 42)))
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(INITS.load(Ordering::SeqCst), 1);
    }
}
