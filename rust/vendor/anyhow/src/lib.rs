//! Minimal vendored `anyhow`: a string-backed error type, the `anyhow!`
//! macro, and a `Result` alias — the subset the examples use.

use std::fmt;

/// A type-erased error carrying a rendered message.
pub struct Error(String);

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(message.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// Like the real anyhow, `Error` deliberately does not implement
// `std::error::Error`, which is what makes this blanket From possible.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => { $crate::Error::msg(format!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macro_formats() {
        let e = anyhow!("failed: {}", 42);
        assert_eq!(e.to_string(), "failed: 42");
        assert_eq!(format!("{e:?}"), "failed: 42");
    }

    #[test]
    fn from_std_error() {
        fn io_fail() -> super::Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "nope"))?;
            Ok(())
        }
        assert!(io_fail().unwrap_err().to_string().contains("nope"));
    }
}
