//! "colbin" — a compact binary columnar format (the repo's Parquet
//! stand-in): per-column encoding with null bitmaps, deflate-compressed,
//! with a self-describing schema header and CRC-checked payload.
//!
//! Layout:
//! ```text
//! magic "DDPC" | version u8 | ncols u16 | nrows u64
//! per column: name (u16 len + utf8) | type tag u8
//! compressed block: per column -> null bitmap | packed values
//! trailing crc32 of the compressed block
//! ```
//!
//! `Any`-typed columns are self-describing: each present value carries a
//! one-byte type tag before its payload (format v2). v1 wrote `Any`
//! values untagged and decoded them as strings — silently corrupting any
//! non-string value; v1 blobs are still readable with that legacy
//! behaviour. The engine's disk-spill path (`engine::spill`) relies on
//! tagged `Any` columns for exact row round-trips.

use crate::engine::row::{Column, ColumnBatch, ColumnData, Field, FieldType, Row, Schema, SchemaRef};
use crate::util::error::{DdpError, Result};
use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"DDPC";
const VERSION: u8 = 2;

fn type_tag(t: FieldType) -> u8 {
    match t {
        FieldType::Any => 0,
        FieldType::Bool => 1,
        FieldType::I64 => 2,
        FieldType::F64 => 3,
        FieldType::Str => 4,
        FieldType::Bytes => 5,
    }
}

/// Concrete type of a value (the one numbering source for per-value
/// tags is [`type_tag`]/[`tag_type`]; `Null` maps to `Any` but never
/// appears in a payload — the bitmap already encodes it).
fn value_type(f: &Field) -> FieldType {
    match f {
        Field::Null => FieldType::Any,
        Field::Bool(_) => FieldType::Bool,
        Field::I64(_) => FieldType::I64,
        Field::F64(_) => FieldType::F64,
        Field::Str(_) => FieldType::Str,
        Field::Bytes(_) => FieldType::Bytes,
    }
}

/// Per-value tag for `Any`-typed columns.
fn field_tag(f: &Field) -> u8 {
    type_tag(value_type(f))
}

fn tag_type(tag: u8) -> Result<FieldType> {
    Ok(match tag {
        0 => FieldType::Any,
        1 => FieldType::Bool,
        2 => FieldType::I64,
        3 => FieldType::F64,
        4 => FieldType::Str,
        5 => FieldType::Bytes,
        t => return Err(DdpError::format("colbin", format!("bad type tag {t}"))),
    })
}

fn header(schema: &Schema, nrows: usize) -> Vec<u8> {
    let mut head = Vec::new();
    head.extend_from_slice(MAGIC);
    head.push(VERSION);
    head.extend_from_slice(&(schema.len() as u16).to_le_bytes());
    head.extend_from_slice(&(nrows as u64).to_le_bytes());
    for i in 0..schema.len() {
        let (name, ty) = schema.field(i);
        head.extend_from_slice(&(name.len() as u16).to_le_bytes());
        head.extend_from_slice(name.as_bytes());
        head.push(type_tag(ty));
    }
    head
}

/// Append one present value's payload bytes (no tag, no bitmap).
fn write_field(payload: &mut Vec<u8>, f: &Field) {
    match f {
        Field::Null => {}
        Field::Bool(b) => payload.push(*b as u8),
        Field::I64(v) => payload.extend_from_slice(&v.to_le_bytes()),
        Field::F64(v) => payload.extend_from_slice(&v.to_le_bytes()),
        Field::Str(s) => {
            payload.extend_from_slice(&(s.len() as u32).to_le_bytes());
            payload.extend_from_slice(s.as_bytes());
        }
        Field::Bytes(b) => {
            payload.extend_from_slice(&(b.len() as u32).to_le_bytes());
            payload.extend_from_slice(b);
        }
    }
}

/// Compress the payload and wrap it with the header + length + crc frame.
fn frame(head: Vec<u8>, payload: &[u8]) -> Result<Vec<u8>> {
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(payload)?;
    let compressed = enc
        .finish()
        .map_err(|e| DdpError::format("colbin", format!("compress: {e}")))?;

    let mut out = head;
    out.extend_from_slice(&(compressed.len() as u64).to_le_bytes());
    let crc = crc32(&compressed);
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(&compressed);
    Ok(out)
}

/// Encode rows column-major and compress.
pub fn encode(schema: &Schema, rows: &[Row]) -> Result<Vec<u8>> {
    let head = header(schema, rows.len());

    // column-major payload
    let mut payload = Vec::new();
    for col in 0..schema.len() {
        // null bitmap
        let mut bitmap = vec![0u8; rows.len().div_ceil(8)];
        for (r, row) in rows.iter().enumerate() {
            if !row.get(col).is_null() {
                bitmap[r / 8] |= 1 << (r % 8);
            }
        }
        payload.extend_from_slice(&bitmap);
        let tagged = schema.field(col).1 == FieldType::Any;
        for row in rows {
            let f = row.get(col);
            if tagged && !f.is_null() {
                payload.push(field_tag(f));
            }
            write_field(&mut payload, f);
        }
    }

    frame(head, &payload)
}

/// Encode a [`ColumnBatch`] column-major — byte-for-byte identical to
/// [`encode`] over the batch's rows, without ever materializing them.
/// The engine's spill path relies on this equivalence: a shuffle bucket
/// spilled from batch-native state produces exactly the file a
/// row-transported run would, so on-disk bytes (and spill accounting)
/// cannot diverge between the two execution modes.
pub fn encode_columns(schema: &Schema, batch: &ColumnBatch) -> Result<Vec<u8>> {
    if batch.num_cols() != schema.len() {
        return Err(DdpError::format(
            "colbin",
            format!("batch has {} cols, schema has {}", batch.num_cols(), schema.len()),
        ));
    }
    let nrows = batch.len();
    let head = header(schema, nrows);

    let mut payload = Vec::new();
    for (ci, col) in batch.cols.iter().enumerate() {
        let mut bitmap = vec![0u8; nrows.div_ceil(8)];
        for r in 0..nrows {
            if !col.is_null(r) {
                bitmap[r / 8] |= 1 << (r % 8);
            }
        }
        payload.extend_from_slice(&bitmap);
        let tagged = schema.field(ci).1 == FieldType::Any;
        // write straight from typed storage; null slots contribute no
        // payload bytes (the placeholder value is never written out)
        macro_rules! typed {
            ($v:expr, $ty:expr, $write:expr) => {
                for (r, x) in $v.iter().enumerate() {
                    if col.is_null(r) {
                        continue;
                    }
                    if tagged {
                        payload.push(type_tag($ty));
                    }
                    #[allow(clippy::redundant_closure_call)]
                    ($write)(&mut payload, x);
                }
            };
        }
        match &col.data {
            ColumnData::Bool(v) => {
                typed!(v, FieldType::Bool, |p: &mut Vec<u8>, x: &bool| p.push(*x as u8))
            }
            ColumnData::I64(v) => {
                typed!(v, FieldType::I64, |p: &mut Vec<u8>, x: &i64| p
                    .extend_from_slice(&x.to_le_bytes()))
            }
            ColumnData::F64(v) => {
                typed!(v, FieldType::F64, |p: &mut Vec<u8>, x: &f64| p
                    .extend_from_slice(&x.to_le_bytes()))
            }
            ColumnData::Str(v) => {
                typed!(v, FieldType::Str, |p: &mut Vec<u8>, x: &String| {
                    p.extend_from_slice(&(x.len() as u32).to_le_bytes());
                    p.extend_from_slice(x.as_bytes());
                })
            }
            ColumnData::Bytes(v) => {
                typed!(v, FieldType::Bytes, |p: &mut Vec<u8>, x: &Vec<u8>| {
                    p.extend_from_slice(&(x.len() as u32).to_le_bytes());
                    p.extend_from_slice(x);
                })
            }
            ColumnData::Any(v) => {
                for f in v {
                    if f.is_null() {
                        continue;
                    }
                    if tagged {
                        payload.push(field_tag(f));
                    }
                    write_field(&mut payload, f);
                }
            }
        }
    }

    frame(head, &payload)
}

/// Decode a colbin blob into rows (a transpose over [`decode_columns`]).
/// The declared schema must match the embedded one.
pub fn decode(schema: &SchemaRef, bytes: &[u8]) -> Result<Vec<Row>> {
    Ok(decode_columns(schema, bytes)?.into_rows())
}

/// Decode a colbin blob straight into a [`ColumnBatch`] — the natural
/// direction for this column-major format. Typed columns land in dense
/// typed vectors (placeholder values at null slots, validity mask
/// alongside) without materializing intermediate rows; `Any` columns
/// decode per-value and densify to typed storage when the stored values
/// turn out homogeneous.
pub fn decode_columns(schema: &SchemaRef, bytes: &[u8]) -> Result<ColumnBatch> {
    let mut cur = Cursor { b: bytes, p: 0 };
    if cur.take(4)? != MAGIC {
        return Err(DdpError::format("colbin", "bad magic"));
    }
    let version = cur.u8()?;
    if version == 0 || version > VERSION {
        return Err(DdpError::format("colbin", "unsupported version"));
    }
    let ncols = cur.u16()? as usize;
    let nrows = cur.u64()? as usize;
    if ncols != schema.len() {
        return Err(DdpError::format(
            "colbin",
            format!("file has {ncols} cols, schema has {}", schema.len()),
        ));
    }
    let mut types = Vec::with_capacity(ncols);
    for i in 0..ncols {
        let nlen = cur.u16()? as usize;
        let name = std::str::from_utf8(cur.take(nlen)?)
            .map_err(|_| DdpError::format("colbin", "bad column name"))?;
        let (want_name, want_ty) = schema.field(i);
        if name != want_name {
            return Err(DdpError::format(
                "colbin",
                format!("column {i} named '{name}', schema says '{want_name}'"),
            ));
        }
        let ty = tag_type(cur.u8()?)?;
        if ty != want_ty {
            return Err(DdpError::format(
                "colbin",
                format!("column '{name}' type {} != schema {}", ty.name(), want_ty.name()),
            ));
        }
        types.push(ty);
    }
    let clen = cur.u64()? as usize;
    let crc_expect = cur.u32()?;
    let compressed = cur.take(clen)?;
    if crc32(compressed) != crc_expect {
        return Err(DdpError::format("colbin", "crc mismatch (corrupt payload)"));
    }
    let mut payload = Vec::new();
    ZlibDecoder::new(compressed)
        .read_to_end(&mut payload)
        .map_err(|e| DdpError::format("colbin", format!("decompress: {e}")))?;

    let mut cur = Cursor { b: &payload, p: 0 };
    let mut cols: Vec<Column> = Vec::with_capacity(ncols);
    for &ty in &types {
        let bitmap = cur.take(nrows.div_ceil(8))?;
        let null_at: Vec<bool> =
            (0..nrows).map(|r| bitmap[r / 8] & (1 << (r % 8)) == 0).collect();
        let mask = null_at.contains(&true).then(|| null_at.clone());
        // typed columns are normalized below so an all-null column decodes
        // to the same canonical representation `Column::from_fields` (and
        // `filter`/`take`) produce — spill round-trips must not drift
        cols.push(match ty {
            FieldType::Any => {
                // self-describing values (v2) or v1 legacy strings;
                // nullness lives in the `Field`s, never in a mask
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] {
                        Field::Null
                    } else if version >= 2 {
                        let vt = tag_type(cur.u8()?)?;
                        read_value(&mut cur, vt)?
                    } else {
                        Field::Str(read_str(&mut cur)?)
                    });
                }
                Column::from_fields(v)
            }
            FieldType::Bool => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] { false } else { cur.u8()? != 0 });
                }
                Column { data: ColumnData::Bool(v), nulls: mask }.normalize()
            }
            FieldType::I64 => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] { 0 } else { i64::from_le_bytes(cur.arr8()?) });
                }
                Column { data: ColumnData::I64(v), nulls: mask }.normalize()
            }
            FieldType::F64 => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] { 0.0 } else { f64::from_le_bytes(cur.arr8()?) });
                }
                Column { data: ColumnData::F64(v), nulls: mask }.normalize()
            }
            FieldType::Str => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] { String::new() } else { read_str(&mut cur)? });
                }
                Column { data: ColumnData::Str(v), nulls: mask }.normalize()
            }
            FieldType::Bytes => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] {
                        Vec::new()
                    } else {
                        let len = cur.u32()? as usize;
                        cur.take(len)?.to_vec()
                    });
                }
                Column { data: ColumnData::Bytes(v), nulls: mask }.normalize()
            }
        });
    }
    Ok(ColumnBatch::new(cols, nrows))
}

fn read_str(cur: &mut Cursor<'_>) -> Result<String> {
    let len = cur.u32()? as usize;
    Ok(std::str::from_utf8(cur.take(len)?)
        .map_err(|_| DdpError::format("colbin", "bad utf8"))?
        .to_string())
}

/// Read one present value of a concrete type — shared by the typed
/// column path and the tagged `Any` path, so the encode/decode type
/// tables can't drift apart.
fn read_value(cur: &mut Cursor<'_>, ty: FieldType) -> Result<Field> {
    Ok(match ty {
        FieldType::Bool => Field::Bool(cur.u8()? != 0),
        FieldType::I64 => Field::I64(i64::from_le_bytes(cur.arr8()?)),
        FieldType::F64 => Field::F64(f64::from_le_bytes(cur.arr8()?)),
        FieldType::Str => Field::Str(read_str(cur)?),
        FieldType::Bytes => {
            let len = cur.u32()? as usize;
            Field::Bytes(cur.take(len)?.to_vec())
        }
        // tag 0 inside a payload would mean "a value of type Any" —
        // nothing ever writes that
        FieldType::Any => return Err(DdpError::format("colbin", "bad value tag 0")),
    })
}

struct Cursor<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.p + n > self.b.len() {
            return Err(DdpError::format("colbin", "truncated"));
        }
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn arr8(&mut self) -> Result<[u8; 8]> {
        Ok(self.take(8)?.try_into().unwrap())
    }
}

/// CRC-32 (IEEE), table-less bitwise variant; payload sizes here are small
/// enough that simplicity beats a lookup table.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;
    use crate::util::testkit::property;

    fn schema() -> SchemaRef {
        Schema::new(vec![
            ("id", FieldType::I64),
            ("text", FieldType::Str),
            ("score", FieldType::F64),
            ("ok", FieldType::Bool),
            ("blob", FieldType::Bytes),
        ])
    }

    #[test]
    fn roundtrip_with_nulls() {
        let s = schema();
        let rows = vec![
            Row::new(vec![
                Field::I64(1),
                Field::Str("héllo".into()),
                Field::F64(0.25),
                Field::Bool(true),
                Field::Bytes(vec![1, 2, 3]),
            ]),
            Row::new(vec![
                Field::Null,
                Field::Null,
                Field::Null,
                Field::Null,
                Field::Null,
            ]),
        ];
        let blob = encode(&s, &rows).unwrap();
        assert_eq!(decode(&s, &blob).unwrap(), rows);
    }

    #[test]
    fn corrupt_payload_detected() {
        let s = schema();
        let rows = vec![row!(1i64, "x", 1.0, true, Field::Bytes(vec![9]))];
        let mut blob = encode(&s, &rows).unwrap();
        let n = blob.len();
        blob[n - 1] ^= 0xFF;
        let err = decode(&s, &blob).unwrap_err().to_string();
        assert!(err.contains("crc") || err.contains("decompress"), "{err}");
    }

    #[test]
    fn schema_mismatch_detected() {
        let s = schema();
        let rows = vec![row!(1i64, "x", 1.0, true, Field::Bytes(vec![]))];
        let blob = encode(&s, &rows).unwrap();
        let other = Schema::new(vec![("id", FieldType::I64)]);
        assert!(decode(&other, &blob).is_err());
        let renamed = Schema::new(vec![
            ("idx", FieldType::I64),
            ("text", FieldType::Str),
            ("score", FieldType::F64),
            ("ok", FieldType::Bool),
            ("blob", FieldType::Bytes),
        ]);
        assert!(decode(&renamed, &blob).is_err());
    }

    #[test]
    fn any_column_roundtrips_mixed_types() {
        // the spill path serializes shuffle buckets under all-Any schemas,
        // so every variant must round-trip exactly through an Any column
        let s = Schema::new(vec![("a", FieldType::Any), ("b", FieldType::Any)]);
        let rows = vec![
            Row::new(vec![Field::I64(-7), Field::Str("x".into())]),
            Row::new(vec![Field::F64(0.125), Field::Bool(true)]),
            Row::new(vec![Field::Bytes(vec![0, 255, 3]), Field::Null]),
            Row::new(vec![Field::Str(String::new()), Field::I64(i64::MIN)]),
        ];
        let blob = encode(&s, &rows).unwrap();
        assert_eq!(decode(&s, &blob).unwrap(), rows);
    }

    #[test]
    fn decode_columns_typed_layout() {
        let s = schema();
        let rows = vec![
            row!(1i64, "a", 0.5, true, Field::Bytes(vec![1])),
            Row::new(vec![Field::Null, Field::Null, Field::Null, Field::Null, Field::Null]),
            row!(3i64, "c", 1.5, false, Field::Bytes(vec![])),
        ];
        let blob = encode(&s, &rows).unwrap();
        let batch = decode_columns(&s, &blob).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(matches!(batch.cols[0].data, ColumnData::I64(_)));
        assert!(matches!(batch.cols[1].data, ColumnData::Str(_)));
        assert!(matches!(batch.cols[2].data, ColumnData::F64(_)));
        assert!(matches!(batch.cols[3].data, ColumnData::Bool(_)));
        assert!(matches!(batch.cols[4].data, ColumnData::Bytes(_)));
        assert!(batch.cols.iter().all(|c| c.is_null(1)), "row 1 is all null");
        assert_eq!(batch.into_rows(), rows);
    }

    #[test]
    fn decode_columns_densifies_homogeneous_any() {
        let s = Schema::new(vec![("a", FieldType::Any)]);
        let rows = vec![row!(1i64), Row::new(vec![Field::Null]), row!(2i64)];
        let blob = encode(&s, &rows).unwrap();
        let batch = decode_columns(&s, &blob).unwrap();
        assert!(
            matches!(batch.cols[0].data, ColumnData::I64(_)),
            "homogeneous Any column densifies to typed storage"
        );
        assert!(batch.cols[0].is_null(1));
        assert_eq!(batch.into_rows(), rows);
    }

    #[test]
    fn decode_columns_empty_blob() {
        let s = schema();
        let blob = encode(&s, &[]).unwrap();
        let batch = decode_columns(&s, &blob).unwrap();
        assert_eq!(batch.len(), 0);
        assert_eq!(batch.num_cols(), 5);
        assert!(batch.into_rows().is_empty());
    }

    #[test]
    fn encode_columns_bytes_identical_to_row_encode() {
        // the batch-native spill path writes with encode_columns; files
        // must be byte-for-byte what the row path would have written
        let any2 = Schema::new(vec![("c0", FieldType::Any), ("c1", FieldType::Any)]);
        let neg_nan = f64::from_bits(f64::NAN.to_bits() | (1u64 << 63));
        let cases: Vec<(SchemaRef, Vec<Row>)> = vec![
            // typed columns with placeholder/real collisions and nulls
            (
                any2.clone(),
                vec![
                    Row::new(vec![Field::I64(0), Field::Str(String::new())]),
                    Row::new(vec![Field::Null, Field::Null]),
                    Row::new(vec![Field::I64(7), Field::Str("x".into())]),
                ],
            ),
            // NaN payloads must keep their exact bit patterns
            (
                any2.clone(),
                vec![
                    Row::new(vec![Field::F64(f64::NAN), Field::F64(-0.0)]),
                    Row::new(vec![Field::F64(neg_nan), Field::Null]),
                ],
            ),
            // genuinely mixed column (Any storage) + all-null column
            (
                any2.clone(),
                vec![
                    Row::new(vec![Field::I64(1), Field::Null]),
                    Row::new(vec![Field::Str("s".into()), Field::Null]),
                    Row::new(vec![Field::Bytes(vec![0, 1]), Field::Null]),
                ],
            ),
            // empty batch
            (any2.clone(), vec![]),
            // typed (non-Any) schema: values are written untagged
            (
                Schema::new(vec![("id", FieldType::I64), ("t", FieldType::Str)]),
                vec![row!(1i64, "a"), Row::new(vec![Field::Null, Field::Null])],
            ),
        ];
        for (schema, rows) in cases {
            let from_rows = encode(&schema, &rows).unwrap();
            // build column-wise so mixed (Any-storage) columns are covered
            let cols: Vec<Column> = (0..schema.len())
                .map(|c| Column::from_fields(rows.iter().map(|r| r.fields[c].clone()).collect()))
                .collect();
            let batch = ColumnBatch::new(cols, rows.len());
            let from_batch = encode_columns(&schema, &batch).unwrap();
            assert_eq!(from_rows, from_batch, "encode paths diverged for {rows:?}");
        }
    }

    #[test]
    fn decode_columns_normalizes_all_null_typed_column() {
        // an I64-typed column that is entirely null must decode to the
        // same canonical representation from_fields produces (Any of
        // Nulls, no mask) — not a typed vector with an all-true mask
        let s = Schema::new(vec![("id", FieldType::I64)]);
        let rows = vec![Row::new(vec![Field::Null]), Row::new(vec![Field::Null])];
        let blob = encode(&s, &rows).unwrap();
        let batch = decode_columns(&s, &blob).unwrap();
        assert_eq!(batch.cols[0], Column::from_fields(vec![Field::Null, Field::Null]));
        assert!(matches!(batch.cols[0].data, ColumnData::Any(_)));
        assert!(batch.cols[0].nulls.is_none());
        assert_eq!(batch.into_rows(), rows);
    }

    #[test]
    fn crc32_known_value() {
        // standard test vector
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn compresses_repetitive_data() {
        let s = Schema::new(vec![("t", FieldType::Str)]);
        let rows: Vec<Row> = (0..1000).map(|_| row!("the same line of text")).collect();
        let blob = encode(&s, &rows).unwrap();
        let raw: usize = rows.iter().map(|r| r.approx_size()).sum();
        assert!(blob.len() < raw / 5, "blob {} vs raw {}", blob.len(), raw);
    }

    #[test]
    fn prop_roundtrip() {
        let s = Schema::new(vec![("a", FieldType::I64), ("b", FieldType::Str)]);
        property(60, |g| {
            let rows: Vec<Row> = (0..g.usize(20))
                .map(|_| {
                    if g.bool() {
                        Row::new(vec![Field::Null, Field::Str(g.string(0, 30))])
                    } else {
                        row!(g.i64(-1000, 1000), g.string(0, 30))
                    }
                })
                .collect();
            let blob = encode(&s, &rows).unwrap();
            assert_eq!(decode(&s, &blob).unwrap(), rows);
        });
    }
}
