//! "colbin" — a compact binary columnar format (the repo's Parquet
//! stand-in): per-column encoding with null bitmaps, deflate-compressed,
//! with a self-describing schema header and CRC-checked payload.
//!
//! Layout:
//! ```text
//! magic "DDPC" | version u8 | ncols u16 | nrows u64
//! per column: name (u16 len + utf8) | type tag u8
//! compressed block: per column -> null bitmap | packed values
//! trailing crc32 of the compressed block
//! ```
//!
//! `Any`-typed columns are self-describing: each present value carries a
//! one-byte type tag before its payload (format v2). v1 wrote `Any`
//! values untagged and decoded them as strings — silently corrupting any
//! non-string value; v1 blobs are still readable with that legacy
//! behaviour. The engine's disk-spill path (`engine::spill`) relies on
//! tagged `Any` columns for exact row round-trips.

use crate::engine::row::{Column, ColumnBatch, ColumnData, Field, FieldType, Row, Schema, SchemaRef};
use crate::util::error::{DdpError, Result};
use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"DDPC";
const VERSION: u8 = 2;

fn type_tag(t: FieldType) -> u8 {
    match t {
        FieldType::Any => 0,
        FieldType::Bool => 1,
        FieldType::I64 => 2,
        FieldType::F64 => 3,
        FieldType::Str => 4,
        FieldType::Bytes => 5,
    }
}

/// Concrete type of a value (the one numbering source for per-value
/// tags is [`type_tag`]/[`tag_type`]; `Null` maps to `Any` but never
/// appears in a payload — the bitmap already encodes it).
fn value_type(f: &Field) -> FieldType {
    match f {
        Field::Null => FieldType::Any,
        Field::Bool(_) => FieldType::Bool,
        Field::I64(_) => FieldType::I64,
        Field::F64(_) => FieldType::F64,
        Field::Str(_) => FieldType::Str,
        Field::Bytes(_) => FieldType::Bytes,
    }
}

/// Per-value tag for `Any`-typed columns.
fn field_tag(f: &Field) -> u8 {
    type_tag(value_type(f))
}

fn tag_type(tag: u8) -> Result<FieldType> {
    Ok(match tag {
        0 => FieldType::Any,
        1 => FieldType::Bool,
        2 => FieldType::I64,
        3 => FieldType::F64,
        4 => FieldType::Str,
        5 => FieldType::Bytes,
        t => return Err(DdpError::format("colbin", format!("bad type tag {t}"))),
    })
}

/// Encode rows column-major and compress.
pub fn encode(schema: &Schema, rows: &[Row]) -> Result<Vec<u8>> {
    let mut head = Vec::new();
    head.extend_from_slice(MAGIC);
    head.push(VERSION);
    head.extend_from_slice(&(schema.len() as u16).to_le_bytes());
    head.extend_from_slice(&(rows.len() as u64).to_le_bytes());
    for i in 0..schema.len() {
        let (name, ty) = schema.field(i);
        head.extend_from_slice(&(name.len() as u16).to_le_bytes());
        head.extend_from_slice(name.as_bytes());
        head.push(type_tag(ty));
    }

    // column-major payload
    let mut payload = Vec::new();
    for col in 0..schema.len() {
        // null bitmap
        let mut bitmap = vec![0u8; rows.len().div_ceil(8)];
        for (r, row) in rows.iter().enumerate() {
            if !row.get(col).is_null() {
                bitmap[r / 8] |= 1 << (r % 8);
            }
        }
        payload.extend_from_slice(&bitmap);
        let tagged = schema.field(col).1 == FieldType::Any;
        for row in rows {
            let f = row.get(col);
            if tagged && !f.is_null() {
                payload.push(field_tag(f));
            }
            match f {
                Field::Null => {}
                Field::Bool(b) => payload.push(*b as u8),
                Field::I64(v) => payload.extend_from_slice(&v.to_le_bytes()),
                Field::F64(v) => payload.extend_from_slice(&v.to_le_bytes()),
                Field::Str(s) => {
                    payload.extend_from_slice(&(s.len() as u32).to_le_bytes());
                    payload.extend_from_slice(s.as_bytes());
                }
                Field::Bytes(b) => {
                    payload.extend_from_slice(&(b.len() as u32).to_le_bytes());
                    payload.extend_from_slice(b);
                }
            }
        }
    }

    let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(&payload)?;
    let compressed = enc
        .finish()
        .map_err(|e| DdpError::format("colbin", format!("compress: {e}")))?;

    let mut out = head;
    out.extend_from_slice(&(compressed.len() as u64).to_le_bytes());
    let crc = crc32(&compressed);
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(&compressed);
    Ok(out)
}

/// Decode a colbin blob into rows (a transpose over [`decode_columns`]).
/// The declared schema must match the embedded one.
pub fn decode(schema: &SchemaRef, bytes: &[u8]) -> Result<Vec<Row>> {
    Ok(decode_columns(schema, bytes)?.into_rows())
}

/// Decode a colbin blob straight into a [`ColumnBatch`] — the natural
/// direction for this column-major format. Typed columns land in dense
/// typed vectors (placeholder values at null slots, validity mask
/// alongside) without materializing intermediate rows; `Any` columns
/// decode per-value and densify to typed storage when the stored values
/// turn out homogeneous.
pub fn decode_columns(schema: &SchemaRef, bytes: &[u8]) -> Result<ColumnBatch> {
    let mut cur = Cursor { b: bytes, p: 0 };
    if cur.take(4)? != MAGIC {
        return Err(DdpError::format("colbin", "bad magic"));
    }
    let version = cur.u8()?;
    if version == 0 || version > VERSION {
        return Err(DdpError::format("colbin", "unsupported version"));
    }
    let ncols = cur.u16()? as usize;
    let nrows = cur.u64()? as usize;
    if ncols != schema.len() {
        return Err(DdpError::format(
            "colbin",
            format!("file has {ncols} cols, schema has {}", schema.len()),
        ));
    }
    let mut types = Vec::with_capacity(ncols);
    for i in 0..ncols {
        let nlen = cur.u16()? as usize;
        let name = std::str::from_utf8(cur.take(nlen)?)
            .map_err(|_| DdpError::format("colbin", "bad column name"))?;
        let (want_name, want_ty) = schema.field(i);
        if name != want_name {
            return Err(DdpError::format(
                "colbin",
                format!("column {i} named '{name}', schema says '{want_name}'"),
            ));
        }
        let ty = tag_type(cur.u8()?)?;
        if ty != want_ty {
            return Err(DdpError::format(
                "colbin",
                format!("column '{name}' type {} != schema {}", ty.name(), want_ty.name()),
            ));
        }
        types.push(ty);
    }
    let clen = cur.u64()? as usize;
    let crc_expect = cur.u32()?;
    let compressed = cur.take(clen)?;
    if crc32(compressed) != crc_expect {
        return Err(DdpError::format("colbin", "crc mismatch (corrupt payload)"));
    }
    let mut payload = Vec::new();
    ZlibDecoder::new(compressed)
        .read_to_end(&mut payload)
        .map_err(|e| DdpError::format("colbin", format!("decompress: {e}")))?;

    let mut cur = Cursor { b: &payload, p: 0 };
    let mut cols: Vec<Column> = Vec::with_capacity(ncols);
    for &ty in &types {
        let bitmap = cur.take(nrows.div_ceil(8))?;
        let null_at: Vec<bool> =
            (0..nrows).map(|r| bitmap[r / 8] & (1 << (r % 8)) == 0).collect();
        let mask = null_at.contains(&true).then(|| null_at.clone());
        cols.push(match ty {
            FieldType::Any => {
                // self-describing values (v2) or v1 legacy strings;
                // nullness lives in the `Field`s, never in a mask
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] {
                        Field::Null
                    } else if version >= 2 {
                        let vt = tag_type(cur.u8()?)?;
                        read_value(&mut cur, vt)?
                    } else {
                        Field::Str(read_str(&mut cur)?)
                    });
                }
                Column::from_fields(v)
            }
            FieldType::Bool => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] { false } else { cur.u8()? != 0 });
                }
                Column { data: ColumnData::Bool(v), nulls: mask }
            }
            FieldType::I64 => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] { 0 } else { i64::from_le_bytes(cur.arr8()?) });
                }
                Column { data: ColumnData::I64(v), nulls: mask }
            }
            FieldType::F64 => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] { 0.0 } else { f64::from_le_bytes(cur.arr8()?) });
                }
                Column { data: ColumnData::F64(v), nulls: mask }
            }
            FieldType::Str => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] { String::new() } else { read_str(&mut cur)? });
                }
                Column { data: ColumnData::Str(v), nulls: mask }
            }
            FieldType::Bytes => {
                let mut v = Vec::with_capacity(nrows);
                for r in 0..nrows {
                    v.push(if null_at[r] {
                        Vec::new()
                    } else {
                        let len = cur.u32()? as usize;
                        cur.take(len)?.to_vec()
                    });
                }
                Column { data: ColumnData::Bytes(v), nulls: mask }
            }
        });
    }
    Ok(ColumnBatch::new(cols, nrows))
}

fn read_str(cur: &mut Cursor<'_>) -> Result<String> {
    let len = cur.u32()? as usize;
    Ok(std::str::from_utf8(cur.take(len)?)
        .map_err(|_| DdpError::format("colbin", "bad utf8"))?
        .to_string())
}

/// Read one present value of a concrete type — shared by the typed
/// column path and the tagged `Any` path, so the encode/decode type
/// tables can't drift apart.
fn read_value(cur: &mut Cursor<'_>, ty: FieldType) -> Result<Field> {
    Ok(match ty {
        FieldType::Bool => Field::Bool(cur.u8()? != 0),
        FieldType::I64 => Field::I64(i64::from_le_bytes(cur.arr8()?)),
        FieldType::F64 => Field::F64(f64::from_le_bytes(cur.arr8()?)),
        FieldType::Str => Field::Str(read_str(cur)?),
        FieldType::Bytes => {
            let len = cur.u32()? as usize;
            Field::Bytes(cur.take(len)?.to_vec())
        }
        // tag 0 inside a payload would mean "a value of type Any" —
        // nothing ever writes that
        FieldType::Any => return Err(DdpError::format("colbin", "bad value tag 0")),
    })
}

struct Cursor<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.p + n > self.b.len() {
            return Err(DdpError::format("colbin", "truncated"));
        }
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn arr8(&mut self) -> Result<[u8; 8]> {
        Ok(self.take(8)?.try_into().unwrap())
    }
}

/// CRC-32 (IEEE), table-less bitwise variant; payload sizes here are small
/// enough that simplicity beats a lookup table.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;
    use crate::util::testkit::property;

    fn schema() -> SchemaRef {
        Schema::new(vec![
            ("id", FieldType::I64),
            ("text", FieldType::Str),
            ("score", FieldType::F64),
            ("ok", FieldType::Bool),
            ("blob", FieldType::Bytes),
        ])
    }

    #[test]
    fn roundtrip_with_nulls() {
        let s = schema();
        let rows = vec![
            Row::new(vec![
                Field::I64(1),
                Field::Str("héllo".into()),
                Field::F64(0.25),
                Field::Bool(true),
                Field::Bytes(vec![1, 2, 3]),
            ]),
            Row::new(vec![
                Field::Null,
                Field::Null,
                Field::Null,
                Field::Null,
                Field::Null,
            ]),
        ];
        let blob = encode(&s, &rows).unwrap();
        assert_eq!(decode(&s, &blob).unwrap(), rows);
    }

    #[test]
    fn corrupt_payload_detected() {
        let s = schema();
        let rows = vec![row!(1i64, "x", 1.0, true, Field::Bytes(vec![9]))];
        let mut blob = encode(&s, &rows).unwrap();
        let n = blob.len();
        blob[n - 1] ^= 0xFF;
        let err = decode(&s, &blob).unwrap_err().to_string();
        assert!(err.contains("crc") || err.contains("decompress"), "{err}");
    }

    #[test]
    fn schema_mismatch_detected() {
        let s = schema();
        let rows = vec![row!(1i64, "x", 1.0, true, Field::Bytes(vec![]))];
        let blob = encode(&s, &rows).unwrap();
        let other = Schema::new(vec![("id", FieldType::I64)]);
        assert!(decode(&other, &blob).is_err());
        let renamed = Schema::new(vec![
            ("idx", FieldType::I64),
            ("text", FieldType::Str),
            ("score", FieldType::F64),
            ("ok", FieldType::Bool),
            ("blob", FieldType::Bytes),
        ]);
        assert!(decode(&renamed, &blob).is_err());
    }

    #[test]
    fn any_column_roundtrips_mixed_types() {
        // the spill path serializes shuffle buckets under all-Any schemas,
        // so every variant must round-trip exactly through an Any column
        let s = Schema::new(vec![("a", FieldType::Any), ("b", FieldType::Any)]);
        let rows = vec![
            Row::new(vec![Field::I64(-7), Field::Str("x".into())]),
            Row::new(vec![Field::F64(0.125), Field::Bool(true)]),
            Row::new(vec![Field::Bytes(vec![0, 255, 3]), Field::Null]),
            Row::new(vec![Field::Str(String::new()), Field::I64(i64::MIN)]),
        ];
        let blob = encode(&s, &rows).unwrap();
        assert_eq!(decode(&s, &blob).unwrap(), rows);
    }

    #[test]
    fn decode_columns_typed_layout() {
        let s = schema();
        let rows = vec![
            row!(1i64, "a", 0.5, true, Field::Bytes(vec![1])),
            Row::new(vec![Field::Null, Field::Null, Field::Null, Field::Null, Field::Null]),
            row!(3i64, "c", 1.5, false, Field::Bytes(vec![])),
        ];
        let blob = encode(&s, &rows).unwrap();
        let batch = decode_columns(&s, &blob).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(matches!(batch.cols[0].data, ColumnData::I64(_)));
        assert!(matches!(batch.cols[1].data, ColumnData::Str(_)));
        assert!(matches!(batch.cols[2].data, ColumnData::F64(_)));
        assert!(matches!(batch.cols[3].data, ColumnData::Bool(_)));
        assert!(matches!(batch.cols[4].data, ColumnData::Bytes(_)));
        assert!(batch.cols.iter().all(|c| c.is_null(1)), "row 1 is all null");
        assert_eq!(batch.into_rows(), rows);
    }

    #[test]
    fn decode_columns_densifies_homogeneous_any() {
        let s = Schema::new(vec![("a", FieldType::Any)]);
        let rows = vec![row!(1i64), Row::new(vec![Field::Null]), row!(2i64)];
        let blob = encode(&s, &rows).unwrap();
        let batch = decode_columns(&s, &blob).unwrap();
        assert!(
            matches!(batch.cols[0].data, ColumnData::I64(_)),
            "homogeneous Any column densifies to typed storage"
        );
        assert!(batch.cols[0].is_null(1));
        assert_eq!(batch.into_rows(), rows);
    }

    #[test]
    fn decode_columns_empty_blob() {
        let s = schema();
        let blob = encode(&s, &[]).unwrap();
        let batch = decode_columns(&s, &blob).unwrap();
        assert_eq!(batch.len(), 0);
        assert_eq!(batch.num_cols(), 5);
        assert!(batch.into_rows().is_empty());
    }

    #[test]
    fn crc32_known_value() {
        // standard test vector
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn compresses_repetitive_data() {
        let s = Schema::new(vec![("t", FieldType::Str)]);
        let rows: Vec<Row> = (0..1000).map(|_| row!("the same line of text")).collect();
        let blob = encode(&s, &rows).unwrap();
        let raw: usize = rows.iter().map(|r| r.approx_size()).sum();
        assert!(blob.len() < raw / 5, "blob {} vs raw {}", blob.len(), raw);
    }

    #[test]
    fn prop_roundtrip() {
        let s = Schema::new(vec![("a", FieldType::I64), ("b", FieldType::Str)]);
        property(60, |g| {
            let rows: Vec<Row> = (0..g.usize(20))
                .map(|_| {
                    if g.bool() {
                        Row::new(vec![Field::Null, Field::Str(g.string(0, 30))])
                    } else {
                        row!(g.i64(-1000, 1000), g.string(0, 30))
                    }
                })
                .collect();
            let blob = encode(&s, &rows).unwrap();
            assert_eq!(decode(&s, &blob).unwrap(), rows);
        });
    }
}
