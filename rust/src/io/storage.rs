//! Storage backends behind one interface — the paper's §3.3.1 Data I/O
//! abstraction ("distributed file systems, local storage, and NoSQL
//! databases"). Pipes never touch a backend directly; `DataDeclare`
//! locations select one declaratively (`file://`, `mem://`, `s3://`,
//! `kv://`).

use crate::util::error::{DdpError, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Byte-blob storage interface.
pub trait Storage: Send + Sync {
    fn name(&self) -> &str;
    fn read(&self, path: &str) -> Result<Vec<u8>>;
    fn write(&self, path: &str, bytes: &[u8]) -> Result<()>;
    fn exists(&self, path: &str) -> bool;
    fn delete(&self, path: &str) -> Result<()>;
    fn list(&self, prefix: &str) -> Result<Vec<String>>;
}

pub type StorageRef = Arc<dyn Storage>;

// ---------------------------------------------------------------------

/// Local filesystem rooted at a directory.
pub struct LocalFs {
    root: PathBuf,
}

impl LocalFs {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        LocalFs { root: root.into() }
    }

    fn full(&self, path: &str) -> PathBuf {
        self.root.join(path.trim_start_matches('/'))
    }
}

impl Storage for LocalFs {
    fn name(&self) -> &str {
        "localfs"
    }

    fn read(&self, path: &str) -> Result<Vec<u8>> {
        std::fs::read(self.full(path))
            .map_err(|e| DdpError::storage("localfs", format!("read {path}: {e}")))
    }

    fn write(&self, path: &str, bytes: &[u8]) -> Result<()> {
        let full = self.full(path);
        if let Some(parent) = full.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(full, bytes)
            .map_err(|e| DdpError::storage("localfs", format!("write {path}: {e}")))
    }

    fn exists(&self, path: &str) -> bool {
        self.full(path).exists()
    }

    fn delete(&self, path: &str) -> Result<()> {
        let full = self.full(path);
        if full.exists() {
            std::fs::remove_file(full)?;
        }
        Ok(())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let dir = self.full(prefix);
        let mut out = Vec::new();
        if dir.is_dir() {
            for entry in std::fs::read_dir(&dir)? {
                let entry = entry?;
                if entry.path().is_file() {
                    out.push(format!(
                        "{}/{}",
                        prefix.trim_end_matches('/'),
                        entry.file_name().to_string_lossy()
                    ));
                }
            }
        }
        out.sort();
        Ok(out)
    }
}

// ---------------------------------------------------------------------

/// In-memory store (tests and `mem://` anchors).
#[derive(Default)]
pub struct MemStore {
    blobs: Mutex<HashMap<String, Vec<u8>>>,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Storage for MemStore {
    fn name(&self) -> &str {
        "mem"
    }

    fn read(&self, path: &str) -> Result<Vec<u8>> {
        self.blobs
            .lock()
            .unwrap()
            .get(path)
            .cloned()
            .ok_or_else(|| DdpError::storage("mem", format!("not found: {path}")))
    }

    fn write(&self, path: &str, bytes: &[u8]) -> Result<()> {
        self.blobs
            .lock()
            .unwrap()
            .insert(path.to_string(), bytes.to_vec());
        Ok(())
    }

    fn exists(&self, path: &str) -> bool {
        self.blobs.lock().unwrap().contains_key(path)
    }

    fn delete(&self, path: &str) -> Result<()> {
        self.blobs.lock().unwrap().remove(path);
        Ok(())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let mut v: Vec<String> = self
            .blobs
            .lock()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        v.sort();
        Ok(v)
    }
}

// ---------------------------------------------------------------------

/// Simulated S3: an inner store plus a first-byte-latency / bandwidth cost
/// model. Costs are *accounted* (for the cluster simulator and metrics)
/// rather than slept, so wall-clock tests stay fast.
pub struct SimS3 {
    inner: StorageRef,
    /// per-request latency (S3 GET ≈ 20–60 ms first byte)
    pub request_latency_secs: f64,
    /// sustained bandwidth in bytes/sec
    pub bandwidth_bps: f64,
    accounted_nanos: AtomicU64,
    requests: AtomicU64,
}

impl SimS3 {
    pub fn new(inner: StorageRef) -> Self {
        SimS3 {
            inner,
            request_latency_secs: 0.030,
            bandwidth_bps: 100.0e6,
            accounted_nanos: AtomicU64::new(0),
            requests: AtomicU64::new(0),
        }
    }

    fn charge(&self, bytes: usize) {
        let secs = self.request_latency_secs + bytes as f64 / self.bandwidth_bps;
        self.accounted_nanos
            .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Total simulated I/O time charged so far.
    pub fn accounted_secs(&self) -> f64 {
        self.accounted_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn request_count(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }
}

impl Storage for SimS3 {
    fn name(&self) -> &str {
        "sim-s3"
    }

    fn read(&self, path: &str) -> Result<Vec<u8>> {
        let out = self.inner.read(path)?;
        self.charge(out.len());
        Ok(out)
    }

    fn write(&self, path: &str, bytes: &[u8]) -> Result<()> {
        self.charge(bytes.len());
        self.inner.write(path, bytes)
    }

    fn exists(&self, path: &str) -> bool {
        self.inner.exists(path)
    }

    fn delete(&self, path: &str) -> Result<()> {
        self.charge(0);
        self.inner.delete(path)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        self.charge(0);
        self.inner.list(prefix)
    }
}

// ---------------------------------------------------------------------

/// Simulated NoSQL KV store: record-oriented API on top of blob storage
/// (`kv://table/key`), with per-item size limits like DynamoDB.
pub struct SimKv {
    items: Mutex<HashMap<String, Vec<u8>>>,
    pub max_item_bytes: usize,
}

impl Default for SimKv {
    fn default() -> Self {
        SimKv { items: Mutex::new(HashMap::new()), max_item_bytes: 400 << 10 }
    }
}

impl SimKv {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Storage for SimKv {
    fn name(&self) -> &str {
        "sim-kv"
    }

    fn read(&self, path: &str) -> Result<Vec<u8>> {
        self.items
            .lock()
            .unwrap()
            .get(path)
            .cloned()
            .ok_or_else(|| DdpError::storage("sim-kv", format!("no item: {path}")))
    }

    fn write(&self, path: &str, bytes: &[u8]) -> Result<()> {
        if bytes.len() > self.max_item_bytes {
            return Err(DdpError::storage(
                "sim-kv",
                format!("item {path} is {} bytes > max {}", bytes.len(), self.max_item_bytes),
            ));
        }
        self.items
            .lock()
            .unwrap()
            .insert(path.to_string(), bytes.to_vec());
        Ok(())
    }

    fn exists(&self, path: &str) -> bool {
        self.items.lock().unwrap().contains_key(path)
    }

    fn delete(&self, path: &str) -> Result<()> {
        self.items.lock().unwrap().remove(path);
        Ok(())
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let mut v: Vec<String> = self
            .items
            .lock()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        v.sort();
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(s: &dyn Storage) {
        s.write("a/b.txt", b"hello").unwrap();
        assert!(s.exists("a/b.txt"));
        assert_eq!(s.read("a/b.txt").unwrap(), b"hello");
        s.write("a/c.txt", b"x").unwrap();
        let listed = s.list("a").unwrap();
        assert_eq!(listed.len(), 2);
        s.delete("a/b.txt").unwrap();
        assert!(!s.exists("a/b.txt"));
        assert!(s.read("a/b.txt").is_err());
    }

    #[test]
    fn mem_roundtrip() {
        roundtrip(&MemStore::new());
    }

    #[test]
    fn localfs_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ddp-test-{}", std::process::id()));
        roundtrip(&LocalFs::new(&dir));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn sims3_charges_costs() {
        let s3 = SimS3::new(Arc::new(MemStore::new()));
        s3.write("k", &vec![0u8; 1_000_000]).unwrap();
        let _ = s3.read("k").unwrap();
        assert_eq!(s3.request_count(), 2);
        // 2 requests * 30ms + 2MB / 100MB/s = 0.06 + 0.02
        assert!((s3.accounted_secs() - 0.08).abs() < 0.001);
    }

    #[test]
    fn simkv_item_limit() {
        let kv = SimKv::new();
        assert!(kv.write("t/k", &vec![0u8; 500 << 10]).is_err());
        kv.write("t/k", b"small").unwrap();
        assert_eq!(kv.read("t/k").unwrap(), b"small");
    }
}
