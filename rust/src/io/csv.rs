//! CSV codec (RFC 4180 quoting) with schema-directed type parsing.

use crate::engine::row::{Field, FieldType, Row, Schema, SchemaRef};
use crate::util::error::{DdpError, Result};

/// Serialize rows to CSV with a header line.
pub fn encode(schema: &Schema, rows: &[Row]) -> String {
    let mut out = String::new();
    let names = schema.names();
    for (i, n) in names.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_cell(n, &mut out);
    }
    out.push('\n');
    for row in rows {
        for (i, f) in row.fields.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            match f {
                Field::Null => {}
                Field::Bytes(b) => write_cell(&hex(b), &mut out),
                other => write_cell(&other.to_string(), &mut out),
            }
        }
        out.push('\n');
    }
    out
}

/// Parse CSV (with header) into rows; cells are typed per the schema.
/// The header must match the schema's column names in order.
pub fn decode(schema: &SchemaRef, text: &str) -> Result<Vec<Row>> {
    let mut records = parse_records(text)?;
    if records.is_empty() {
        return Ok(vec![]);
    }
    let header = records.remove(0);
    let names = schema.names();
    if header.len() != names.len() || header.iter().zip(&names).any(|(h, n)| h != n) {
        return Err(DdpError::format(
            "csv",
            format!("header {:?} does not match schema {:?}", header, names),
        ));
    }
    let mut rows = Vec::with_capacity(records.len());
    for (line_no, rec) in records.into_iter().enumerate() {
        if rec.len() != names.len() {
            return Err(DdpError::format(
                "csv",
                format!("record {} has {} cells, expected {}", line_no + 2, rec.len(), names.len()),
            ));
        }
        let fields: Result<Vec<Field>> = rec
            .into_iter()
            .enumerate()
            .map(|(i, cell)| parse_cell(&cell, schema.field_type(i)))
            .collect();
        rows.push(Row::new(fields?));
    }
    Ok(rows)
}

fn parse_cell(cell: &str, ty: FieldType) -> Result<Field> {
    if cell.is_empty() && ty != FieldType::Str {
        return Ok(Field::Null);
    }
    Ok(match ty {
        FieldType::Any | FieldType::Str => Field::Str(cell.to_string()),
        FieldType::Bool => Field::Bool(cell == "true"),
        FieldType::I64 => Field::I64(
            cell.parse()
                .map_err(|_| DdpError::format("csv", format!("bad i64: '{cell}'")))?,
        ),
        FieldType::F64 => Field::F64(
            cell.parse()
                .map_err(|_| DdpError::format("csv", format!("bad f64: '{cell}'")))?,
        ),
        FieldType::Bytes => Field::Bytes(unhex(cell)?),
    })
}

fn write_cell(s: &str, out: &mut String) {
    if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r') {
        out.push('"');
        for c in s.chars() {
            if c == '"' {
                out.push('"');
            }
            out.push(c);
        }
        out.push('"');
    } else {
        out.push_str(s);
    }
}

/// Split CSV text into records of unquoted cells.
fn parse_records(text: &str) -> Result<Vec<Vec<String>>> {
    let mut records = Vec::new();
    let mut record: Vec<String> = Vec::new();
    let mut cell = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        cell.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => cell.push(c),
            }
        } else {
            match c {
                '"' => {
                    in_quotes = true;
                    any = true;
                }
                ',' => {
                    record.push(std::mem::take(&mut cell));
                    any = true;
                }
                '\r' => {}
                '\n' => {
                    if any || !cell.is_empty() || !record.is_empty() {
                        record.push(std::mem::take(&mut cell));
                        records.push(std::mem::take(&mut record));
                    }
                    any = false;
                }
                c => {
                    cell.push(c);
                    any = true;
                }
            }
        }
    }
    if in_quotes {
        return Err(DdpError::format("csv", "unterminated quoted cell"));
    }
    if any || !cell.is_empty() || !record.is_empty() {
        record.push(cell);
        records.push(record);
    }
    Ok(records)
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn unhex(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(DdpError::format("csv", "odd hex length"));
    }
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .map_err(|_| DdpError::format("csv", "bad hex"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;
    use crate::util::testkit::property;

    fn schema() -> SchemaRef {
        Schema::new(vec![
            ("id", FieldType::I64),
            ("text", FieldType::Str),
            ("score", FieldType::F64),
            ("ok", FieldType::Bool),
        ])
    }

    #[test]
    fn roundtrip_basic() {
        let s = schema();
        let rows = vec![
            row!(1i64, "hello", 0.5, true),
            row!(2i64, "with,comma and \"quotes\"\nand newline", -1.25, false),
        ];
        let text = encode(&s, &rows);
        let back = decode(&s, &text).unwrap();
        assert_eq!(back, rows);
    }

    #[test]
    fn nulls_roundtrip() {
        let s = schema();
        let rows = vec![Row::new(vec![
            Field::Null,
            Field::Str("".into()),
            Field::Null,
            Field::Null,
        ])];
        let back = decode(&s, &encode(&s, &rows)).unwrap();
        assert_eq!(back, rows);
    }

    #[test]
    fn header_mismatch_rejected() {
        let s = schema();
        assert!(decode(&s, "a,b,c,d\n").is_err());
    }

    #[test]
    fn bad_cell_count_rejected() {
        let s = schema();
        assert!(decode(&s, "id,text,score,ok\n1,x\n").is_err());
    }

    #[test]
    fn prop_string_roundtrip() {
        let s = Schema::new(vec![("a", FieldType::Str), ("b", FieldType::Str)]);
        property(120, |g| {
            let rows: Vec<Row> = (0..g.usize(5))
                .map(|_| row!(g.string(0, 20), g.string(0, 20)))
                .collect();
            let back = decode(&s, &encode(&s, &rows)).unwrap();
            // empty strings decode as empty strings (Str type), so equality holds
            assert_eq!(back, rows);
        });
    }
}
