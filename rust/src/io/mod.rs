//! Data I/O abstraction (paper §3.3.1): unified, declarative read/write of
//! rows across storage backends ([`storage`]) and file formats ([`csv`],
//! [`jsonl`], [`colbin`]), with transparent encryption ([`crate::security`]).
//! Pipes never perform I/O; the DDP driver resolves `DataDeclare`s through
//! this module.

pub mod storage;
pub mod csv;
pub mod jsonl;
pub mod colbin;

pub use storage::{LocalFs, MemStore, SimKv, SimS3, Storage, StorageRef};

use crate::engine::row::{Row, SchemaRef};
use crate::security::{self, EncryptionMode, KeyChain};
use crate::util::error::{DdpError, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Supported file formats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Csv,
    Jsonl,
    Colbin,
}

impl Format {
    pub fn parse(s: &str) -> Result<Format> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "csv" => Format::Csv,
            "json" | "jsonl" => Format::Jsonl,
            "colbin" | "parquet" | "binary" => Format::Colbin,
            other => return Err(DdpError::format("io", format!("unknown format '{other}'"))),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Format::Csv => "csv",
            Format::Jsonl => "jsonl",
            Format::Colbin => "colbin",
        }
    }
}

/// A parsed dataset location: `scheme://path`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Location {
    pub scheme: String,
    pub path: String,
}

impl Location {
    pub fn parse(loc: &str) -> Result<Location> {
        match loc.split_once("://") {
            Some((scheme, path)) if !scheme.is_empty() && !path.is_empty() => Ok(Location {
                scheme: scheme.to_string(),
                path: path.to_string(),
            }),
            _ => Err(DdpError::format(
                "io",
                format!("bad location '{loc}', expected scheme://path"),
            )),
        }
    }
}

/// Resolves location schemes to storage backends. The default registry
/// wires `mem://` and `file://`; deployments add `s3://` / `kv://`.
pub struct IoRegistry {
    backends: HashMap<String, StorageRef>,
    keychain: Option<Arc<KeyChain>>,
}

impl IoRegistry {
    pub fn new() -> IoRegistry {
        let mut backends: HashMap<String, StorageRef> = HashMap::new();
        backends.insert("mem".into(), Arc::new(MemStore::new()));
        backends.insert("file".into(), Arc::new(LocalFs::new("/")));
        IoRegistry { backends, keychain: None }
    }

    /// Registry with simulated cloud backends (`s3://` with latency model,
    /// `kv://` NoSQL) for experiments.
    pub fn with_sim_cloud() -> IoRegistry {
        let mut r = IoRegistry::new();
        r.backends
            .insert("s3".into(), Arc::new(SimS3::new(Arc::new(MemStore::new()))));
        r.backends.insert("kv".into(), Arc::new(SimKv::new()));
        r
    }

    pub fn register(&mut self, scheme: &str, backend: StorageRef) {
        self.backends.insert(scheme.to_string(), backend);
    }

    pub fn set_keychain(&mut self, chain: Arc<KeyChain>) {
        self.keychain = Some(chain);
    }

    pub fn backend(&self, scheme: &str) -> Result<&StorageRef> {
        self.backends
            .get(scheme)
            .ok_or_else(|| DdpError::storage(scheme, "no backend registered for scheme"))
    }

    /// Read rows from a declarative location.
    pub fn read_rows(
        &self,
        loc: &str,
        format: Format,
        schema: &SchemaRef,
        encryption: EncryptionMode,
        dataset_id: &str,
    ) -> Result<Vec<Row>> {
        let location = Location::parse(loc)?;
        let backend = self.backend(&location.scheme)?;
        let raw = backend.read(&location.path)?;
        let plain = self.maybe_decrypt(encryption, dataset_id, raw)?;
        match format {
            Format::Csv => {
                let text = String::from_utf8(plain)
                    .map_err(|_| DdpError::format("csv", "not utf-8"))?;
                csv::decode(schema, &text)
            }
            Format::Jsonl => {
                let text = String::from_utf8(plain)
                    .map_err(|_| DdpError::format("jsonl", "not utf-8"))?;
                jsonl::decode(schema, &text)
            }
            Format::Colbin => colbin::decode(schema, &plain),
        }
    }

    /// Write rows to a declarative location.
    pub fn write_rows(
        &self,
        loc: &str,
        format: Format,
        schema: &SchemaRef,
        rows: &[Row],
        encryption: EncryptionMode,
        dataset_id: &str,
    ) -> Result<()> {
        let location = Location::parse(loc)?;
        let backend = self.backend(&location.scheme)?;
        let plain = match format {
            Format::Csv => csv::encode(schema, rows).into_bytes(),
            Format::Jsonl => jsonl::encode(schema, rows).into_bytes(),
            Format::Colbin => colbin::encode(schema, rows)?,
        };
        let blob = self.maybe_encrypt(encryption, dataset_id, plain)?;
        backend.write(&location.path, &blob)
    }

    fn maybe_encrypt(
        &self,
        mode: EncryptionMode,
        dataset_id: &str,
        blob: Vec<u8>,
    ) -> Result<Vec<u8>> {
        if mode == EncryptionMode::None {
            return Ok(blob);
        }
        let chain = self
            .keychain
            .as_ref()
            .ok_or_else(|| DdpError::security("encryption requested but no keychain configured"))?;
        security::encrypt_blob(chain, mode, dataset_id, &blob)
    }

    fn maybe_decrypt(
        &self,
        mode: EncryptionMode,
        dataset_id: &str,
        blob: Vec<u8>,
    ) -> Result<Vec<u8>> {
        if mode == EncryptionMode::None {
            return Ok(blob);
        }
        let chain = self
            .keychain
            .as_ref()
            .ok_or_else(|| DdpError::security("decryption requested but no keychain configured"))?;
        security::decrypt_blob(chain, mode, dataset_id, &blob)
    }
}

impl Default for IoRegistry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::{FieldType, Schema};
    use crate::row;
    use crate::security::MasterKey;

    fn schema() -> SchemaRef {
        Schema::new(vec![("id", FieldType::I64), ("text", FieldType::Str)])
    }

    #[test]
    fn location_parsing() {
        let l = Location::parse("s3://bucket/key.jsonl").unwrap();
        assert_eq!(l.scheme, "s3");
        assert_eq!(l.path, "bucket/key.jsonl");
        assert!(Location::parse("no-scheme").is_err());
        assert!(Location::parse("://x").is_err());
    }

    #[test]
    fn roundtrip_all_formats_mem() {
        let reg = IoRegistry::new();
        let s = schema();
        let rows = vec![row!(1i64, "a"), row!(2i64, "b,\"c\"")];
        for fmt in [Format::Csv, Format::Jsonl, Format::Colbin] {
            let loc = format!("mem://t/{}", fmt.name());
            reg.write_rows(&loc, fmt, &s, &rows, EncryptionMode::None, "d").unwrap();
            let back = reg.read_rows(&loc, fmt, &s, EncryptionMode::None, "d").unwrap();
            assert_eq!(back, rows, "{}", fmt.name());
        }
    }

    #[test]
    fn encrypted_roundtrip_and_wrong_mode_fails() {
        let mut reg = IoRegistry::new();
        reg.set_keychain(Arc::new(KeyChain::new(MasterKey::from_passphrase("k"))));
        let s = schema();
        let rows = vec![row!(1i64, "secret")];
        reg.write_rows("mem://enc/data", Format::Jsonl, &s, &rows, EncryptionMode::DatasetLevel, "ds")
            .unwrap();
        // raw bytes are not plaintext
        let raw = reg.backend("mem").unwrap().read("enc/data").unwrap();
        assert!(!String::from_utf8_lossy(&raw).contains("secret"));
        let back = reg
            .read_rows("mem://enc/data", Format::Jsonl, &s, EncryptionMode::DatasetLevel, "ds")
            .unwrap();
        assert_eq!(back, rows);
        // reading without decryption fails to parse
        assert!(reg
            .read_rows("mem://enc/data", Format::Jsonl, &s, EncryptionMode::None, "ds")
            .is_err());
    }

    #[test]
    fn encryption_without_keychain_errors() {
        let reg = IoRegistry::new();
        let s = schema();
        let r = reg.write_rows(
            "mem://x",
            Format::Jsonl,
            &s,
            &[row!(1i64, "x")],
            EncryptionMode::ServiceSide,
            "d",
        );
        assert!(r.is_err());
    }

    #[test]
    fn sim_cloud_schemes_available() {
        let reg = IoRegistry::with_sim_cloud();
        assert!(reg.backend("s3").is_ok());
        assert!(reg.backend("kv").is_ok());
        assert!(reg.backend("gcs").is_err());
    }

    #[test]
    fn format_parsing() {
        assert_eq!(Format::parse("CSV").unwrap(), Format::Csv);
        assert_eq!(Format::parse("parquet").unwrap(), Format::Colbin);
        assert!(Format::parse("xml").is_err());
    }
}
