//! JSON-Lines codec: one JSON object per line, keyed by schema column
//! names. The web-document corpus and metrics sink use this format.

use crate::engine::row::{Field, FieldType, Row, Schema, SchemaRef};
use crate::json::{self, Value};
use crate::util::error::{DdpError, Result};

/// Serialize rows to JSONL.
pub fn encode(schema: &Schema, rows: &[Row]) -> String {
    let mut out = String::new();
    for row in rows {
        let mut obj = std::collections::BTreeMap::new();
        for (i, f) in row.fields.iter().enumerate() {
            let (name, _) = schema.field(i);
            obj.insert(name.to_string(), field_to_value(f));
        }
        out.push_str(&json::to_string(&Value::Obj(obj)));
        out.push('\n');
    }
    out
}

/// Parse JSONL into rows; missing keys become nulls, extra keys error.
pub fn decode(schema: &SchemaRef, text: &str) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for (no, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = json::parse(line)
            .map_err(|e| DdpError::format("jsonl", format!("line {}: {e}", no + 1)))?;
        let obj = v
            .as_obj()
            .ok_or_else(|| DdpError::format("jsonl", format!("line {} is not an object", no + 1)))?;
        for key in obj.keys() {
            if schema.idx(key).is_none() {
                return Err(DdpError::format(
                    "jsonl",
                    format!("line {}: unknown key '{key}'", no + 1),
                ));
            }
        }
        let mut fields = Vec::with_capacity(schema.len());
        for i in 0..schema.len() {
            let (name, ty) = schema.field(i);
            let f = match obj.get(name) {
                None | Some(Value::Null) => Field::Null,
                Some(v) => value_to_field(v, ty).map_err(|e| {
                    DdpError::format("jsonl", format!("line {} field '{name}': {e}", no + 1))
                })?,
            };
            fields.push(f);
        }
        rows.push(Row::new(fields));
    }
    Ok(rows)
}

pub fn field_to_value(f: &Field) -> Value {
    match f {
        Field::Null => Value::Null,
        Field::Bool(b) => Value::Bool(*b),
        Field::I64(v) => Value::Num(*v as f64),
        Field::F64(v) => Value::Num(*v),
        Field::Str(s) => Value::Str(s.clone()),
        Field::Bytes(b) => Value::Str(base16(b)),
    }
}

pub fn value_to_field(v: &Value, ty: FieldType) -> Result<Field> {
    Ok(match (ty, v) {
        (_, Value::Null) => Field::Null,
        (FieldType::Bool, Value::Bool(b)) => Field::Bool(*b),
        (FieldType::I64, Value::Num(n)) if n.fract() == 0.0 => Field::I64(*n as i64),
        (FieldType::F64, Value::Num(n)) => Field::F64(*n),
        (FieldType::Str, Value::Str(s)) => Field::Str(s.clone()),
        (FieldType::Bytes, Value::Str(s)) => Field::Bytes(unbase16(s)?),
        (FieldType::Any, v) => match v {
            Value::Bool(b) => Field::Bool(*b),
            Value::Num(n) if n.fract() == 0.0 => Field::I64(*n as i64),
            Value::Num(n) => Field::F64(*n),
            Value::Str(s) => Field::Str(s.clone()),
            _ => return Err(DdpError::format("jsonl", "unsupported value for 'any'")),
        },
        (ty, v) => {
            return Err(DdpError::format(
                "jsonl",
                format!("cannot decode {v:?} as {}", ty.name()),
            ))
        }
    })
}

fn base16(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

fn unbase16(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(DdpError::format("jsonl", "odd hex length"));
    }
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16).map_err(|_| DdpError::format("jsonl", "bad hex"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;

    fn schema() -> SchemaRef {
        Schema::new(vec![
            ("id", FieldType::I64),
            ("text", FieldType::Str),
            ("score", FieldType::F64),
        ])
    }

    #[test]
    fn roundtrip() {
        let s = schema();
        let rows = vec![
            row!(1i64, "héllo \"w\"", 0.5),
            Row::new(vec![Field::I64(2), Field::Null, Field::F64(1.0)]),
        ];
        let text = encode(&s, &rows);
        assert_eq!(text.lines().count(), 2);
        assert_eq!(decode(&s, &text).unwrap(), rows);
    }

    #[test]
    fn missing_keys_are_null() {
        let s = schema();
        let rows = decode(&s, r#"{"id": 5}"#).unwrap();
        assert_eq!(rows[0].get(0).as_i64(), Some(5));
        assert!(rows[0].get(1).is_null());
    }

    #[test]
    fn unknown_key_rejected() {
        let s = schema();
        assert!(decode(&s, r#"{"nope": 1}"#).is_err());
    }

    #[test]
    fn type_mismatch_rejected() {
        let s = schema();
        assert!(decode(&s, r#"{"id": "str"}"#).is_err());
        assert!(decode(&s, r#"{"id": 1.5}"#).is_err());
    }

    #[test]
    fn bytes_roundtrip() {
        let s = Schema::new(vec![("b", FieldType::Bytes)]);
        let rows = vec![Row::new(vec![Field::Bytes(vec![0, 255, 16])])];
        assert_eq!(decode(&s, &encode(&s, &rows)).unwrap(), rows);
    }

    #[test]
    fn blank_lines_skipped() {
        let s = schema();
        let rows = decode(&s, "\n{\"id\": 1}\n\n{\"id\": 2}\n").unwrap();
        assert_eq!(rows.len(), 2);
    }
}
