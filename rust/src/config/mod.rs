//! Declarative pipeline configuration — the paper's §3.1 "Data as Anchor"
//! entry point. A pipeline is declared as three lists:
//!
//! * `data` — **DataDeclare**: every dataset (anchor) with location,
//!   schema, format, encryption, partitioning and cache policy;
//! * `pipes` — **TransformerDeclare**: logic units with
//!   `inputDataId` / `transformerType` / `outputDataId` (exactly the
//!   paper's JSON shape) plus free-form `params`;
//! * `metrics` — **MetricDeclare**: named metrics with a kind, published
//!   automatically at the configured cadence.
//!
//! Data ids referenced by pipes but not declared default to in-memory
//! anchors (`memory`), so the paper's literal four-pipe example parses
//! as-is.

use crate::engine::row::{FieldType, Schema, SchemaRef};
use crate::io::Format;
use crate::json::{self, Value};
use crate::security::EncryptionMode;
use crate::util::error::{DdpError, Result};
use std::collections::BTreeMap;

/// Where a dataset lives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DataLocation {
    /// in-memory anchor, owned by the run
    Memory,
    /// external storage location (`scheme://path`)
    Stored(String),
}

impl DataLocation {
    pub fn parse(s: &str) -> DataLocation {
        if s.is_empty() || s == "memory" || s == "mem" {
            DataLocation::Memory
        } else {
            DataLocation::Stored(s.to_string())
        }
    }

    pub fn as_str(&self) -> &str {
        match self {
            DataLocation::Memory => "memory",
            DataLocation::Stored(s) => s,
        }
    }
}

/// DataDeclare: one dataset anchor.
#[derive(Debug, Clone)]
pub struct DataDeclare {
    pub id: String,
    pub location: DataLocation,
    pub format: Format,
    pub schema: SchemaRef,
    /// schema explicitly declared (false = defaulted, skip contract checks)
    pub schema_declared: bool,
    pub encryption: EncryptionMode,
    pub partitions: usize,
    /// persist this anchor in the engine cache (§3.2 selective caching)
    pub cache: bool,
}

impl DataDeclare {
    /// Default in-memory anchor for an undeclared id.
    pub fn memory(id: &str, partitions: usize) -> DataDeclare {
        DataDeclare {
            id: id.to_string(),
            location: DataLocation::Memory,
            format: Format::Jsonl,
            schema: Schema::of_names(&[]),
            schema_declared: false,
            encryption: EncryptionMode::None,
            partitions,
            cache: false,
        }
    }

    fn from_json(v: &Value, default_partitions: usize) -> Result<DataDeclare> {
        let id = v
            .get("id")
            .and_then(|x| x.as_str())
            .ok_or_else(|| DdpError::config("DataDeclare missing 'id'"))?
            .to_string();
        let location = DataLocation::parse(&v.str_or("location", "memory"));
        let format = Format::parse(&v.str_or("format", "jsonl"))?;
        let (schema, schema_declared) = match v.get("schema") {
            Some(Value::Arr(cols)) => {
                let mut fields = Vec::new();
                for c in cols {
                    let name = c
                        .get("name")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| DdpError::config(format!("schema column in '{id}' missing 'name'")))?;
                    let ty = FieldType::parse(&c.str_or("type", "any"))?;
                    fields.push((name.to_string(), ty));
                }
                (
                    Schema::new(fields.iter().map(|(n, t)| (n.as_str(), *t)).collect()),
                    true,
                )
            }
            _ => (Schema::of_names(&[]), false),
        };
        let encryption = EncryptionMode::parse(&v.str_or("encryption", "none"))?;
        Ok(DataDeclare {
            id,
            location,
            format,
            schema,
            schema_declared,
            encryption,
            partitions: v.u64_or("partitions", default_partitions as u64) as usize,
            cache: v.bool_or("cache", false),
        })
    }
}

/// TransformerDeclare: one pipe instance.
#[derive(Debug, Clone)]
pub struct TransformerDeclare {
    /// unique instance name (defaults to the transformer type)
    pub name: String,
    pub transformer_type: String,
    pub input_data_ids: Vec<String>,
    pub output_data_ids: Vec<String>,
    /// free-form parameters forwarded to the pipe factory
    pub params: Value,
}

impl TransformerDeclare {
    fn from_json(v: &Value, index: usize) -> Result<TransformerDeclare> {
        let transformer_type = v
            .get("transformerType")
            .and_then(|x| x.as_str())
            .ok_or_else(|| {
                DdpError::config(format!("pipe #{index} missing 'transformerType'"))
            })?
            .to_string();
        let input_data_ids = v.get_string_list("inputDataId");
        let output_data_ids = v.get_string_list("outputDataId");
        if input_data_ids.is_empty() {
            return Err(DdpError::config(format!(
                "pipe '{transformer_type}' (#{index}) has no inputDataId"
            )));
        }
        if output_data_ids.is_empty() {
            return Err(DdpError::config(format!(
                "pipe '{transformer_type}' (#{index}) has no outputDataId"
            )));
        }
        let name = v.str_or("name", &transformer_type);
        Ok(TransformerDeclare {
            name,
            transformer_type,
            input_data_ids,
            output_data_ids,
            params: v.get("params").cloned().unwrap_or(Value::Obj(BTreeMap::new())),
        })
    }
}

/// MetricDeclare: one monitored metric.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MetricDeclare {
    pub id: String,
    pub kind: MetricKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricDeclare {
    fn from_json(v: &Value) -> Result<MetricDeclare> {
        let id = v
            .get("id")
            .and_then(|x| x.as_str())
            .ok_or_else(|| DdpError::config("MetricDeclare missing 'id'"))?
            .to_string();
        let kind = match v.str_or("kind", "counter").as_str() {
            "counter" => MetricKind::Counter,
            "gauge" => MetricKind::Gauge,
            "histogram" => MetricKind::Histogram,
            other => return Err(DdpError::config(format!("unknown metric kind '{other}'"))),
        };
        Ok(MetricDeclare { id, kind })
    }
}

/// Run-wide settings.
#[derive(Debug, Clone)]
pub struct PipelineSettings {
    pub metrics_cadence_secs: f64,
    pub default_partitions: usize,
    pub workers: usize,
    /// upper bound on pipes executing concurrently in the stage-parallel
    /// scheduler; `0` = auto (use `workers`), `1` = serial (exact legacy
    /// topo-order execution)
    pub max_concurrent_pipes: usize,
}

impl Default for PipelineSettings {
    fn default() -> Self {
        PipelineSettings {
            metrics_cadence_secs: 30.0, // the paper's default
            default_partitions: 8,
            workers: 4,
            max_concurrent_pipes: 0,
        }
    }
}

impl PipelineSettings {
    /// Resolve the effective scheduler width (`0` = auto = `workers`).
    pub fn effective_max_concurrent_pipes(&self) -> usize {
        if self.max_concurrent_pipes == 0 {
            self.workers.max(1)
        } else {
            self.max_concurrent_pipes
        }
    }
}

/// A complete pipeline declaration.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub name: String,
    pub data: BTreeMap<String, DataDeclare>,
    pub pipes: Vec<TransformerDeclare>,
    pub metrics: Vec<MetricDeclare>,
    pub settings: PipelineSettings,
}

impl PipelineSpec {
    /// Parse from JSON text. Accepts both the full object form
    /// (`{"name":..., "data":[...], "pipes":[...]}`) and the paper's bare
    /// array-of-pipes form.
    pub fn parse(text: &str) -> Result<PipelineSpec> {
        let v = json::parse(text)?;
        let (name, data_v, pipes_v, metrics_v, settings_v) = match &v {
            Value::Arr(_) => ("pipeline".to_string(), None, Some(v.clone()), None, None),
            Value::Obj(_) => (
                v.str_or("name", "pipeline"),
                v.get("data").cloned(),
                v.get("pipes").cloned(),
                v.get("metrics").cloned(),
                v.get("settings").cloned(),
            ),
            _ => return Err(DdpError::config("pipeline config must be an object or array")),
        };

        let mut settings = PipelineSettings::default();
        if let Some(s) = &settings_v {
            settings.metrics_cadence_secs = s.f64_or("metricsCadenceSecs", settings.metrics_cadence_secs);
            settings.default_partitions =
                s.u64_or("defaultPartitions", settings.default_partitions as u64) as usize;
            settings.workers = s.u64_or("workers", settings.workers as u64) as usize;
            settings.max_concurrent_pipes =
                s.u64_or("maxConcurrentPipes", settings.max_concurrent_pipes as u64) as usize;
        }

        let mut data = BTreeMap::new();
        if let Some(Value::Arr(items)) = &data_v {
            for item in items {
                let d = DataDeclare::from_json(item, settings.default_partitions)?;
                if data.insert(d.id.clone(), d.clone()).is_some() {
                    return Err(DdpError::config(format!("duplicate DataDeclare id '{}'", d.id)));
                }
            }
        }

        let pipes_arr = match &pipes_v {
            Some(Value::Arr(items)) => items.clone(),
            _ => return Err(DdpError::config("pipeline has no 'pipes' array")),
        };
        let mut pipes = Vec::new();
        let mut names = std::collections::HashSet::new();
        for (i, item) in pipes_arr.iter().enumerate() {
            let mut t = TransformerDeclare::from_json(item, i)?;
            // de-duplicate instance names
            while !names.insert(t.name.clone()) {
                t.name = format!("{}#{}", t.name, i);
            }
            pipes.push(t);
        }
        if pipes.is_empty() {
            return Err(DdpError::config("pipeline has no pipes"));
        }

        let mut metrics = Vec::new();
        if let Some(Value::Arr(items)) = &metrics_v {
            for item in items {
                metrics.push(MetricDeclare::from_json(item)?);
            }
        }

        // default-declare any data id referenced by a pipe but not declared
        let mut spec = PipelineSpec { name, data, pipes, metrics, settings };
        for pipe in &spec.pipes {
            for id in pipe.input_data_ids.iter().chain(&pipe.output_data_ids) {
                if !spec.data.contains_key(id) {
                    spec.data
                        .insert(id.clone(), DataDeclare::memory(id, spec.settings.default_partitions));
                }
            }
        }
        Ok(spec)
    }

    /// Data ids no pipe produces (must be supplied externally or loadable).
    pub fn source_ids(&self) -> Vec<String> {
        let produced: std::collections::HashSet<&String> = self
            .pipes
            .iter()
            .flat_map(|p| p.output_data_ids.iter())
            .collect();
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for p in &self.pipes {
            for id in &p.input_data_ids {
                if !produced.contains(id) && seen.insert(id.clone()) {
                    out.push(id.clone());
                }
            }
        }
        out
    }

    /// Data ids produced but never consumed (pipeline outputs).
    pub fn sink_ids(&self) -> Vec<String> {
        let consumed: std::collections::HashSet<&String> = self
            .pipes
            .iter()
            .flat_map(|p| p.input_data_ids.iter())
            .collect();
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for p in &self.pipes {
            for id in &p.output_data_ids {
                if !consumed.contains(id) && seen.insert(id.clone()) {
                    out.push(id.clone());
                }
            }
        }
        out
    }
}

/// The paper's §3.1 example pipeline, used in docs, tests and the
/// quickstart.
pub const PAPER_EXAMPLE: &str = r#"[
  {"inputDataId": ["InputData"],
   "transformerType": "PreprocessTransformer",
   "outputDataId": "IntermediateData"},
  {"inputDataId": "IntermediateData",
   "transformerType": "FeatureGenerationTransformer",
   "outputDataId": "FeatureData"},
  {"inputDataId": "FeatureData",
   "transformerType": "ModelPredictionTransformer",
   "outputDataId": "PredictionData"},
  {"inputDataId": ["InputData", "PredictionData"],
   "transformerType": "PostProcessTransformer",
   "outputDataId": "OutputData"}
]"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_parses() {
        let spec = PipelineSpec::parse(PAPER_EXAMPLE).unwrap();
        assert_eq!(spec.pipes.len(), 4);
        assert_eq!(spec.source_ids(), vec!["InputData"]);
        assert_eq!(spec.sink_ids(), vec!["OutputData"]);
        // undeclared anchors default to memory
        assert_eq!(spec.data["FeatureData"].location, DataLocation::Memory);
        assert_eq!(spec.data.len(), 5);
    }

    #[test]
    fn full_object_form() {
        let text = r#"{
          "name": "demo",
          "settings": {"defaultPartitions": 4, "metricsCadenceSecs": 0.5},
          "data": [
            {"id": "In", "location": "s3://b/in.csv", "format": "csv",
             "schema": [{"name": "id", "type": "i64"}, {"name": "t", "type": "str"}],
             "encryption": "dataset-level", "partitions": 16, "cache": true}
          ],
          "pipes": [
            {"inputDataId": "In", "transformerType": "X", "outputDataId": "Out",
             "params": {"threshold": 0.5}}
          ],
          "metrics": [{"id": "docs_total", "kind": "counter"}]
        }"#;
        let spec = PipelineSpec::parse(text).unwrap();
        assert_eq!(spec.name, "demo");
        let d = &spec.data["In"];
        assert_eq!(d.location, DataLocation::Stored("s3://b/in.csv".into()));
        assert_eq!(d.format, Format::Csv);
        assert!(d.schema_declared);
        assert_eq!(d.schema.len(), 2);
        assert_eq!(d.encryption, EncryptionMode::DatasetLevel);
        assert_eq!(d.partitions, 16);
        assert!(d.cache);
        assert_eq!(spec.pipes[0].params.f64_or("threshold", 0.0), 0.5);
        assert_eq!(spec.metrics[0].kind, MetricKind::Counter);
        assert_eq!(spec.settings.metrics_cadence_secs, 0.5);
        // Out is auto-declared
        assert!(spec.data.contains_key("Out"));
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(PipelineSpec::parse("{}").is_err()); // no pipes
        assert!(PipelineSpec::parse(r#"[{"transformerType": "X", "outputDataId": "o"}]"#).is_err()); // no input
        assert!(PipelineSpec::parse(r#"[{"inputDataId": "i", "outputDataId": "o"}]"#).is_err()); // no type
        assert!(PipelineSpec::parse("42").is_err());
    }

    #[test]
    fn max_concurrent_pipes_setting() {
        // default: auto (0) resolves to the worker count
        let spec = PipelineSpec::parse(PAPER_EXAMPLE).unwrap();
        assert_eq!(spec.settings.max_concurrent_pipes, 0);
        assert_eq!(
            spec.settings.effective_max_concurrent_pipes(),
            spec.settings.workers
        );

        let text = r#"{
          "settings": {"maxConcurrentPipes": 3, "workers": 8},
          "pipes": [{"inputDataId": "A", "transformerType": "X", "outputDataId": "B"}]
        }"#;
        let spec = PipelineSpec::parse(text).unwrap();
        assert_eq!(spec.settings.max_concurrent_pipes, 3);
        assert_eq!(spec.settings.effective_max_concurrent_pipes(), 3);

        let text = r#"{
          "settings": {"maxConcurrentPipes": 1},
          "pipes": [{"inputDataId": "A", "transformerType": "X", "outputDataId": "B"}]
        }"#;
        let spec = PipelineSpec::parse(text).unwrap();
        assert_eq!(spec.settings.effective_max_concurrent_pipes(), 1);
    }

    #[test]
    fn duplicate_data_id_rejected() {
        let text = r#"{
          "data": [{"id": "A"}, {"id": "A"}],
          "pipes": [{"inputDataId": "A", "transformerType": "X", "outputDataId": "B"}]
        }"#;
        assert!(PipelineSpec::parse(text).is_err());
    }

    #[test]
    fn duplicate_pipe_names_deduped() {
        let text = r#"[
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        assert_ne!(spec.pipes[0].name, spec.pipes[1].name);
    }

    #[test]
    fn multi_output_pipe() {
        let text = r#"[
          {"inputDataId": "A", "transformerType": "Splitter",
           "outputDataId": ["B", "C"]}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        assert_eq!(spec.pipes[0].output_data_ids, vec!["B", "C"]);
        assert_eq!(spec.sink_ids(), vec!["B", "C"]);
    }
}
