//! Rule-based lint framework over an analyzed plan DAG.
//!
//! Rules run after type inference and see the whole DAG at once via
//! [`LintCx`]: every analyzed node (post-order, children before
//! parents), its inferred columns, its consumer count, and a per-node
//! column [`Demand`] computed by walking requirements from the analysis
//! root down to the sources. Each rule appends [`Diagnostic`]s; rules
//! are pure observers and never mutate the plan.
//!
//! Standard rules (see the module docs on [`super`] for the code table):
//! duplicate column names (W101), persisted-with-single-consumer (W103),
//! dead columns (W104), opaque-closure-blocks-pushdown (N201) and
//! vectorization-fallback prediction (N202). Key-type mismatch checks
//! (E005) live in the inference pass itself because they are
//! type-driven, not shape-driven.

use super::super::dataset::Plan;
use super::super::row::FieldType;
use super::{Diagnostic, NodeMeta, Severity};
use std::collections::{BTreeSet, HashMap};

/// Which columns of a node's output are referenced downstream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Demand {
    /// every column may be read (closure-based consumers, analysis root)
    All,
    /// only these column positions are read
    Cols(BTreeSet<usize>),
}

impl Demand {
    fn union(&mut self, other: Demand) {
        if matches!(self, Demand::All) {
            return;
        }
        match other {
            Demand::All => *self = Demand::All,
            Demand::Cols(b) => {
                if let Demand::Cols(a) = self {
                    a.extend(b);
                }
            }
        }
    }
}

/// Everything a lint rule can see.
pub struct LintCx<'a> {
    /// analyzed nodes in post-order (children before parents; the
    /// analysis root is last)
    pub nodes: &'a [NodeMeta],
    /// downstream column demand per node id
    pub demand: HashMap<u64, Demand>,
    /// whether a node id is registered in the engine cache
    pub persisted: &'a dyn Fn(u64) -> bool,
}

/// A lint rule: a stable name and a pass over the analyzed DAG.
pub trait LintRule {
    fn name(&self) -> &'static str;
    fn run(&self, cx: &LintCx<'_>, out: &mut Vec<Diagnostic>);
}

/// The standard rule set, in emission order.
pub fn standard_rules() -> Vec<Box<dyn LintRule>> {
    vec![
        Box::new(DuplicateColumnNames),
        Box::new(SingleConsumerPersist),
        Box::new(DeadColumns),
        Box::new(OpaqueBlocksPushdown),
        Box::new(VectorizeFallback),
    ]
}

/// Run the standard rules over an analyzed node list.
pub fn run(nodes: &[NodeMeta], persisted: &dyn Fn(u64) -> bool, out: &mut Vec<Diagnostic>) {
    let cx = LintCx { demand: compute_demand(nodes), nodes, persisted };
    for rule in standard_rules() {
        rule.run(&cx, out);
    }
}

/// Propagate column demand from the analysis root (demands everything)
/// down to the sources. Nodes arrive in post-order, so iterating in
/// reverse visits every consumer before its inputs.
fn compute_demand(nodes: &[NodeMeta]) -> HashMap<u64, Demand> {
    let mut demand: HashMap<u64, Demand> = HashMap::new();
    if let Some(root) = nodes.last() {
        demand.insert(root.id, Demand::All);
    }
    let mut add = |demand: &mut HashMap<u64, Demand>, id: u64, d: Demand| {
        demand.entry(id).or_insert_with(|| Demand::Cols(BTreeSet::new())).union(d);
    };
    for meta in nodes.iter().rev() {
        let d = demand.get(&meta.id).cloned().unwrap_or(Demand::All);
        match &*meta.ds.node {
            Plan::Source { .. } => {}
            // closure-based operators may read any input column
            Plan::Map { input, .. }
            | Plan::Filter { input, .. }
            | Plan::FlatMap { input, .. }
            | Plan::MapPartitions { input, .. }
            | Plan::Sort { input, .. } => add(&mut demand, input.id, Demand::All),
            // whole-row hashing / closure reducers read everything
            Plan::Distinct { input, .. } | Plan::ReduceByKey { input, .. } => {
                add(&mut demand, input.id, Demand::All)
            }
            Plan::FilterExpr { input, expr } => {
                let mut want = d.clone();
                want.union(Demand::Cols(super::super::expr::cols_used(expr)));
                add(&mut demand, input.id, want);
            }
            Plan::Project { input, cols, .. } => {
                let want = match &d {
                    Demand::All => Demand::Cols(cols.iter().copied().collect()),
                    Demand::Cols(ps) => {
                        Demand::Cols(ps.iter().filter_map(|&p| cols.get(p).copied()).collect())
                    }
                };
                add(&mut demand, input.id, want);
            }
            Plan::Repartition { input, .. } => add(&mut demand, input.id, d.clone()),
            Plan::Union { inputs } => {
                for input in inputs {
                    add(&mut demand, input.id, d.clone());
                }
            }
            Plan::Join { left, right, lkey_col, rkey_col, .. } => {
                let lw = left.schema.len();
                let (mut dl, mut dr) = match &d {
                    Demand::All => (Demand::All, Demand::All),
                    Demand::Cols(ps) => (
                        Demand::Cols(ps.iter().copied().filter(|&p| p < lw).collect()),
                        Demand::Cols(
                            ps.iter().copied().filter(|&p| p >= lw).map(|p| p - lw).collect(),
                        ),
                    ),
                };
                // closure keys read the whole row; column keys just theirs
                match lkey_col {
                    Some(k) => dl.union(Demand::Cols(BTreeSet::from([*k]))),
                    None => dl = Demand::All,
                }
                match rkey_col {
                    Some(k) => dr.union(Demand::Cols(BTreeSet::from([*k]))),
                    None => dr = Demand::All,
                }
                add(&mut demand, left.id, dl);
                add(&mut demand, right.id, dr);
            }
        }
    }
    demand
}

// ------------------------------- rules --------------------------------

/// W101: a schema-introducing node declares the same column name twice;
/// `Schema::idx` resolves to only one of them, so by-name access is
/// ambiguous.
struct DuplicateColumnNames;

impl LintRule for DuplicateColumnNames {
    fn name(&self) -> &'static str {
        "duplicate-column-names"
    }

    fn run(&self, cx: &LintCx<'_>, out: &mut Vec<Diagnostic>) {
        for meta in cx.nodes {
            let introduces = matches!(
                &*meta.ds.node,
                Plan::Source { .. }
                    | Plan::Map { .. }
                    | Plan::FlatMap { .. }
                    | Plan::MapPartitions { .. }
                    | Plan::Project { .. }
                    | Plan::Join { .. }
            );
            if !introduces {
                continue;
            }
            let mut seen: BTreeSet<&str> = BTreeSet::new();
            let mut dups: Vec<&str> = Vec::new();
            for c in meta.cols.iter() {
                if !seen.insert(&c.name) && !dups.contains(&c.name.as_str()) {
                    dups.push(&c.name);
                }
            }
            if !dups.is_empty() {
                out.push(Diagnostic {
                    code: "W101",
                    severity: Severity::Warning,
                    path: meta.path.clone(),
                    message: format!(
                        "duplicate column name(s) [{}]; by-name access resolves to only one of them",
                        dups.join(", ")
                    ),
                });
            }
        }
    }
}

/// W103: a dataset is registered in the cache but only one plan node
/// consumes it — persisting buys nothing and costs memory.
struct SingleConsumerPersist;

impl LintRule for SingleConsumerPersist {
    fn name(&self) -> &'static str {
        "single-consumer-persist"
    }

    fn run(&self, cx: &LintCx<'_>, out: &mut Vec<Diagnostic>) {
        for meta in cx.nodes {
            // the analysis root legitimately has one consumer (the caller)
            let is_root = cx.nodes.last().map(|r| r.id) == Some(meta.id);
            if !is_root && (cx.persisted)(meta.id) && meta.consumers <= 1 {
                out.push(Diagnostic {
                    code: "W103",
                    severity: Severity::Warning,
                    path: meta.path.clone(),
                    message: format!(
                        "dataset is persisted but has a single consumer in this plan; \
                         caching pays only when lineage is re-executed ({} column(s) held)",
                        meta.cols.len()
                    ),
                });
            }
        }
    }
}

/// W104: columns produced at a materialization point (source or wide
/// operator) that no downstream node ever reads — a projection before
/// the shuffle/scan would shrink every row.
struct DeadColumns;

impl LintRule for DeadColumns {
    fn name(&self) -> &'static str {
        "dead-columns"
    }

    fn run(&self, cx: &LintCx<'_>, out: &mut Vec<Diagnostic>) {
        for meta in cx.nodes {
            let materializes =
                matches!(&*meta.ds.node, Plan::Source { .. }) || meta.ds.is_wide();
            if !materializes {
                continue;
            }
            let Some(Demand::Cols(used)) = cx.demand.get(&meta.id) else { continue };
            let dead: Vec<&str> = meta
                .cols
                .iter()
                .enumerate()
                .filter(|(i, _)| !used.contains(i))
                .map(|(_, c)| c.name.as_str())
                .collect();
            if !dead.is_empty() {
                out.push(Diagnostic {
                    code: "W104",
                    severity: Severity::Warning,
                    path: meta.path.clone(),
                    message: format!(
                        "column(s) [{}] are never referenced downstream; \
                         project them away to shrink rows",
                        dead.join(", ")
                    ),
                });
            }
        }
    }
}

/// N201: a `FilterExpr` sits directly above an opaque closure node, so
/// the optimizer cannot push the predicate any further down.
struct OpaqueBlocksPushdown;

impl LintRule for OpaqueBlocksPushdown {
    fn name(&self) -> &'static str {
        "opaque-blocks-pushdown"
    }

    fn run(&self, cx: &LintCx<'_>, out: &mut Vec<Diagnostic>) {
        for meta in cx.nodes {
            let Plan::FilterExpr { input, .. } = &*meta.ds.node else { continue };
            let blocker = match &*input.node {
                Plan::Map { .. } => Some("map"),
                Plan::FlatMap { .. } => Some("flat_map"),
                Plan::MapPartitions { .. } => Some("map_partitions"),
                Plan::Filter { .. } => Some("filter"),
                _ => None,
            };
            if let Some(kind) = blocker {
                out.push(Diagnostic {
                    code: "N201",
                    severity: Severity::Note,
                    path: meta.path.clone(),
                    message: format!(
                        "predicate sits above an opaque '{kind}' closure; \
                         pushdown stops here (express the closure as \
                         FilterExpr/Project to unlock it)"
                    ),
                });
            }
        }
    }
}

/// N202: a vectorizable node (`FilterExpr`/`Project`) whose input has
/// `any`-typed columns — `ColumnBatch::try_from_rows` needs a concrete
/// uniform type per column, so mixed batches fall back to row-at-a-time
/// execution.
struct VectorizeFallback;

impl LintRule for VectorizeFallback {
    fn name(&self) -> &'static str {
        "vectorize-fallback"
    }

    fn run(&self, cx: &LintCx<'_>, out: &mut Vec<Diagnostic>) {
        for meta in cx.nodes {
            let input = match &*meta.ds.node {
                Plan::FilterExpr { input, .. } => input,
                Plan::Project { input, .. } => input,
                _ => continue,
            };
            let Some(ix) = cx.nodes.iter().position(|n| n.id == input.id) else { continue };
            let any_cols: Vec<&str> = cx.nodes[ix]
                .cols
                .iter()
                .filter(|c| c.ty.base == FieldType::Any)
                .map(|c| c.name.as_str())
                .collect();
            if !any_cols.is_empty() {
                out.push(Diagnostic {
                    code: "N202",
                    severity: Severity::Note,
                    path: meta.path.clone(),
                    message: format!(
                        "input column(s) [{}] have no concrete type; batches mixing \
                         types here fall back to row-wise execution \
                         (declare concrete column types to keep this vectorized)",
                        any_cols.join(", ")
                    ),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::analyze_with_lints;
    use super::*;
    use crate::engine::dataset::Dataset;
    use crate::engine::expr::{BinOp, Expr, Func};
    use crate::engine::row::{Field, FieldType, Schema};
    use crate::row;

    fn src() -> Dataset {
        let schema = Schema::new(vec![
            ("id", FieldType::I64),
            ("name", FieldType::Str),
            ("score", FieldType::F64),
        ]);
        Dataset::from_rows("t", schema, vec![row!(1i64, "a", 0.5f64)], 2)
    }

    fn gt_zero(col: usize, name: &str) -> Expr {
        Expr::Binary(
            BinOp::Gt,
            Box::new(Expr::Col(col, name.into())),
            Box::new(Expr::Lit(Field::I64(0))),
        )
    }

    fn lints(ds: &Dataset) -> Vec<Diagnostic> {
        analyze_with_lints(ds, &|_| false).diagnostics
    }

    #[test]
    fn dead_columns_at_source() {
        // only 'id' is demanded: filter on id, then project to id
        let ds = src().filter_expr(gt_zero(0, "id")).project(vec![0]);
        let diags = lints(&ds);
        let w104 = diags.iter().find(|d| d.code == "W104").expect("dead columns");
        assert!(w104.message.contains("name"), "{}", w104.message);
        assert!(w104.message.contains("score"), "{}", w104.message);
        assert!(!w104.message.contains("[id"), "{}", w104.message);
    }

    #[test]
    fn closure_consumer_demands_everything() {
        let ds = src().filter(|_| true).project(vec![0]);
        // the closure filter may read any column: no dead-column warning
        assert!(lints(&ds).iter().all(|d| d.code != "W104"));
    }

    #[test]
    fn duplicate_names_warn() {
        let schema = Schema::new(vec![("x", FieldType::I64), ("x", FieldType::I64)]);
        let ds = Dataset::from_rows("dup", schema, vec![row!(1i64, 2i64)], 1);
        let diags = lints(&ds);
        assert!(diags.iter().any(|d| d.code == "W101"), "{diags:?}");
    }

    #[test]
    fn single_consumer_persist_warns_only_when_persisted() {
        let base = src().filter_expr(gt_zero(0, "id"));
        let root = base.project(vec![0]);
        assert!(lints(&root).iter().all(|d| d.code != "W103"));
        let persisted = base.id;
        let diags =
            analyze_with_lints(&root, &move |id| id == persisted).diagnostics;
        assert!(diags.iter().any(|d| d.code == "W103"), "{diags:?}");
    }

    #[test]
    fn opaque_closure_blocks_pushdown_note() {
        let mapped = src().map(src().schema.clone(), |r| r.clone());
        let ds = mapped.filter_expr(gt_zero(0, "id"));
        let diags = lints(&ds);
        assert!(diags.iter().any(|d| d.code == "N201"), "{diags:?}");
    }

    #[test]
    fn any_typed_input_predicts_fallback() {
        let schema = Schema::of_names(&["a", "b"]);
        let ds = Dataset::from_rows("u", schema, vec![row!(1i64, 2i64)], 1)
            .filter_expr(gt_zero(0, "a"));
        let diags = lints(&ds);
        assert!(diags.iter().any(|d| d.code == "N202"), "{diags:?}");
        // fully-typed inputs predict no fallback
        assert!(lints(&src().filter_expr(gt_zero(0, "id")))
            .iter()
            .all(|d| d.code != "N202"));
    }

    #[test]
    fn join_demand_splits_sides() {
        let l = src();
        let r = src();
        let schema = Schema::of_names(&["a", "b", "c", "d", "e", "f"]);
        // demand only left column 1 + join keys; right non-key columns die
        let ds = l
            .join_on(&r, schema, crate::engine::dataset::JoinKind::Inner, 2, 0, 0)
            .project(vec![1]);
        let diags = lints(&ds);
        let dead: Vec<&str> = diags
            .iter()
            .filter(|d| d.code == "W104")
            .map(|d| d.message.as_str())
            .collect();
        assert!(!dead.is_empty(), "{diags:?}");
        // 'score' is dead on both source sides
        assert!(dead.iter().any(|m| m.contains("score")), "{dead:?}");
    }

    #[test]
    fn string_function_lint_flows_through_call() {
        // contains(name, "x") over typed input: clean
        let e = Expr::Call(
            Func::Contains,
            vec![Expr::Col(1, "name".into()), Expr::Lit(Field::Str("x".into()))],
        );
        let a = analyze_with_lints(&src().filter_expr(e), &|_| false);
        assert!(a.is_clean(), "{}", a.error_summary());
    }
}
