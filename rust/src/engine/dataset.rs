//! Lazy, lineage-tracked dataset abstraction — the engine's RDD analogue.
//!
//! A [`Dataset`] is a handle to a node in a logical plan DAG. Nothing
//! executes until an action (`collect`, `count`, ...) runs on an
//! [`super::executor::EngineCtx`]. Narrow transformations (map / filter /
//! flat_map / map_partitions) fuse into per-partition pipelines; wide
//! transformations (reduce_by_key / join / distinct / repartition) insert
//! shuffle boundaries, exactly like Spark stages.

use super::row::{Row, SchemaRef};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One partition of materialized rows (shared, immutable).
pub type PartRef = Arc<Vec<Row>>;

/// A fully materialized distributed collection.
#[derive(Clone)]
pub struct Partitioned {
    pub schema: SchemaRef,
    pub parts: Vec<PartRef>,
}

impl Partitioned {
    pub fn num_rows(&self) -> usize {
        self.parts.iter().map(|p| p.len()).sum()
    }

    pub fn approx_bytes(&self) -> usize {
        self.parts
            .iter()
            .map(|p| p.iter().map(|r| r.approx_size()).sum::<usize>())
            .sum()
    }

    /// Flatten to a single vector (driver-side collect).
    pub fn rows(&self) -> Vec<Row> {
        let mut out = Vec::with_capacity(self.num_rows());
        for p in &self.parts {
            out.extend(p.iter().cloned());
        }
        out
    }
}

pub type MapFn = Arc<dyn Fn(&Row) -> Row + Send + Sync>;
pub type PredFn = Arc<dyn Fn(&Row) -> bool + Send + Sync>;
pub type FlatMapFn = Arc<dyn Fn(&Row) -> Vec<Row> + Send + Sync>;
pub type PartFn = Arc<dyn Fn(Vec<Row>) -> Vec<Row> + Send + Sync>;
pub type KeyFn = Arc<dyn Fn(&Row) -> super::row::Field + Send + Sync>;
pub type ReduceFn = Arc<dyn Fn(Row, &Row) -> Row + Send + Sync>;
pub type CmpFn = Arc<dyn Fn(&Row, &Row) -> std::cmp::Ordering + Send + Sync>;

/// Join variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinKind {
    Inner,
    Left,
}

/// Logical plan node. Each node gets a process-unique id used for caching,
/// stage naming, and visualization.
pub enum Plan {
    Source {
        name: String,
        data: Partitioned,
    },
    Map {
        input: Dataset,
        f: MapFn,
        schema: SchemaRef,
    },
    Filter {
        input: Dataset,
        f: PredFn,
    },
    FlatMap {
        input: Dataset,
        f: FlatMapFn,
        schema: SchemaRef,
    },
    /// Whole-partition transform; the hook for batched model inference
    /// (instance-level lifecycle: the closure owns the loaded model).
    MapPartitions {
        input: Dataset,
        f: PartFn,
        schema: SchemaRef,
    },
    ReduceByKey {
        input: Dataset,
        key: KeyFn,
        reduce: ReduceFn,
        num_parts: usize,
    },
    Distinct {
        input: Dataset,
        num_parts: usize,
    },
    Join {
        left: Dataset,
        right: Dataset,
        lkey: KeyFn,
        rkey: KeyFn,
        kind: JoinKind,
        num_parts: usize,
        schema: SchemaRef,
    },
    Union {
        inputs: Vec<Dataset>,
    },
    Sort {
        input: Dataset,
        cmp: CmpFn,
    },
    Repartition {
        input: Dataset,
        num_parts: usize,
    },
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Handle to a plan node.
#[derive(Clone)]
pub struct Dataset {
    pub id: u64,
    pub node: Arc<Plan>,
    pub schema: SchemaRef,
}

impl Dataset {
    /// Create a source dataset from pre-partitioned rows.
    pub fn from_parts(name: &str, schema: SchemaRef, parts: Vec<Vec<Row>>) -> Dataset {
        let data = Partitioned {
            schema: schema.clone(),
            parts: parts.into_iter().map(Arc::new).collect(),
        };
        Dataset {
            id: next_id(),
            schema,
            node: Arc::new(Plan::Source { name: name.to_string(), data }),
        }
    }

    /// Create a source dataset by splitting rows into `n` partitions.
    pub fn from_rows(name: &str, schema: SchemaRef, rows: Vec<Row>, n: usize) -> Dataset {
        let n = n.max(1);
        let chunk = rows.len().div_ceil(n).max(1);
        let mut parts: Vec<Vec<Row>> = Vec::with_capacity(n);
        let mut it = rows.into_iter().peekable();
        while it.peek().is_some() {
            parts.push(it.by_ref().take(chunk).collect());
        }
        if parts.is_empty() {
            parts.push(Vec::new());
        }
        Dataset::from_parts(name, schema, parts)
    }

    pub fn name(&self) -> String {
        match &*self.node {
            Plan::Source { name, .. } => name.clone(),
            Plan::Map { .. } => "map".into(),
            Plan::Filter { .. } => "filter".into(),
            Plan::FlatMap { .. } => "flat_map".into(),
            Plan::MapPartitions { .. } => "map_partitions".into(),
            Plan::ReduceByKey { .. } => "reduce_by_key".into(),
            Plan::Distinct { .. } => "distinct".into(),
            Plan::Join { .. } => "join".into(),
            Plan::Union { .. } => "union".into(),
            Plan::Sort { .. } => "sort".into(),
            Plan::Repartition { .. } => "repartition".into(),
        }
    }

    fn derive(&self, node: Plan, schema: SchemaRef) -> Dataset {
        Dataset { id: next_id(), node: Arc::new(node), schema }
    }

    /// 1→1 row transform. `schema` describes the output rows.
    pub fn map(&self, schema: SchemaRef, f: impl Fn(&Row) -> Row + Send + Sync + 'static) -> Dataset {
        self.derive(
            Plan::Map { input: self.clone(), f: Arc::new(f), schema: schema.clone() },
            schema,
        )
    }

    /// Keep rows matching the predicate.
    pub fn filter(&self, f: impl Fn(&Row) -> bool + Send + Sync + 'static) -> Dataset {
        self.derive(
            Plan::Filter { input: self.clone(), f: Arc::new(f) },
            self.schema.clone(),
        )
    }

    /// 1→N row transform.
    pub fn flat_map(
        &self,
        schema: SchemaRef,
        f: impl Fn(&Row) -> Vec<Row> + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::FlatMap { input: self.clone(), f: Arc::new(f), schema: schema.clone() },
            schema,
        )
    }

    /// Whole-partition transform (used for batched inference).
    pub fn map_partitions(
        &self,
        schema: SchemaRef,
        f: impl Fn(Vec<Row>) -> Vec<Row> + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::MapPartitions { input: self.clone(), f: Arc::new(f), schema: schema.clone() },
            schema,
        )
    }

    /// Shuffle by `key`, then fold rows with equal keys pairwise.
    pub fn reduce_by_key(
        &self,
        num_parts: usize,
        key: impl Fn(&Row) -> super::row::Field + Send + Sync + 'static,
        reduce: impl Fn(Row, &Row) -> Row + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::ReduceByKey {
                input: self.clone(),
                key: Arc::new(key),
                reduce: Arc::new(reduce),
                num_parts: num_parts.max(1),
            },
            self.schema.clone(),
        )
    }

    /// Global de-duplication of identical rows (shuffle + hash set).
    pub fn distinct(&self, num_parts: usize) -> Dataset {
        self.derive(
            Plan::Distinct { input: self.clone(), num_parts: num_parts.max(1) },
            self.schema.clone(),
        )
    }

    /// Hash join. Output schema = left fields ++ right fields.
    pub fn join(
        &self,
        right: &Dataset,
        out_schema: SchemaRef,
        kind: JoinKind,
        num_parts: usize,
        lkey: impl Fn(&Row) -> super::row::Field + Send + Sync + 'static,
        rkey: impl Fn(&Row) -> super::row::Field + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::Join {
                left: self.clone(),
                right: right.clone(),
                lkey: Arc::new(lkey),
                rkey: Arc::new(rkey),
                kind,
                num_parts: num_parts.max(1),
                schema: out_schema.clone(),
            },
            out_schema,
        )
    }

    /// Concatenate datasets with identical schemas.
    pub fn union(&self, others: &[Dataset]) -> Dataset {
        let mut inputs = vec![self.clone()];
        inputs.extend(others.iter().cloned());
        self.derive(Plan::Union { inputs }, self.schema.clone())
    }

    /// Global sort (gather-sort: result is a single partition).
    pub fn sort_by(
        &self,
        cmp: impl Fn(&Row, &Row) -> std::cmp::Ordering + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::Sort { input: self.clone(), cmp: Arc::new(cmp) },
            self.schema.clone(),
        )
    }

    /// Round-robin shuffle into `n` partitions.
    pub fn repartition(&self, n: usize) -> Dataset {
        self.derive(
            Plan::Repartition { input: self.clone(), num_parts: n.max(1) },
            self.schema.clone(),
        )
    }

    /// Direct upstream datasets (lineage edges).
    pub fn inputs(&self) -> Vec<Dataset> {
        match &*self.node {
            Plan::Source { .. } => vec![],
            Plan::Map { input, .. }
            | Plan::Filter { input, .. }
            | Plan::FlatMap { input, .. }
            | Plan::MapPartitions { input, .. }
            | Plan::ReduceByKey { input, .. }
            | Plan::Distinct { input, .. }
            | Plan::Sort { input, .. }
            | Plan::Repartition { input, .. } => vec![input.clone()],
            Plan::Join { left, right, .. } => vec![left.clone(), right.clone()],
            Plan::Union { inputs } => inputs.clone(),
        }
    }

    /// True if this node starts a new stage (shuffle boundary or source).
    pub fn is_wide(&self) -> bool {
        matches!(
            &*self.node,
            Plan::ReduceByKey { .. }
                | Plan::Distinct { .. }
                | Plan::Join { .. }
                | Plan::Sort { .. }
                | Plan::Repartition { .. }
        )
    }

    /// Depth of the lineage chain (for tests / diagnostics).
    pub fn lineage_depth(&self) -> usize {
        1 + self
            .inputs()
            .iter()
            .map(|d| d.lineage_depth())
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::{FieldType, Schema};
    use crate::row;

    fn sample() -> Dataset {
        let schema = Schema::new(vec![("id", FieldType::I64), ("v", FieldType::Str)]);
        let rows = (0..10).map(|i| row!(i as i64, format!("v{i}"))).collect();
        Dataset::from_rows("src", schema, rows, 3)
    }

    #[test]
    fn partitioning_splits_rows() {
        let ds = sample();
        match &*ds.node {
            Plan::Source { data, .. } => {
                assert_eq!(data.num_rows(), 10);
                assert_eq!(data.parts.len(), 3);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn empty_source_has_one_partition() {
        let schema = Schema::of_names(&["a"]);
        let ds = Dataset::from_rows("empty", schema, vec![], 4);
        match &*ds.node {
            Plan::Source { data, .. } => assert_eq!(data.parts.len(), 1),
            _ => unreachable!(),
        }
    }

    #[test]
    fn lineage_tracking() {
        let ds = sample();
        let mapped = ds.map(ds.schema.clone(), |r| r.clone());
        let filtered = mapped.filter(|_| true);
        assert_eq!(filtered.lineage_depth(), 3);
        assert_eq!(filtered.inputs()[0].id, mapped.id);
        assert!(!filtered.is_wide());
        assert!(filtered.distinct(2).is_wide());
    }

    #[test]
    fn ids_unique() {
        let ds = sample();
        let a = ds.filter(|_| true);
        let b = ds.filter(|_| true);
        assert_ne!(a.id, b.id);
    }
}
