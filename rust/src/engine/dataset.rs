//! Lazy, lineage-tracked dataset abstraction — the engine's RDD analogue.
//!
//! A [`Dataset`] is a handle to a node in a logical plan DAG. Nothing
//! executes until an action (`collect`, `count`, ...) runs on an
//! [`super::executor::EngineCtx`]. Narrow transformations (map / filter /
//! flat_map / map_partitions) fuse into per-partition pipelines; wide
//! transformations (reduce_by_key / join / distinct / repartition) insert
//! shuffle boundaries, exactly like Spark stages.

use super::expr::Expr;
use super::row::{Row, Schema, SchemaRef};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One partition of materialized rows (shared, immutable).
pub type PartRef = Arc<Vec<Row>>;

/// A fully materialized distributed collection.
#[derive(Clone)]
pub struct Partitioned {
    pub schema: SchemaRef,
    pub parts: Vec<PartRef>,
}

impl Partitioned {
    pub fn num_rows(&self) -> usize {
        self.parts.iter().map(|p| p.len()).sum()
    }

    pub fn approx_bytes(&self) -> usize {
        self.parts
            .iter()
            .map(|p| p.iter().map(|r| r.approx_size()).sum::<usize>())
            .sum()
    }

    /// Flatten to a single vector (driver-side collect).
    pub fn rows(&self) -> Vec<Row> {
        let mut out = Vec::with_capacity(self.num_rows());
        for p in &self.parts {
            out.extend(p.iter().cloned());
        }
        out
    }
}

pub type MapFn = Arc<dyn Fn(&Row) -> Row + Send + Sync>;
pub type PredFn = Arc<dyn Fn(&Row) -> bool + Send + Sync>;
pub type FlatMapFn = Arc<dyn Fn(&Row) -> Vec<Row> + Send + Sync>;
pub type PartFn = Arc<dyn Fn(Vec<Row>) -> Vec<Row> + Send + Sync>;
pub type KeyFn = Arc<dyn Fn(&Row) -> super::row::Field + Send + Sync>;
pub type ReduceFn = Arc<dyn Fn(Row, &Row) -> Row + Send + Sync>;
pub type CmpFn = Arc<dyn Fn(&Row, &Row) -> std::cmp::Ordering + Send + Sync>;

/// Join variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinKind {
    Inner,
    Left,
}

/// Logical plan node. Each node gets a process-unique id used for caching,
/// stage naming, and visualization.
pub enum Plan {
    Source {
        name: String,
        data: Partitioned,
    },
    Map {
        input: Dataset,
        f: MapFn,
        schema: SchemaRef,
    },
    Filter {
        input: Dataset,
        f: PredFn,
    },
    /// Structured filter carrying the SQL expression AST. Unlike the
    /// closure-based [`Plan::Filter`], the optimizer can inspect, fold,
    /// split and push this predicate.
    FilterExpr {
        input: Dataset,
        expr: Arc<Expr>,
    },
    /// Structured column projection (select + reorder by index). Unlike a
    /// closure-based [`Plan::Map`], the optimizer can collapse and push it.
    Project {
        input: Dataset,
        cols: Vec<usize>,
        schema: SchemaRef,
    },
    FlatMap {
        input: Dataset,
        f: FlatMapFn,
        schema: SchemaRef,
    },
    /// Whole-partition transform; the hook for batched model inference
    /// (instance-level lifecycle: the closure owns the loaded model).
    MapPartitions {
        input: Dataset,
        f: PartFn,
        schema: SchemaRef,
    },
    ReduceByKey {
        input: Dataset,
        key: KeyFn,
        reduce: ReduceFn,
        num_parts: usize,
        /// `Some(c)` when the key is exactly column `c` (built through
        /// [`Dataset::reduce_by_key_col`]); lets the optimizer push
        /// key-column predicates below the shuffle. `None` = opaque key.
        key_col: Option<usize>,
    },
    Distinct {
        input: Dataset,
        num_parts: usize,
    },
    Join {
        left: Dataset,
        right: Dataset,
        lkey: KeyFn,
        rkey: KeyFn,
        kind: JoinKind,
        num_parts: usize,
        schema: SchemaRef,
        /// key column indices when structured (built through
        /// [`Dataset::join_on`]); `None` = opaque key closures. Structured
        /// keys let the optimizer prune join inputs to referenced columns.
        lkey_col: Option<usize>,
        rkey_col: Option<usize>,
    },
    Union {
        inputs: Vec<Dataset>,
    },
    Sort {
        input: Dataset,
        cmp: CmpFn,
    },
    Repartition {
        input: Dataset,
        num_parts: usize,
    },
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Handle to a plan node.
#[derive(Clone)]
pub struct Dataset {
    pub id: u64,
    pub node: Arc<Plan>,
    pub schema: SchemaRef,
}

impl Dataset {
    /// Create a source dataset from pre-partitioned rows.
    pub fn from_parts(name: &str, schema: SchemaRef, parts: Vec<Vec<Row>>) -> Dataset {
        let data = Partitioned {
            schema: schema.clone(),
            parts: parts.into_iter().map(Arc::new).collect(),
        };
        Dataset {
            id: next_id(),
            schema,
            node: Arc::new(Plan::Source { name: name.to_string(), data }),
        }
    }

    /// Create a source dataset by splitting rows into `n` partitions.
    pub fn from_rows(name: &str, schema: SchemaRef, rows: Vec<Row>, n: usize) -> Dataset {
        let n = n.max(1);
        let chunk = rows.len().div_ceil(n).max(1);
        let mut parts: Vec<Vec<Row>> = Vec::with_capacity(n);
        let mut it = rows.into_iter().peekable();
        while it.peek().is_some() {
            parts.push(it.by_ref().take(chunk).collect());
        }
        if parts.is_empty() {
            parts.push(Vec::new());
        }
        Dataset::from_parts(name, schema, parts)
    }

    pub fn name(&self) -> String {
        match &*self.node {
            Plan::Source { name, .. } => name.clone(),
            Plan::Map { .. } => "map".into(),
            Plan::Filter { .. } => "filter".into(),
            Plan::FilterExpr { .. } => "filter_expr".into(),
            Plan::Project { .. } => "project".into(),
            Plan::FlatMap { .. } => "flat_map".into(),
            Plan::MapPartitions { .. } => "map_partitions".into(),
            Plan::ReduceByKey { .. } => "reduce_by_key".into(),
            Plan::Distinct { .. } => "distinct".into(),
            Plan::Join { .. } => "join".into(),
            Plan::Union { .. } => "union".into(),
            Plan::Sort { .. } => "sort".into(),
            Plan::Repartition { .. } => "repartition".into(),
        }
    }

    fn derive(&self, node: Plan, schema: SchemaRef) -> Dataset {
        Dataset::with_node(node, schema)
    }

    /// Wrap a plan node in a fresh dataset handle (optimizer constructor).
    pub(crate) fn with_node(node: Plan, schema: SchemaRef) -> Dataset {
        Dataset { id: next_id(), node: Arc::new(node), schema }
    }

    /// 1→1 row transform. `schema` describes the output rows.
    pub fn map(&self, schema: SchemaRef, f: impl Fn(&Row) -> Row + Send + Sync + 'static) -> Dataset {
        self.derive(
            Plan::Map { input: self.clone(), f: Arc::new(f), schema: schema.clone() },
            schema,
        )
    }

    /// Keep rows matching the predicate.
    pub fn filter(&self, f: impl Fn(&Row) -> bool + Send + Sync + 'static) -> Dataset {
        self.derive(
            Plan::Filter { input: self.clone(), f: Arc::new(f) },
            self.schema.clone(),
        )
    }

    /// Structured filter: keep rows where the SQL expression is truthy.
    /// Prefer this over [`Dataset::filter`] when the predicate is
    /// expressible — the plan optimizer can rewrite it.
    pub fn filter_expr(&self, expr: Expr) -> Dataset {
        self.derive(
            Plan::FilterExpr { input: self.clone(), expr: Arc::new(expr) },
            self.schema.clone(),
        )
    }

    /// Structured projection: select (and reorder) columns by index. The
    /// output schema is derived from the input schema. Prefer this over a
    /// closure [`Dataset::map`] for column selection — the plan optimizer
    /// can collapse and push it.
    pub fn project(&self, cols: Vec<usize>) -> Dataset {
        let schema = Schema::new(cols.iter().map(|&i| self.schema.field(i)).collect::<Vec<_>>());
        self.derive(
            Plan::Project { input: self.clone(), cols, schema: schema.clone() },
            schema,
        )
    }

    /// 1→N row transform.
    pub fn flat_map(
        &self,
        schema: SchemaRef,
        f: impl Fn(&Row) -> Vec<Row> + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::FlatMap { input: self.clone(), f: Arc::new(f), schema: schema.clone() },
            schema,
        )
    }

    /// Whole-partition transform (used for batched inference).
    pub fn map_partitions(
        &self,
        schema: SchemaRef,
        f: impl Fn(Vec<Row>) -> Vec<Row> + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::MapPartitions { input: self.clone(), f: Arc::new(f), schema: schema.clone() },
            schema,
        )
    }

    /// Shuffle by `key`, then fold rows with equal keys pairwise.
    pub fn reduce_by_key(
        &self,
        num_parts: usize,
        key: impl Fn(&Row) -> super::row::Field + Send + Sync + 'static,
        reduce: impl Fn(Row, &Row) -> Row + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::ReduceByKey {
                input: self.clone(),
                key: Arc::new(key),
                reduce: Arc::new(reduce),
                num_parts: num_parts.max(1),
                key_col: None,
            },
            self.schema.clone(),
        )
    }

    /// Column-keyed [`Dataset::reduce_by_key`]. Contract: `reduce` must
    /// preserve the key column — `reduce(acc, r)` returns a row whose
    /// column `key_col` equals the group key (true of any aggregation
    /// that folds values per key). The optimizer relies on this to push
    /// key-column predicates below the shuffle.
    pub fn reduce_by_key_col(
        &self,
        num_parts: usize,
        key_col: usize,
        reduce: impl Fn(Row, &Row) -> Row + Send + Sync + 'static,
    ) -> Dataset {
        // debug builds enforce the key-preservation contract: a violating
        // reducer would otherwise make optimizer-on and optimizer-off runs
        // silently disagree once a key predicate is pushed below the fold
        let checked = move |acc: Row, r: &Row| -> Row {
            if cfg!(debug_assertions) {
                let key = r.get(key_col).clone();
                let out = reduce(acc, r);
                assert!(
                    out.get(key_col).canonical_cmp(&key) == std::cmp::Ordering::Equal,
                    "reduce_by_key_col contract violated: reducer changed key column {key_col}"
                );
                out
            } else {
                reduce(acc, r)
            }
        };
        self.derive(
            Plan::ReduceByKey {
                input: self.clone(),
                key: Arc::new(move |r: &Row| r.get(key_col).clone()),
                reduce: Arc::new(checked),
                num_parts: num_parts.max(1),
                key_col: Some(key_col),
            },
            self.schema.clone(),
        )
    }

    /// Global de-duplication of identical rows (shuffle + hash set).
    pub fn distinct(&self, num_parts: usize) -> Dataset {
        self.derive(
            Plan::Distinct { input: self.clone(), num_parts: num_parts.max(1) },
            self.schema.clone(),
        )
    }

    /// Hash join. Output schema = left fields ++ right fields.
    pub fn join(
        &self,
        right: &Dataset,
        out_schema: SchemaRef,
        kind: JoinKind,
        num_parts: usize,
        lkey: impl Fn(&Row) -> super::row::Field + Send + Sync + 'static,
        rkey: impl Fn(&Row) -> super::row::Field + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::Join {
                left: self.clone(),
                right: right.clone(),
                lkey: Arc::new(lkey),
                rkey: Arc::new(rkey),
                kind,
                num_parts: num_parts.max(1),
                schema: out_schema.clone(),
                lkey_col: None,
                rkey_col: None,
            },
            out_schema,
        )
    }

    /// Column-keyed [`Dataset::join`]: equi-join on `left[lkey_col] ==
    /// right[rkey_col]`. Structured keys let the optimizer prune unused
    /// columns below the shuffle.
    pub fn join_on(
        &self,
        right: &Dataset,
        out_schema: SchemaRef,
        kind: JoinKind,
        num_parts: usize,
        lkey_col: usize,
        rkey_col: usize,
    ) -> Dataset {
        self.derive(
            Plan::Join {
                left: self.clone(),
                right: right.clone(),
                lkey: Arc::new(move |r: &Row| r.get(lkey_col).clone()),
                rkey: Arc::new(move |r: &Row| r.get(rkey_col).clone()),
                kind,
                num_parts: num_parts.max(1),
                schema: out_schema.clone(),
                lkey_col: Some(lkey_col),
                rkey_col: Some(rkey_col),
            },
            out_schema,
        )
    }

    /// Concatenate datasets with identical schemas.
    pub fn union(&self, others: &[Dataset]) -> Dataset {
        let mut inputs = vec![self.clone()];
        inputs.extend(others.iter().cloned());
        self.derive(Plan::Union { inputs }, self.schema.clone())
    }

    /// Global stable sort (result is a single totally-ordered partition;
    /// executed as a memory-governed external merge sort — per-partition
    /// sorted runs, spilled under budget pressure, k-way merged).
    pub fn sort_by(
        &self,
        cmp: impl Fn(&Row, &Row) -> std::cmp::Ordering + Send + Sync + 'static,
    ) -> Dataset {
        self.derive(
            Plan::Sort { input: self.clone(), cmp: Arc::new(cmp) },
            self.schema.clone(),
        )
    }

    /// Round-robin shuffle into `n` partitions.
    pub fn repartition(&self, n: usize) -> Dataset {
        self.derive(
            Plan::Repartition { input: self.clone(), num_parts: n.max(1) },
            self.schema.clone(),
        )
    }

    /// Direct upstream datasets (lineage edges).
    pub fn inputs(&self) -> Vec<Dataset> {
        match &*self.node {
            Plan::Source { .. } => vec![],
            Plan::Map { input, .. }
            | Plan::Filter { input, .. }
            | Plan::FilterExpr { input, .. }
            | Plan::Project { input, .. }
            | Plan::FlatMap { input, .. }
            | Plan::MapPartitions { input, .. }
            | Plan::ReduceByKey { input, .. }
            | Plan::Distinct { input, .. }
            | Plan::Sort { input, .. }
            | Plan::Repartition { input, .. } => vec![input.clone()],
            Plan::Join { left, right, .. } => vec![left.clone(), right.clone()],
            Plan::Union { inputs } => inputs.clone(),
        }
    }

    /// True if this node starts a new stage (shuffle boundary or source).
    pub fn is_wide(&self) -> bool {
        matches!(
            &*self.node,
            Plan::ReduceByKey { .. }
                | Plan::Distinct { .. }
                | Plan::Join { .. }
                | Plan::Sort { .. }
                | Plan::Repartition { .. }
        )
    }

    /// Depth of the lineage chain (for tests / diagnostics).
    pub fn lineage_depth(&self) -> usize {
        1 + self
            .inputs()
            .iter()
            .map(|d| d.lineage_depth())
            .max()
            .unwrap_or(0)
    }

    /// Render the plan tree as indented text — stable across runs (no node
    /// ids), used by the optimizer's golden tests and for diagnostics.
    /// Shared subtrees print once per consumer.
    pub fn plan_display(&self) -> String {
        fn label(ds: &Dataset) -> String {
            match &*ds.node {
                Plan::Source { name, .. } => format!("source[{name}]"),
                Plan::Map { .. } => "map".into(),
                Plan::Filter { .. } => "filter".into(),
                Plan::FilterExpr { expr, .. } => format!("filter_expr[{expr}]"),
                Plan::Project { schema, .. } => {
                    format!("project[{}]", schema.names().join(", "))
                }
                Plan::FlatMap { .. } => "flat_map".into(),
                Plan::MapPartitions { .. } => "map_partitions".into(),
                Plan::ReduceByKey { num_parts, key_col, .. } => match key_col {
                    Some(c) => format!("reduce_by_key[col {c}, parts {num_parts}]"),
                    None => format!("reduce_by_key[parts {num_parts}]"),
                },
                Plan::Distinct { num_parts, .. } => format!("distinct[parts {num_parts}]"),
                Plan::Join { kind, num_parts, lkey_col, rkey_col, .. } => {
                    let k = match kind {
                        JoinKind::Inner => "inner",
                        JoinKind::Left => "left",
                    };
                    match (lkey_col, rkey_col) {
                        (Some(l), Some(r)) => {
                            format!("join[{k}, parts {num_parts}, on {l}={r}]")
                        }
                        _ => format!("join[{k}, parts {num_parts}]"),
                    }
                }
                Plan::Union { .. } => "union".into(),
                Plan::Sort { .. } => "sort".into(),
                Plan::Repartition { num_parts, .. } => {
                    format!("repartition[parts {num_parts}]")
                }
            }
        }
        fn go(ds: &Dataset, depth: usize, out: &mut String) {
            out.push_str(&"  ".repeat(depth));
            out.push_str(&label(ds));
            out.push('\n');
            for input in ds.inputs() {
                go(&input, depth + 1, out);
            }
        }
        let mut out = String::new();
        go(self, 0, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::{FieldType, Schema};
    use crate::row;

    fn sample() -> Dataset {
        let schema = Schema::new(vec![("id", FieldType::I64), ("v", FieldType::Str)]);
        let rows = (0..10).map(|i| row!(i as i64, format!("v{i}"))).collect();
        Dataset::from_rows("src", schema, rows, 3)
    }

    #[test]
    fn partitioning_splits_rows() {
        let ds = sample();
        match &*ds.node {
            Plan::Source { data, .. } => {
                assert_eq!(data.num_rows(), 10);
                assert_eq!(data.parts.len(), 3);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn empty_source_has_one_partition() {
        let schema = Schema::of_names(&["a"]);
        let ds = Dataset::from_rows("empty", schema, vec![], 4);
        match &*ds.node {
            Plan::Source { data, .. } => assert_eq!(data.parts.len(), 1),
            _ => unreachable!(),
        }
    }

    #[test]
    fn lineage_tracking() {
        let ds = sample();
        let mapped = ds.map(ds.schema.clone(), |r| r.clone());
        let filtered = mapped.filter(|_| true);
        assert_eq!(filtered.lineage_depth(), 3);
        assert_eq!(filtered.inputs()[0].id, mapped.id);
        assert!(!filtered.is_wide());
        assert!(filtered.distinct(2).is_wide());
    }

    #[test]
    fn ids_unique() {
        let ds = sample();
        let a = ds.filter(|_| true);
        let b = ds.filter(|_| true);
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn project_derives_schema() {
        let ds = sample();
        let p = ds.project(vec![1, 0]);
        assert_eq!(p.schema.names(), vec!["v", "id"]);
        assert_eq!(p.schema.field_type(0), FieldType::Str);
        assert!(!p.is_wide());
    }

    #[test]
    fn structured_nodes_carry_metadata() {
        use crate::engine::expr::{BinOp, Expr};
        let ds = sample();
        let f = ds.filter_expr(Expr::Binary(
            BinOp::Gt,
            Box::new(Expr::Col(0, "id".into())),
            Box::new(Expr::Lit(crate::engine::row::Field::F64(3.0))),
        ));
        assert_eq!(f.name(), "filter_expr");
        let r = ds.reduce_by_key_col(2, 0, |acc, _| acc);
        match &*r.node {
            Plan::ReduceByKey { key_col, .. } => assert_eq!(*key_col, Some(0)),
            _ => unreachable!(),
        }
        let j = ds.join_on(&ds.clone(), Schema::of_names(&["a", "b", "c", "d"]), JoinKind::Inner, 2, 0, 0);
        match &*j.node {
            Plan::Join { lkey_col, rkey_col, .. } => {
                assert_eq!(*lkey_col, Some(0));
                assert_eq!(*rkey_col, Some(0));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn plan_display_renders_tree() {
        let ds = sample();
        let p = ds.project(vec![0]).repartition(3);
        assert_eq!(
            p.plan_display(),
            "repartition[parts 3]\n  project[id]\n    source[src]\n"
        );
    }
}
