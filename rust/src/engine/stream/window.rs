//! Event-time tumbling windows with watermarks.
//!
//! Unlike the [`super::StreamQuery`] capture states — which exist to be
//! byte-identical with a batch replay — windows are a *streaming-native*
//! operator: results are emitted continuously as event time progresses,
//! not at drain. Determinism still holds, just with a different anchor:
//! given the same rows in the same arrival order, window closure happens
//! at the same points and emissions come out in the same order (windows
//! ascending by start, keys in canonical field order within a window).
//!
//! The watermark is the classic low-watermark heuristic: `max event time
//! seen − allowed lateness`. A window `[start, start+width)` closes when
//! the watermark reaches its end; rows arriving for an already-closed
//! window are counted as late drops rather than reopening it (emitting a
//! window twice would break downstream exactly-once accounting).

use super::super::dataset::ReduceFn;
use super::super::executor::field_hash;
use super::super::row::{Field, Row};
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// Tracks the event-time low watermark.
#[derive(Debug, Clone, Copy)]
pub struct WatermarkTracker {
    max_event_ts: Option<i64>,
    lateness: i64,
}

impl WatermarkTracker {
    pub fn new(allowed_lateness: i64) -> WatermarkTracker {
        WatermarkTracker { max_event_ts: None, lateness: allowed_lateness.max(0) }
    }

    pub fn observe(&mut self, ts: i64) {
        self.max_event_ts = Some(self.max_event_ts.map_or(ts, |m| m.max(ts)));
    }

    /// Current watermark; `i64::MIN` until the first observation.
    pub fn watermark(&self) -> i64 {
        self.max_event_ts
            .map(|m| m.saturating_sub(self.lateness))
            .unwrap_or(i64::MIN)
    }
}

/// Tumbling window geometry over an integer event-time column.
#[derive(Debug, Clone, Copy)]
pub struct TumblingWindow {
    /// window width in event-time units (must be > 0)
    pub width: i64,
    /// column holding the event timestamp (i64)
    pub ts_col: usize,
    /// optional grouping column (None = one group per window)
    pub key_col: Option<usize>,
}

impl TumblingWindow {
    /// Window start containing `ts` (euclidean floor, so negative
    /// timestamps land in the right window too).
    pub fn window_start(&self, ts: i64) -> i64 {
        ts.div_euclid(self.width) * self.width
    }
}

/// Windowed streaming aggregation: folds rows per (window, key) with a
/// reduce function, closing windows as the watermark passes them.
///
/// Emitted rows are `[window_start: i64] ++ accumulator fields`.
pub struct WindowAgg {
    win: TumblingWindow,
    reduce: ReduceFn,
    wm: WatermarkTracker,
    open: HashMap<(i64, Field), Row>,
    /// all windows ending at or before this are closed (late frontier)
    frontier: i64,
    late_drops: u64,
    /// rows whose timestamp column was missing or non-i64 — data
    /// breakage, counted apart from genuine lateness so alarms can tell
    /// the two failure modes apart
    invalid_ts_drops: u64,
    windows_emitted: u64,
}

impl WindowAgg {
    pub fn new(
        win: TumblingWindow,
        allowed_lateness: i64,
        reduce: impl Fn(Row, &Row) -> Row + Send + Sync + 'static,
    ) -> WindowAgg {
        assert!(win.width > 0, "window width must be positive");
        WindowAgg {
            win,
            reduce: Arc::new(reduce),
            wm: WatermarkTracker::new(allowed_lateness),
            open: HashMap::new(),
            frontier: i64::MIN,
            late_drops: 0,
            invalid_ts_drops: 0,
            windows_emitted: 0,
        }
    }

    /// Absorb a micro-batch. Rows for already-closed windows are dropped
    /// (late) and counted.
    pub fn push(&mut self, rows: &[Row]) {
        let reduce = self.reduce.clone();
        for r in rows {
            let ts = match r.get(self.win.ts_col).as_i64() {
                Some(t) => t,
                None => {
                    self.invalid_ts_drops += 1;
                    continue;
                }
            };
            let start = self.win.window_start(ts);
            if self.frontier != i64::MIN && start + self.win.width <= self.frontier {
                self.late_drops += 1;
                continue;
            }
            let key = self
                .win
                .key_col
                .map(|c| r.get(c).clone())
                .unwrap_or(Field::Null);
            let slot = (start, key);
            match self.open.remove(&slot) {
                Some(acc) => {
                    self.open.insert(slot, reduce(acc, r));
                }
                None => {
                    self.open.insert(slot, r.clone());
                }
            }
            self.wm.observe(ts);
        }
    }

    /// Emit every window the watermark has passed, deterministically
    /// ordered (window start ascending, then canonical key order).
    pub fn poll_closed(&mut self) -> Vec<Row> {
        let wm = self.wm.watermark();
        if wm == i64::MIN {
            return Vec::new();
        }
        let closed = self.take_closed(|start, width| start + width <= wm);
        if wm > self.frontier {
            self.frontier = wm;
        }
        closed
    }

    /// End of stream: close and emit every remaining window.
    pub fn finish(&mut self) -> Vec<Row> {
        self.frontier = i64::MAX;
        self.take_closed(|_, _| true)
    }

    fn take_closed(&mut self, ready: impl Fn(i64, i64) -> bool) -> Vec<Row> {
        let width = self.win.width;
        let mut keys: Vec<(i64, Field)> = self
            .open
            .keys()
            .filter(|(start, _)| ready(*start, width))
            .cloned()
            .collect();
        keys.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.canonical_cmp(&b.1)));
        let mut out = Vec::with_capacity(keys.len());
        for k in keys {
            if let Some(acc) = self.open.remove(&k) {
                let mut fields = Vec::with_capacity(acc.fields.len() + 1);
                fields.push(Field::I64(k.0));
                fields.extend(acc.fields);
                out.push(Row::new(fields));
                self.windows_emitted += 1;
            }
        }
        out
    }

    pub fn watermark(&self) -> i64 {
        self.wm.watermark()
    }

    pub fn open_windows(&self) -> usize {
        self.open.len()
    }

    pub fn late_drops(&self) -> u64 {
        self.late_drops
    }

    pub fn invalid_ts_drops(&self) -> u64 {
        self.invalid_ts_drops
    }

    pub fn windows_emitted(&self) -> u64 {
        self.windows_emitted
    }
}

/// Streaming de-duplication keyed on a content hash of one column:
/// first occurrence passes through (append mode), repeats are dropped.
/// State is one `u64` per distinct content hash, not one row.
pub struct StreamingDedup {
    key_col: usize,
    seen: HashSet<u64>,
    passed: u64,
    dropped: u64,
}

impl StreamingDedup {
    pub fn new(key_col: usize) -> StreamingDedup {
        StreamingDedup { key_col, seen: HashSet::new(), passed: 0, dropped: 0 }
    }

    /// Keep only first-seen rows, in arrival order.
    pub fn push(&mut self, rows: Vec<Row>) -> Vec<Row> {
        let mut out = Vec::with_capacity(rows.len());
        for r in rows {
            let h = field_hash(r.get(self.key_col));
            if self.seen.insert(h) {
                self.passed += 1;
                out.push(r);
            } else {
                self.dropped += 1;
            }
        }
        out
    }

    pub fn distinct_seen(&self) -> usize {
        self.seen.len()
    }

    pub fn passed(&self) -> u64 {
        self.passed
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;

    fn count_reduce() -> impl Fn(Row, &Row) -> Row + Send + Sync + 'static {
        // rows are (ts, key, n); fold sums n
        |acc: Row, r: &Row| {
            row!(
                acc.get(0).as_i64().unwrap(),
                acc.get(1).as_i64().unwrap(),
                acc.get(2).as_i64().unwrap() + r.get(2).as_i64().unwrap()
            )
        }
    }

    fn agg(lateness: i64) -> WindowAgg {
        WindowAgg::new(
            TumblingWindow { width: 10, ts_col: 0, key_col: Some(1) },
            lateness,
            count_reduce(),
        )
    }

    #[test]
    fn windows_close_as_watermark_passes() {
        let mut w = agg(0);
        w.push(&[row!(1i64, 0i64, 1i64), row!(5i64, 1i64, 1i64), row!(12i64, 0i64, 1i64)]);
        // watermark 12: window [0,10) closed, [10,20) still open
        let closed = w.poll_closed();
        assert_eq!(closed.len(), 2);
        // deterministic order: window 0 / key 0, then window 0 / key 1
        assert_eq!(closed[0].get(0).as_i64(), Some(0));
        assert_eq!(closed[0].get(2).as_i64(), Some(0));
        assert_eq!(closed[1].get(2).as_i64(), Some(1));
        assert_eq!(w.open_windows(), 1);

        w.push(&[row!(25i64, 0i64, 1i64)]);
        let closed = w.poll_closed();
        assert_eq!(closed.len(), 1, "[10,20) closes at watermark 25");
        assert_eq!(closed[0].get(0).as_i64(), Some(10));

        let last = w.finish();
        assert_eq!(last.len(), 1, "[20,30) closes at end of stream");
        assert_eq!(w.windows_emitted(), 4);
    }

    #[test]
    fn lateness_holds_windows_open_and_late_rows_drop() {
        let mut w = agg(5);
        w.push(&[row!(1i64, 0i64, 1i64), row!(12i64, 0i64, 1i64)]);
        // watermark = 12 - 5 = 7: nothing closes yet
        assert!(w.poll_closed().is_empty());
        w.push(&[row!(3i64, 0i64, 1i64)]); // within lateness: still folds
        w.push(&[row!(16i64, 0i64, 1i64)]);
        // watermark 11: [0,10) closes with both early rows folded
        let closed = w.poll_closed();
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].get(3).as_i64(), Some(2), "late-but-allowed row included");
        // a row for the closed window is now a late drop
        w.push(&[row!(2i64, 0i64, 1i64)]);
        assert_eq!(w.late_drops(), 1);
        assert_eq!(w.finish().len(), 1);
    }

    #[test]
    fn deterministic_across_replays() {
        let rows: Vec<Row> = (0..100)
            .map(|i| row!((i * 3 % 47) as i64, (i % 3) as i64, 1i64))
            .collect();
        let run = || {
            let mut w = agg(2);
            let mut out = Vec::new();
            for chunk in rows.chunks(9) {
                w.push(chunk);
                out.extend(w.poll_closed());
            }
            out.extend(w.finish());
            (out, w.late_drops())
        };
        let (a, la) = run();
        let (b, lb) = run();
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn invalid_timestamps_counted_apart_from_lateness() {
        let mut w = agg(0);
        w.push(&[row!("not a ts", 0i64, 1i64), row!(5i64, 0i64, 1i64)]);
        assert_eq!(w.invalid_ts_drops(), 1);
        assert_eq!(w.late_drops(), 0, "data breakage is not lateness");
        assert_eq!(w.finish().len(), 1, "valid row still aggregates");
    }

    #[test]
    fn negative_timestamps_window_correctly() {
        let w = TumblingWindow { width: 10, ts_col: 0, key_col: None };
        assert_eq!(w.window_start(-1), -10);
        assert_eq!(w.window_start(-10), -10);
        assert_eq!(w.window_start(-11), -20);
        assert_eq!(w.window_start(0), 0);
        assert_eq!(w.window_start(9), 0);
    }

    #[test]
    fn streaming_dedup_first_seen_wins() {
        let mut d = StreamingDedup::new(1);
        let out = d.push(vec![row!(0i64, "a"), row!(1i64, "b"), row!(2i64, "a")]);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].get(0).as_i64(), Some(0), "first occurrence kept");
        let out = d.push(vec![row!(3i64, "b"), row!(4i64, "c")]);
        assert_eq!(out.len(), 1);
        assert_eq!(d.distinct_seen(), 3);
        assert_eq!(d.passed(), 3);
        assert_eq!(d.dropped(), 2);
    }
}
