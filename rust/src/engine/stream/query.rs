//! Micro-batch execution of a (possibly stateful) Plan DAG.
//!
//! A [`StreamQuery`] is compiled once from a *template* plan built over a
//! placeholder source dataset. Each micro-batch is spliced into the
//! template in place of that placeholder and the **existing** engine —
//! optimizer, fused narrow stages, shuffle operators — evaluates the
//! per-batch work; nothing below re-implements row transformation.
//!
//! ## Plan segmentation
//!
//! Compiling classifies every template node:
//!
//! * **Streaming** — the placeholder source and any narrow chain above
//!   it: evaluated once per micro-batch, emitting a per-batch delta;
//! * **Static** — subtrees that never read the streaming source (e.g.
//!   the bounded side of a join): left untouched until drain;
//! * **Finish** — wide/stateful operators fed (directly or transitively)
//!   by streaming rows, plus everything above them.
//!
//! Every Streaming node consumed by a Finish node is a *capture point*:
//! its per-batch delta is absorbed into the [`StreamQuery`]'s state. A
//! capture consumed by exactly one `ReduceByKey` folds incrementally
//! (state = one accumulator row per key); one consumed by exactly one
//! `Distinct` keeps a first-seen set bucketed exactly like the batch
//! shuffle; one consumed by exactly one `Sort` keeps governed sorted
//! runs (each batch delta pre-sorted, spilled when the budget refuses)
//! that drain through the external merge sort's k-way merge. Other
//! consumers (join, union, repartition — inherently blocking ops)
//! accumulate raw rows in arrival order.
//!
//! ## Batch parity
//!
//! At drain, incremental captures (`ReduceByKey`, `Distinct`) are
//! materialized with the *exact partition layout the batch executor
//! would have produced at that node* — same bucket assignment via the
//! executor's own hashes, same canonical key order (`Sort` frontiers
//! merge their runs with batch-order tie-breaking, which equals the
//! stable sort of the arrival-order concatenation) — so everything
//! above them, evaluated by the regular executor, is byte-identical to
//! the batch run including partition boundaries. Raw captures
//! (join/union/repartition inputs) preserve exact **row content
//! and order** but concatenate to a single partition; their consumers
//! re-bucket by content (`Join`,
//! `Repartition`, `Distinct`), which re-normalizes the layout — only a
//! partition-*boundary*-sensitive operator directly above a `Union` of
//! a raw capture would observe the difference, which the
//! `map_partitions` contract below already excludes. Replaying a corpus
//! therefore yields byte-identical final output to the one-shot batch
//! run, at any micro-batch size, provided:
//!
//! * reduce functions are **associative** (the batch engine's map-side
//!   combine already assumes this; counts, min/max, keep-first/lowest
//!   qualify — chained f64 sums are only approximately associative);
//! * `map_partitions` closures are batch-boundary-agnostic (per-row
//!   outputs, e.g. batched inference — partition *sizes* differ between
//!   a micro-batch run and a batch run).
//!
//! The differential suite in `tests/streaming.rs` asserts this parity at
//! batch sizes {1, 100, whole-corpus}, optimizer on and off.

use super::super::dataset::{CmpFn, Dataset, KeyFn, Partitioned, Plan, ReduceFn};
use super::super::executor::{bucket_of, whole_row_key, EngineCtx};
use super::super::optimizer;
use super::super::row::{Field, Row, SchemaRef};
use super::super::spill::{SortedRun, SortedRunSet, SpilledRows};
use super::super::stats::Stat;
use super::super::trace::SpanKind;
use crate::util::error::{DdpError, Result};
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// Node classification (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Class {
    Static,
    Streaming,
    Finish,
}

/// Cross-batch state of one capture point.
enum CapState {
    /// raw rows in arrival order (blocking consumers); substituted for
    /// the captured node itself at drain. The buffer reserves from the
    /// engine's [`super::super::memory::MemoryGovernor`] and spills to
    /// disk chunks when refused, so a long-running query's blocking
    /// state stays within the memory budget
    Raw(SpilledRows),
    /// incremental fold for a single `ReduceByKey` consumer; the
    /// *consumer* node is substituted at drain
    Reduce {
        consumer: Dataset,
        key: KeyFn,
        reduce: ReduceFn,
        num_parts: usize,
        accs: HashMap<Field, Row>,
    },
    /// first-seen set for a single `Distinct` consumer, bucketed exactly
    /// like the batch shuffle; the consumer is substituted at drain.
    /// Rows are shared (`Arc`) between the seen-set and the bucket lists
    /// so each distinct row is held once, not twice.
    Distinct {
        consumer: Dataset,
        seen: HashSet<Arc<Row>>,
        buckets: Vec<Vec<Arc<Row>>>,
    },
    /// sorted-run frontier for a single `Sort` consumer: each micro-batch
    /// delta is stably pre-sorted into a governed [`SortedRun`] (spilled
    /// when the budget refuses), and drain k-way merges the runs — the
    /// external merge sort's reduce side — instead of materializing the
    /// whole buffer in memory first. Merging batch-order runs with
    /// run-index tie-breaking equals the stable sort of the arrival-order
    /// concatenation, which is exactly what the batch executor produces.
    /// The consumer is substituted at drain.
    Sort {
        consumer: Dataset,
        cmp: CmpFn,
        runs: SortedRunSet,
    },
}

struct Capture {
    /// the Streaming node whose per-batch delta feeds this state
    node: Dataset,
    state: CapState,
}

/// A compiled streaming query over one template plan.
pub struct StreamQuery {
    root: Dataset,
    source_id: u64,
    source_schema: SchemaRef,
    captures: Vec<Capture>,
    emit_root: bool,
    retain_output: bool,
    emitted: Vec<Row>,
    rows_in: u64,
    rows_out: u64,
    batches: u64,
    finished: bool,
}

impl StreamQuery {
    /// Compile a query from a template plan and the placeholder source
    /// dataset the template was built over.
    pub fn compile(root: &Dataset, source: &Dataset) -> Result<StreamQuery> {
        let source_id = source.id;
        let source_schema = match &*source.node {
            Plan::Source { .. } => source.schema.clone(),
            _ => {
                return Err(DdpError::engine(
                    "streaming placeholder must be a source dataset",
                ))
            }
        };
        let mut classes: HashMap<u64, Class> = HashMap::new();
        let root_class = classify(root, source_id, &mut classes);
        if root_class == Class::Static {
            return Err(DdpError::engine(
                "streaming query never reads the streaming source",
            ));
        }
        // capture edges: Finish consumers of Streaming nodes
        let mut consumers: HashMap<u64, Vec<Dataset>> = HashMap::new();
        let mut snodes: HashMap<u64, Dataset> = HashMap::new();
        let mut visited: HashSet<u64> = HashSet::new();
        collect_edges(root, &classes, &mut consumers, &mut snodes, &mut visited);

        let mut ids: Vec<u64> = consumers.keys().copied().collect();
        ids.sort_unstable();
        let mut captures = Vec::with_capacity(ids.len());
        for id in ids {
            let node = snodes[&id].clone();
            // dedupe consumers (a self-join wires the same node twice)
            let mut uniq: Vec<Dataset> = Vec::new();
            for c in &consumers[&id] {
                if !uniq.iter().any(|u| u.id == c.id) {
                    uniq.push(c.clone());
                }
            }
            let state = if uniq.len() == 1 {
                match &*uniq[0].node {
                    Plan::ReduceByKey { key, reduce, num_parts, .. } => CapState::Reduce {
                        consumer: uniq[0].clone(),
                        key: key.clone(),
                        reduce: reduce.clone(),
                        num_parts: *num_parts,
                        accs: HashMap::new(),
                    },
                    Plan::Distinct { num_parts, .. } => CapState::Distinct {
                        consumer: uniq[0].clone(),
                        seen: HashSet::new(),
                        buckets: (0..*num_parts).map(|_| Vec::new()).collect(),
                    },
                    Plan::Sort { cmp, .. } => CapState::Sort {
                        consumer: uniq[0].clone(),
                        cmp: cmp.clone(),
                        runs: SortedRunSet::new(),
                    },
                    _ => CapState::Raw(SpilledRows::new()),
                }
            } else {
                CapState::Raw(SpilledRows::new())
            };
            captures.push(Capture { node, state });
        }
        let emit_root = root_class == Class::Streaming;
        debug_assert!(!emit_root || captures.is_empty());
        Ok(StreamQuery {
            root: root.clone(),
            source_id,
            source_schema,
            captures,
            emit_root,
            retain_output: true,
            emitted: Vec::new(),
            rows_in: 0,
            rows_out: 0,
            batches: 0,
            finished: false,
        })
    }

    /// Whether per-batch emissions are retained for
    /// [`StreamQuery::finish`] (needed for drain parity; disable for
    /// unbounded append-mode runs whose sink is elsewhere).
    pub fn set_retain_output(&mut self, retain: bool) {
        self.retain_output = retain;
    }

    /// True when the plan is fully stateless (append mode): every batch
    /// emits its delta and drain adds nothing new.
    pub fn is_append_mode(&self) -> bool {
        self.emit_root
    }

    pub fn records_in(&self) -> u64 {
        self.rows_in
    }

    pub fn records_out(&self) -> u64 {
        self.rows_out
    }

    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Rows currently held in cross-batch state (accumulators, dedup
    /// sets, blocked-op buffers) — the quantity backpressure bounds.
    pub fn state_rows(&self) -> usize {
        self.captures
            .iter()
            .map(|c| match &c.state {
                CapState::Raw(v) => v.len_rows(),
                CapState::Reduce { accs, .. } => accs.len(),
                CapState::Distinct { seen, .. } => seen.len(),
                CapState::Sort { runs, .. } => runs.len_rows(),
            })
            .sum()
    }

    /// Process one micro-batch: splice it in as the source, run the
    /// per-batch prefix through the engine, absorb deltas into state,
    /// and return the rows emitted by this batch (append-mode plans
    /// emit; stateful plans emit at drain).
    pub fn push_batch(&mut self, ctx: &EngineCtx, rows: &[Row]) -> Result<Vec<Row>> {
        if self.finished {
            return Err(DdpError::engine("stream query already finished"));
        }
        if rows.is_empty() {
            return Ok(Vec::new());
        }
        self.rows_in += rows.len() as u64;
        self.batches += 1;
        // one micro-batch span scopes this push: the stage/task spans the
        // engine opens below nest under it, and the streaming state
        // charges (spilled buffers, sort runs) attribute to it
        let batch_no = self.batches;
        let span =
            ctx.tracer.begin(SpanKind::MicroBatch, || format!("micro_batch#{batch_no}"), None);
        let _scope = ctx.tracer.scope(span);
        let batch = Partitioned {
            schema: self.source_schema.clone(),
            parts: vec![Arc::new(rows.to_vec())],
        };
        let mut subs: HashMap<u64, Partitioned> = HashMap::new();
        subs.insert(self.source_id, batch);
        let mut memo: HashMap<u64, Dataset> = HashMap::new();
        for cap in self.captures.iter_mut() {
            let rebuilt = substitute(&cap.node, &subs, &mut memo);
            // the template was optimized at compile; skip the per-batch
            // optimizer pass (pure latency, zero rewrites)
            let delta = ctx.collect_unprepared(&rebuilt)?.rows();
            match &mut cap.state {
                CapState::Raw(buf) => {
                    let (spill_bytes, spill_files) =
                        buf.push(&ctx.governor, &ctx.spill, delta)?;
                    if spill_files > 0 {
                        ctx.charge(Stat::SpillBytes, spill_bytes);
                        ctx.charge(Stat::SpillFiles, spill_files);
                    }
                }
                CapState::Reduce { key, reduce, accs, .. } => {
                    let key = key.clone();
                    let reduce = reduce.clone();
                    for r in delta {
                        let k = key(&r);
                        match accs.remove(&k) {
                            Some(acc) => {
                                accs.insert(k, reduce(acc, &r));
                            }
                            None => {
                                accs.insert(k, r);
                            }
                        }
                    }
                }
                CapState::Distinct { seen, buckets, .. } => {
                    let num_parts = buckets.len().max(1);
                    for r in delta {
                        let r = Arc::new(r);
                        if seen.insert(r.clone()) {
                            buckets[distinct_bucket(&r, num_parts)].push(r);
                        }
                    }
                }
                CapState::Sort { cmp, runs, .. } => {
                    if !delta.is_empty() {
                        let cmp = cmp.clone();
                        let mut run_rows = delta;
                        run_rows.sort_by(|a, b| cmp(a, b));
                        let run = SortedRun::build(&ctx.governor, &ctx.spill, run_rows)?;
                        ctx.charge(Stat::SortRuns, 1);
                        if let Some(fb) = run.spilled_file_bytes() {
                            ctx.charge(Stat::SortSpillBytes, fb);
                            ctx.charge(Stat::SpillBytes, fb);
                            ctx.charge(Stat::SpillFiles, 1);
                        }
                        runs.push(run);
                    }
                }
            }
        }
        if self.emit_root {
            let rebuilt = substitute(&self.root, &subs, &mut memo);
            let out = ctx.collect_unprepared(&rebuilt)?.rows();
            self.rows_out += out.len() as u64;
            if self.retain_output {
                self.emitted.extend(out.iter().cloned());
            }
            return Ok(out);
        }
        Ok(Vec::new())
    }

    /// Drain the query: materialize every capture with the batch
    /// executor's exact layout and evaluate the remaining plan suffix.
    /// The result is byte-identical to the one-shot batch run over the
    /// full replayed corpus (see module docs for the contract).
    pub fn finish(&mut self, ctx: &EngineCtx) -> Result<Partitioned> {
        if self.finished {
            return Err(DdpError::engine("stream query already finished"));
        }
        self.finished = true;
        // the drain's merge/suffix work (run merges, capture
        // re-evaluation through the engine) traces as one final span
        let span = ctx.tracer.begin(SpanKind::MicroBatch, || "drain".to_string(), None);
        let _scope = ctx.tracer.scope(span);
        if self.emit_root {
            let rows = std::mem::take(&mut self.emitted);
            return Ok(Partitioned {
                schema: self.root.schema.clone(),
                parts: vec![Arc::new(rows)],
            });
        }
        let mut subs: HashMap<u64, Partitioned> = HashMap::new();
        for cap in self.captures.iter_mut() {
            match &mut cap.state {
                CapState::Raw(buf) => {
                    let rows = buf.drain()?;
                    subs.insert(
                        cap.node.id,
                        Partitioned {
                            schema: cap.node.schema.clone(),
                            parts: vec![Arc::new(rows)],
                        },
                    );
                }
                CapState::Reduce { consumer, num_parts, accs, .. } => {
                    let num_parts = (*num_parts).max(1);
                    let mut buckets: Vec<Vec<(Field, Row)>> =
                        (0..num_parts).map(|_| Vec::new()).collect();
                    for (k, r) in accs.drain() {
                        let b = bucket_of(&k, num_parts);
                        buckets[b].push((k, r));
                    }
                    let parts = buckets
                        .into_iter()
                        .map(|mut b| {
                            // canonical key order, matching the batch
                            // executor's reduce-side emission
                            b.sort_by(|x, y| x.0.canonical_cmp(&y.0));
                            Arc::new(b.into_iter().map(|(_, r)| r).collect::<Vec<Row>>())
                        })
                        .collect();
                    subs.insert(
                        consumer.id,
                        Partitioned { schema: consumer.schema.clone(), parts },
                    );
                }
                CapState::Distinct { consumer, buckets, .. } => {
                    let parts = std::mem::take(buckets)
                        .into_iter()
                        .map(|b| {
                            Arc::new(b.into_iter().map(|r| (*r).clone()).collect::<Vec<Row>>())
                        })
                        .collect();
                    subs.insert(
                        consumer.id,
                        Partitioned { schema: consumer.schema.clone(), parts },
                    );
                }
                CapState::Sort { consumer, cmp, runs } => {
                    // the external merge sort's reduce side, run in place:
                    // spilled runs stream back chunk-at-a-time, so drain
                    // memory stays governed instead of materializing the
                    // whole buffer before sorting
                    let cmp = cmp.clone();
                    let rows = std::mem::take(runs).merge(&ctx.governor, &*cmp)?;
                    subs.insert(
                        consumer.id,
                        Partitioned {
                            schema: consumer.schema.clone(),
                            parts: vec![Arc::new(rows)],
                        },
                    );
                }
            }
        }
        let mut memo: HashMap<u64, Dataset> = HashMap::new();
        let rebuilt = substitute(&self.root, &subs, &mut memo);
        let out = ctx.collect_unprepared(&rebuilt)?;
        self.rows_out += out.num_rows() as u64;
        Ok(out)
    }
}

/// Batch-identical bucket for a distinct row: the executor's own
/// whole-row shuffle key, routed through the executor's single bucket
/// definition (`bucket_of`) so stream drains and batch output agree.
fn distinct_bucket(r: &Row, num_parts: usize) -> usize {
    bucket_of(&whole_row_key(r), num_parts)
}

fn classify(ds: &Dataset, source_id: u64, memo: &mut HashMap<u64, Class>) -> Class {
    if let Some(c) = memo.get(&ds.id) {
        return *c;
    }
    let c = match &*ds.node {
        Plan::Source { .. } => {
            if ds.id == source_id {
                Class::Streaming
            } else {
                Class::Static
            }
        }
        Plan::Map { input, .. }
        | Plan::Filter { input, .. }
        | Plan::FilterExpr { input, .. }
        | Plan::Project { input, .. }
        | Plan::FlatMap { input, .. }
        | Plan::MapPartitions { input, .. } => classify(input, source_id, memo),
        Plan::ReduceByKey { input, .. }
        | Plan::Distinct { input, .. }
        | Plan::Sort { input, .. }
        | Plan::Repartition { input, .. } => match classify(input, source_id, memo) {
            Class::Static => Class::Static,
            _ => Class::Finish,
        },
        Plan::Join { left, right, .. } => {
            let l = classify(left, source_id, memo);
            let r = classify(right, source_id, memo);
            if l == Class::Static && r == Class::Static {
                Class::Static
            } else {
                Class::Finish
            }
        }
        // union interleaves branch deltas if streamed through, which
        // would break append-order parity — treat it as a stateful
        // barrier whenever a streaming branch feeds it
        Plan::Union { inputs } => {
            let cs: Vec<Class> = inputs
                .iter()
                .map(|i| classify(i, source_id, memo))
                .collect();
            if cs.iter().all(|c| *c == Class::Static) {
                Class::Static
            } else {
                Class::Finish
            }
        }
    };
    memo.insert(ds.id, c);
    c
}

fn collect_edges(
    ds: &Dataset,
    classes: &HashMap<u64, Class>,
    consumers: &mut HashMap<u64, Vec<Dataset>>,
    snodes: &mut HashMap<u64, Dataset>,
    visited: &mut HashSet<u64>,
) {
    if !visited.insert(ds.id) {
        return;
    }
    let my_class = classes.get(&ds.id).copied().unwrap_or(Class::Static);
    for input in ds.inputs() {
        if my_class == Class::Finish
            && classes.get(&input.id).copied() == Some(Class::Streaming)
        {
            consumers.entry(input.id).or_default().push(ds.clone());
            snodes.entry(input.id).or_insert_with(|| input.clone());
        }
        collect_edges(&input, classes, consumers, snodes, visited);
    }
}

/// Clone the template with `subs` node ids replaced by materialized
/// sources; keeps original handles (and ids) where nothing changed, so
/// static subtrees keep their identity across batches.
fn substitute(
    ds: &Dataset,
    subs: &HashMap<u64, Partitioned>,
    memo: &mut HashMap<u64, Dataset>,
) -> Dataset {
    if let Some(done) = memo.get(&ds.id) {
        return done.clone();
    }
    let out = if let Some(data) = subs.get(&ds.id) {
        Dataset::with_node(
            Plan::Source { name: format!("stream:{}", ds.name()), data: data.clone() },
            ds.schema.clone(),
        )
    } else {
        rebuild_children(ds, subs, memo)
    };
    memo.insert(ds.id, out.clone());
    out
}

fn rebuild_children(
    ds: &Dataset,
    subs: &HashMap<u64, Partitioned>,
    memo: &mut HashMap<u64, Dataset>,
) -> Dataset {
    let node = match &*ds.node {
        Plan::Source { .. } => return ds.clone(),
        Plan::Map { input, f, schema } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Map { input: ni, f: f.clone(), schema: schema.clone() }
        }
        Plan::Filter { input, f } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Filter { input: ni, f: f.clone() }
        }
        Plan::FilterExpr { input, expr } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::FilterExpr { input: ni, expr: expr.clone() }
        }
        Plan::Project { input, cols, schema } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Project { input: ni, cols: cols.clone(), schema: schema.clone() }
        }
        Plan::FlatMap { input, f, schema } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::FlatMap { input: ni, f: f.clone(), schema: schema.clone() }
        }
        Plan::MapPartitions { input, f, schema } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::MapPartitions { input: ni, f: f.clone(), schema: schema.clone() }
        }
        Plan::ReduceByKey { input, key, reduce, num_parts, key_col } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::ReduceByKey {
                input: ni,
                key: key.clone(),
                reduce: reduce.clone(),
                num_parts: *num_parts,
                key_col: *key_col,
            }
        }
        Plan::Distinct { input, num_parts } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Distinct { input: ni, num_parts: *num_parts }
        }
        Plan::Sort { input, cmp } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Sort { input: ni, cmp: cmp.clone() }
        }
        Plan::Repartition { input, num_parts } => {
            let ni = substitute(input, subs, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Repartition { input: ni, num_parts: *num_parts }
        }
        Plan::Join { left, right, lkey, rkey, kind, num_parts, schema, lkey_col, rkey_col } => {
            let nl = substitute(left, subs, memo);
            let nr = substitute(right, subs, memo);
            if nl.id == left.id && nr.id == right.id {
                return ds.clone();
            }
            Plan::Join {
                left: nl,
                right: nr,
                lkey: lkey.clone(),
                rkey: rkey.clone(),
                kind: *kind,
                num_parts: *num_parts,
                schema: schema.clone(),
                lkey_col: *lkey_col,
                rkey_col: *rkey_col,
            }
        }
        Plan::Union { inputs } => {
            let nis: Vec<Dataset> = inputs
                .iter()
                .map(|i| substitute(i, subs, memo))
                .collect();
            if nis.iter().zip(inputs.iter()).all(|(a, b)| a.id == b.id) {
                return ds.clone();
            }
            Plan::Union { inputs: nis }
        }
    };
    Dataset::with_node(node, ds.schema.clone())
}

/// Engine-layer streaming context: owns the engine handle and a compiled
/// query, optimizing the template once (honouring
/// [`super::super::executor::EngineConfig::optimize`]) before
/// segmentation — "the existing optimized Plan DAG, once per micro-batch".
pub struct StreamingCtx {
    pub engine: Arc<EngineCtx>,
    query: StreamQuery,
}

impl StreamingCtx {
    /// Compile a streaming context over `root`, a template plan reading
    /// the placeholder `source` dataset.
    pub fn new(engine: Arc<EngineCtx>, root: &Dataset, source: &Dataset) -> Result<StreamingCtx> {
        let optimized = if engine.cfg.optimize {
            optimizer::optimize(root, &|id| engine.cache.is_registered(id)).plan
        } else {
            root.clone()
        };
        let query = StreamQuery::compile(&optimized, source)?;
        Ok(StreamingCtx { engine, query })
    }

    pub fn set_retain_output(&mut self, retain: bool) {
        self.query.set_retain_output(retain);
    }

    pub fn is_append_mode(&self) -> bool {
        self.query.is_append_mode()
    }

    pub fn records_in(&self) -> u64 {
        self.query.records_in()
    }

    pub fn records_out(&self) -> u64 {
        self.query.records_out()
    }

    pub fn batches(&self) -> u64 {
        self.query.batches()
    }

    pub fn state_rows(&self) -> usize {
        self.query.state_rows()
    }

    /// Drive one micro-batch through the plan.
    pub fn push_batch(&mut self, rows: &[Row]) -> Result<Vec<Row>> {
        self.query.push_batch(&self.engine, rows)
    }

    /// Drain: final output, byte-identical to the batch run.
    pub fn finish(&mut self) -> Result<Partitioned> {
        self.query.finish(&self.engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::executor::EngineConfig;
    use crate::engine::row::{FieldType, Schema};
    use crate::row;

    fn engine() -> Arc<EngineCtx> {
        EngineCtx::new(EngineConfig { workers: 2, ..Default::default() })
    }

    fn kv_schema() -> SchemaRef {
        Schema::new(vec![("k", FieldType::I64), ("v", FieldType::I64)])
    }

    fn kv_rows(n: i64) -> Vec<Row> {
        (0..n).map(|i| row!(i % 7, i)).collect()
    }

    fn placeholder() -> Dataset {
        Dataset::from_rows("src", kv_schema(), Vec::new(), 1)
    }

    /// layout = partition structure, the strongest equality.
    fn layout(p: &Partitioned) -> Vec<Vec<Row>> {
        p.parts.iter().map(|part| (**part).clone()).collect()
    }

    fn stream_all(root: &Dataset, src: &Dataset, rows: &[Row], batch: usize) -> Partitioned {
        let mut sc = StreamingCtx::new(engine(), root, src).unwrap();
        for chunk in rows.chunks(batch.max(1)) {
            sc.push_batch(chunk).unwrap();
        }
        sc.finish().unwrap()
    }

    fn double(r: &Row) -> Row {
        row!(r.get(0).as_i64().unwrap(), r.get(1).as_i64().unwrap() * 2)
    }

    fn sum_v(acc: Row, r: &Row) -> Row {
        row!(
            acc.get(0).as_i64().unwrap(),
            acc.get(1).as_i64().unwrap() + r.get(1).as_i64().unwrap()
        )
    }

    fn max_v(acc: Row, r: &Row) -> Row {
        row!(
            acc.get(0).as_i64().unwrap(),
            acc.get(1).as_i64().unwrap().max(r.get(1).as_i64().unwrap())
        )
    }

    #[test]
    fn stateless_plan_streams_append_mode() {
        let src = placeholder();
        let plan = src
            .map(src.schema.clone(), double)
            .filter(|r| r.get(1).as_i64().unwrap() % 3 != 0);
        let rows = kv_rows(50);
        let mut sc = StreamingCtx::new(engine(), &plan, &src).unwrap();
        assert!(sc.is_append_mode());
        let mut emitted = Vec::new();
        for chunk in rows.chunks(8) {
            emitted.extend(sc.push_batch(chunk).unwrap());
        }
        let fin = sc.finish().unwrap();
        assert_eq!(fin.rows(), emitted, "drain replays the retained emissions");

        // batch reference over the same rows
        let batch_src = Dataset::from_rows("src", kv_schema(), rows, 4);
        let batch_plan = batch_src
            .map(batch_src.schema.clone(), double)
            .filter(|r| r.get(1).as_i64().unwrap() % 3 != 0);
        let want = engine().collect(&batch_plan).unwrap().rows();
        assert_eq!(emitted, want);
    }

    #[test]
    fn incremental_reduce_matches_batch_layout() {
        let src = placeholder();
        let plan = src.reduce_by_key_col(4, 0, sum_v);
        let rows = kv_rows(100);
        for batch in [1usize, 13, 100] {
            let got = stream_all(&plan, &src, &rows, batch);
            let batch_src = Dataset::from_rows("src", kv_schema(), rows.clone(), 5);
            let batch_plan = batch_src.reduce_by_key_col(4, 0, sum_v);
            let want = engine().collect(&batch_plan).unwrap();
            assert_eq!(layout(&got), layout(&want), "batch size {batch}");
        }
    }

    #[test]
    fn incremental_distinct_matches_batch_layout() {
        let src = placeholder();
        let plan = src.distinct(3);
        let rows: Vec<Row> = (0..120).map(|i| row!(i % 11, i % 4)).collect();
        for batch in [1usize, 17, 120] {
            let got = stream_all(&plan, &src, &rows, batch);
            let batch_src = Dataset::from_rows("src", kv_schema(), rows.clone(), 6);
            let want = engine().collect(&batch_src.distinct(3)).unwrap();
            assert_eq!(layout(&got), layout(&want), "batch size {batch}");
        }
    }

    #[test]
    fn sort_and_suffix_above_reduce_match_batch() {
        // narrow → reduce (incremental) → filter → sort: the suffix above
        // the frontier runs through the batch executor at drain
        fn bump(r: &Row) -> Row {
            row!(r.get(0).as_i64().unwrap(), r.get(1).as_i64().unwrap() + 1)
        }
        let build = |src: &Dataset| {
            src.map(src.schema.clone(), bump)
                .reduce_by_key_col(3, 0, max_v)
                .filter(|r| r.get(0).as_i64().unwrap() != 2)
                .sort_by(|a, b| a.get(1).as_i64().unwrap().cmp(&b.get(1).as_i64().unwrap()))
        };
        let src = placeholder();
        let plan = build(&src);
        let rows = kv_rows(90);
        let got = stream_all(&plan, &src, &rows, 7);
        let batch_src = Dataset::from_rows("src", kv_schema(), rows, 4);
        let want = engine().collect(&build(&batch_src)).unwrap();
        assert_eq!(layout(&got), layout(&want));
    }

    #[test]
    fn join_with_static_side_matches_batch() {
        let dim_schema = Schema::new(vec![("k2", FieldType::I64), ("label", FieldType::Str)]);
        let dim_rows: Vec<Row> = (0..7).map(|i| row!(i, format!("g{i}"))).collect();
        use crate::engine::dataset::JoinKind;
        let out_schema = Schema::of_names(&["k", "v", "k2", "label"]);
        let build = |src: &Dataset, dim: &Dataset| {
            src.join_on(dim, out_schema.clone(), JoinKind::Inner, 3, 0, 0)
        };
        let src = placeholder();
        let dim = Dataset::from_rows("dim", dim_schema.clone(), dim_rows.clone(), 2);
        let plan = build(&src, &dim);
        let rows = kv_rows(60);
        let got = stream_all(&plan, &src, &rows, 9);
        let batch_src = Dataset::from_rows("src", kv_schema(), rows, 4);
        let batch_dim = Dataset::from_rows("dim", dim_schema, dim_rows, 2);
        let want = engine().collect(&build(&batch_src, &batch_dim)).unwrap();
        assert_eq!(layout(&got), layout(&want));
    }

    #[test]
    fn state_stays_bounded_for_incremental_ops() {
        let src = placeholder();
        let plan = src.reduce_by_key_col(2, 0, |acc: Row, _r: &Row| acc);
        let mut sc = StreamingCtx::new(engine(), &plan, &src).unwrap();
        let rows = kv_rows(500); // keys 0..7 only
        for chunk in rows.chunks(50) {
            sc.push_batch(chunk).unwrap();
        }
        assert_eq!(sc.state_rows(), 7, "one accumulator per key, not per row");
        assert_eq!(sc.records_in(), 500);
        sc.finish().unwrap();
    }

    #[test]
    fn finish_is_terminal() {
        let src = placeholder();
        let plan = src.filter(|_| true);
        let mut sc = StreamingCtx::new(engine(), &plan, &src).unwrap();
        sc.push_batch(&kv_rows(3)).unwrap();
        sc.finish().unwrap();
        assert!(sc.push_batch(&kv_rows(3)).is_err());
        assert!(sc.finish().is_err());
    }

    fn by_v(a: &Row, b: &Row) -> std::cmp::Ordering {
        a.get(1).as_i64().unwrap().cmp(&b.get(1).as_i64().unwrap())
    }

    #[test]
    fn raw_capture_spills_under_tiny_budget_and_stays_byte_identical() {
        // a Repartition consumer takes the raw-capture path; a
        // few-hundred-byte budget forces the buffer onto disk chunk by
        // chunk
        let eng = EngineCtx::new(EngineConfig {
            workers: 2,
            memory_budget_bytes: Some(512),
            ..Default::default()
        });
        let gov = eng.governor.clone();
        let src = placeholder();
        let plan = src.repartition(3);
        let rows = kv_rows(200);
        let mut sc = StreamingCtx::new(eng, &plan, &src).unwrap();
        for chunk in rows.chunks(9) {
            sc.push_batch(chunk).unwrap();
        }
        let got = sc.finish().unwrap();
        let snap = sc.engine.stats.snapshot();
        assert!(snap.spill_bytes > 0, "tiny budget must spill the raw buffer");
        assert!(snap.spill_files > 0);

        let batch_src = Dataset::from_rows("src", kv_schema(), rows, 4);
        let want = engine().collect(&batch_src.repartition(3)).unwrap();
        assert_eq!(layout(&got), layout(&want), "spilled drain is byte-identical");
        drop(sc);
        assert_eq!(gov.reserved_bytes(), 0, "no reservation leak after drop");
    }

    #[test]
    fn sort_frontier_merges_runs_and_spills_under_tiny_budget() {
        // a Sort consumer takes the sorted-run frontier: per-batch runs
        // (spilled under the tiny budget) k-way merged at drain, never
        // materializing the whole buffer unsorted
        let eng = EngineCtx::new(EngineConfig {
            workers: 2,
            memory_budget_bytes: Some(512),
            ..Default::default()
        });
        let gov = eng.governor.clone();
        let src = placeholder();
        let plan = src.sort_by(by_v);
        let rows = kv_rows(200);
        let mut sc = StreamingCtx::new(eng, &plan, &src).unwrap();
        for chunk in rows.chunks(9) {
            sc.push_batch(chunk).unwrap();
        }
        assert_eq!(sc.state_rows(), 200, "sort frontier accounts its buffered rows");
        let got = sc.finish().unwrap();
        let snap = sc.engine.stats.snapshot();
        assert!(snap.sort_runs > 0, "each micro-batch contributes a run");
        assert!(snap.sort_spill_bytes > 0, "tiny budget must spill sort runs");
        assert!(snap.spill_bytes >= snap.sort_spill_bytes);

        let batch_src = Dataset::from_rows("src", kv_schema(), rows, 4);
        let want = engine().collect(&batch_src.sort_by(by_v)).unwrap();
        assert_eq!(layout(&got), layout(&want), "spilled merge drain is byte-identical");
        drop(sc);
        assert_eq!(gov.reserved_bytes(), 0, "no reservation leak after drop");
    }

    #[test]
    fn dropping_unfinished_query_releases_reservations() {
        let eng = EngineCtx::new(EngineConfig { workers: 2, ..Default::default() });
        let gov = eng.governor.clone();
        let src = placeholder();
        let plan = src.sort_by(by_v);
        let mut sc = StreamingCtx::new(eng, &plan, &src).unwrap();
        for chunk in kv_rows(300).chunks(50) {
            sc.push_batch(chunk).unwrap();
        }
        assert!(gov.reserved_bytes() > 0, "raw buffer holds a live reservation");
        drop(sc);
        assert_eq!(gov.reserved_bytes(), 0, "drop releases without finish()");
    }

    #[test]
    fn static_only_plan_rejected() {
        let src = placeholder();
        let other = Dataset::from_rows("other", kv_schema(), kv_rows(5), 1);
        let plan = other.filter(|_| true);
        assert!(StreamingCtx::new(engine(), &plan, &src).is_err());
    }
}
