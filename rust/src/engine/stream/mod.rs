//! Micro-batch streaming runtime — continuous execution over the same
//! declarative Plan DAG the batch engine runs (tf.data-style: one
//! operator graph, two drivers).
//!
//! * [`source`] — replayable corpus-backed and rate-limited row sources;
//! * [`query`] — [`StreamQuery`]/[`StreamingCtx`]: splice each
//!   micro-batch into a compiled template plan, run the per-batch prefix
//!   through the existing optimizer + executor, fold wide operators into
//!   cross-batch state, and drain to output **byte-identical** to the
//!   one-shot batch run;
//! * [`window`] — event-time tumbling windows with watermarks (the
//!   streaming-native operator set: windowed aggregation, streaming
//!   dedup keyed on content hash);
//! * [`backpressure`] — bounded ingest queue + AIMD batch sizing that
//!   keeps steady-state per-batch latency under a target.
//!
//! The `ddp`-layer [`crate::ddp::streaming::StreamingDriver`] builds on
//! this so declaratively configured Pipes run unmodified in a continuous
//! loop.

pub mod backpressure;
pub mod query;
pub mod source;
pub mod window;

pub use backpressure::{BackpressureController, BoundedRowQueue};
pub use query::{StreamQuery, StreamingCtx};
pub use source::{CorpusSource, RateLimitedSource, StreamSource};
pub use window::{StreamingDedup, TumblingWindow, WatermarkTracker, WindowAgg};
