//! Backpressure: a bounded ingest queue plus an AIMD micro-batch sizer.
//!
//! The streaming loop is pull-based, so backpressure is structural: the
//! source is only polled for as many rows as the bounded queue has free,
//! which caps in-flight memory no matter how fast the source produces.
//! What *adapts* is the micro-batch size — an AIMD controller (the same
//! shape TCP congestion control and tf.data's autotuning use) grows the
//! batch while per-batch latency is comfortably under target and halves
//! it when a batch overshoots, so steady-state latency converges below
//! the target without starving throughput.

use super::super::row::Row;
use std::collections::VecDeque;

/// Bounded FIFO of pending rows between the source and the pipeline.
pub struct BoundedRowQueue {
    cap: usize,
    q: VecDeque<Row>,
    max_depth: usize,
}

impl BoundedRowQueue {
    pub fn new(cap_rows: usize) -> BoundedRowQueue {
        BoundedRowQueue { cap: cap_rows.max(1), q: VecDeque::new(), max_depth: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() >= self.cap
    }

    /// Free row slots (what the source may be polled for).
    pub fn free(&self) -> usize {
        self.cap.saturating_sub(self.q.len())
    }

    /// Enqueue rows; panics if the caller overfills (the driver polls
    /// the source for at most [`BoundedRowQueue::free`] rows).
    pub fn push(&mut self, rows: Vec<Row>) {
        assert!(
            self.q.len() + rows.len() <= self.cap,
            "bounded queue overfilled ({} + {} > {})",
            self.q.len(),
            rows.len(),
            self.cap
        );
        self.q.extend(rows);
        self.max_depth = self.max_depth.max(self.q.len());
    }

    /// Dequeue up to `n` rows in FIFO order.
    pub fn take(&mut self, n: usize) -> Vec<Row> {
        let k = n.min(self.q.len());
        self.q.drain(..k).collect()
    }

    /// High-water mark over the queue's lifetime (the bounded-memory
    /// evidence the backpressure tests assert on).
    pub fn max_depth(&self) -> usize {
        self.max_depth
    }
}

/// AIMD micro-batch sizer targeting a per-batch latency.
#[derive(Debug, Clone, Copy)]
pub struct BackpressureController {
    pub target_latency_secs: f64,
    min_rows: usize,
    max_rows: usize,
    cur: usize,
    shrinks: u64,
    grows: u64,
}

impl BackpressureController {
    pub fn new(
        target_latency_secs: f64,
        min_rows: usize,
        max_rows: usize,
        initial_rows: usize,
    ) -> BackpressureController {
        let min_rows = min_rows.max(1);
        let max_rows = max_rows.max(min_rows);
        BackpressureController {
            target_latency_secs: target_latency_secs.max(1e-6),
            min_rows,
            max_rows,
            cur: initial_rows.clamp(min_rows, max_rows),
            shrinks: 0,
            grows: 0,
        }
    }

    /// Rows to take for the next micro-batch.
    pub fn batch_rows(&self) -> usize {
        self.cur
    }

    /// Feed back the latency of the batch just processed: multiplicative
    /// decrease on overshoot, additive increase while well under target.
    pub fn observe(&mut self, latency_secs: f64) {
        if latency_secs > self.target_latency_secs {
            let next = (self.cur / 2).max(self.min_rows);
            if next < self.cur {
                self.shrinks += 1;
            }
            self.cur = next;
        } else if latency_secs < 0.5 * self.target_latency_secs {
            let next = (self.cur + (self.cur / 4).max(1)).min(self.max_rows);
            if next > self.cur {
                self.grows += 1;
            }
            self.cur = next;
        }
    }

    pub fn shrinks(&self) -> u64 {
        self.shrinks
    }

    pub fn grows(&self) -> u64 {
        self.grows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;

    fn rows(n: i64) -> Vec<Row> {
        (0..n).map(|i| row!(i)).collect()
    }

    #[test]
    fn queue_bounds_and_fifo() {
        let mut q = BoundedRowQueue::new(10);
        q.push(rows(6));
        assert_eq!(q.free(), 4);
        q.push(rows(4));
        assert!(q.is_full());
        assert_eq!(q.free(), 0);
        let got = q.take(3);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].get(0).as_i64(), Some(0), "FIFO order");
        assert_eq!(q.len(), 7);
        assert_eq!(q.max_depth(), 10);
        q.take(100);
        assert!(q.is_empty());
        assert_eq!(q.max_depth(), 10, "high-water mark sticks");
    }

    #[test]
    #[should_panic(expected = "overfilled")]
    fn queue_rejects_overfill() {
        let mut q = BoundedRowQueue::new(4);
        q.push(rows(5));
    }

    #[test]
    fn controller_shrinks_on_overshoot_and_grows_when_idle() {
        let mut c = BackpressureController::new(0.1, 8, 1024, 256);
        c.observe(0.5); // way over target -> halve
        assert_eq!(c.batch_rows(), 128);
        c.observe(0.2);
        assert_eq!(c.batch_rows(), 64);
        // fast batches -> additive growth, never past max
        for _ in 0..100 {
            c.observe(0.01);
        }
        assert_eq!(c.batch_rows(), 1024);
        assert!(c.shrinks() >= 2 && c.grows() > 0);
        // floor respected
        for _ in 0..100 {
            c.observe(1.0);
        }
        assert_eq!(c.batch_rows(), 8);
    }

    #[test]
    fn controller_holds_steady_in_band() {
        let mut c = BackpressureController::new(0.1, 1, 1000, 100);
        // between 50% and 100% of target: no change
        c.observe(0.07);
        c.observe(0.09);
        assert_eq!(c.batch_rows(), 100);
    }

    #[test]
    fn controller_pinned_when_min_equals_max() {
        let mut c = BackpressureController::new(0.1, 64, 64, 64);
        for lat in [10.0, 0.0, 0.5, 0.001, 2.0] {
            c.observe(lat);
            assert_eq!(c.batch_rows(), 64, "degenerate band must pin the batch size");
        }
        assert_eq!(c.shrinks(), 0, "clamped halving is not a shrink");
        assert_eq!(c.grows(), 0, "clamped growth is not a grow");
    }

    #[test]
    fn controller_survives_zero_and_negative_latency() {
        let mut c = BackpressureController::new(0.1, 8, 512, 256);
        // a zero-duration batch (timer resolution) reads as "fast": grow
        c.observe(0.0);
        assert!(c.batch_rows() > 256);
        // negative latency (clock skew) must not panic or shrink
        let before = c.batch_rows();
        c.observe(-1.0);
        assert!(c.batch_rows() >= before);
        assert!((8..=512).contains(&c.batch_rows()));
        assert_eq!(c.shrinks(), 0);
    }

    #[test]
    fn controller_clamps_degenerate_construction() {
        // zero/min>max/zero-target inputs normalize instead of panicking
        let c = BackpressureController::new(0.0, 0, 0, 0);
        assert_eq!(c.batch_rows(), 1, "floors clamp to 1");
        let mut c = BackpressureController::new(-5.0, 100, 10, 1000);
        // max clamps up to min, initial clamps into [min, max]
        assert_eq!(c.batch_rows(), 100);
        c.observe(1.0);
        assert_eq!(c.batch_rows(), 100, "collapsed band stays pinned");
    }

    #[test]
    fn queue_zero_capacity_clamps_to_one() {
        let mut q = BoundedRowQueue::new(0);
        assert_eq!(q.capacity(), 1, "zero capacity would deadlock the poll loop");
        assert_eq!(q.free(), 1);
        q.push(rows(1));
        assert!(q.is_full());
        assert_eq!(q.take(5).len(), 1);
        assert!(q.is_empty());
    }
}
