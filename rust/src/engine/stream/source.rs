//! Streaming sources: where micro-batch rows come from.
//!
//! A [`StreamSource`] is polled by the streaming loop for up to
//! `max_rows` rows per call. Sources are deliberately synchronous and
//! deterministic — arrival order is the contract the batch-vs-stream
//! differential proof rests on, so there is no background thread and no
//! wall-clock coupling here:
//!
//! * [`CorpusSource`] — replayable, backed by an in-memory corpus; yields
//!   rows in corpus order and can [`CorpusSource::reset`] for replay runs
//!   (the differential test replays the same corpus at several batch
//!   sizes);
//! * [`RateLimitedSource`] — wraps any source with a per-poll row quota,
//!   modelling an arrival rate in scheduler ticks (deterministic, unlike
//!   sleeping on a wall clock). Setting the quota above the consumer's
//!   queue capacity is how the backpressure tests make the source
//!   outpace the pipeline.

use crate::engine::row::{Row, SchemaRef};

/// A pull-based row stream.
pub trait StreamSource {
    /// Schema of every produced row.
    fn schema(&self) -> SchemaRef;

    /// Up to `max_rows` next rows. `None` = exhausted (end of stream);
    /// `Some(vec![])` = nothing available *this* poll, more may come —
    /// the driver re-polls immediately, so unbounded sources should
    /// return rows or `None` rather than empty batches in a tight loop.
    fn next_batch(&mut self, max_rows: usize) -> Option<Vec<Row>>;
}

/// Replayable corpus-backed source.
pub struct CorpusSource {
    schema: SchemaRef,
    rows: Vec<Row>,
    pos: usize,
}

impl CorpusSource {
    pub fn new(schema: SchemaRef, rows: Vec<Row>) -> CorpusSource {
        CorpusSource { schema, rows, pos: 0 }
    }

    /// Rewind to the start of the corpus (replay).
    pub fn reset(&mut self) {
        self.pos = 0;
    }

    pub fn remaining(&self) -> usize {
        self.rows.len() - self.pos
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

impl StreamSource for CorpusSource {
    fn schema(&self) -> SchemaRef {
        self.schema.clone()
    }

    fn next_batch(&mut self, max_rows: usize) -> Option<Vec<Row>> {
        if self.pos >= self.rows.len() {
            return None;
        }
        let end = (self.pos + max_rows.max(1)).min(self.rows.len());
        let out = self.rows[self.pos..end].to_vec();
        self.pos = end;
        Some(out)
    }
}

/// Per-poll rate limit over an inner source.
pub struct RateLimitedSource<S: StreamSource> {
    inner: S,
    /// max rows handed out per poll ("arrival rate per scheduler tick")
    pub rows_per_poll: usize,
    polls: u64,
}

impl<S: StreamSource> RateLimitedSource<S> {
    pub fn new(inner: S, rows_per_poll: usize) -> RateLimitedSource<S> {
        RateLimitedSource { inner, rows_per_poll: rows_per_poll.max(1), polls: 0 }
    }

    pub fn polls(&self) -> u64 {
        self.polls
    }
}

impl<S: StreamSource> StreamSource for RateLimitedSource<S> {
    fn schema(&self) -> SchemaRef {
        self.inner.schema()
    }

    fn next_batch(&mut self, max_rows: usize) -> Option<Vec<Row>> {
        self.polls += 1;
        self.inner.next_batch(max_rows.min(self.rows_per_poll))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::Schema;
    use crate::row;

    fn nums(n: i64) -> CorpusSource {
        let schema = Schema::of_names(&["x"]);
        CorpusSource::new(schema, (0..n).map(|i| row!(i)).collect())
    }

    #[test]
    fn corpus_yields_in_order_then_exhausts() {
        let mut s = nums(5);
        assert_eq!(s.next_batch(2).unwrap().len(), 2);
        assert_eq!(s.remaining(), 3);
        assert_eq!(s.next_batch(10).unwrap().len(), 3);
        assert!(s.next_batch(1).is_none());
        s.reset();
        let all = s.next_batch(100).unwrap();
        assert_eq!(all.len(), 5);
        assert_eq!(all[0].get(0).as_i64(), Some(0));
        assert_eq!(all[4].get(0).as_i64(), Some(4));
    }

    #[test]
    fn rate_limit_caps_per_poll() {
        let mut s = RateLimitedSource::new(nums(10), 3);
        assert_eq!(s.next_batch(100).unwrap().len(), 3);
        assert_eq!(s.next_batch(2).unwrap().len(), 2, "caller cap still applies");
        assert!(s.polls() == 2);
        // drain
        let mut total = 5;
        while let Some(rows) = s.next_batch(100) {
            total += rows.len();
        }
        assert_eq!(total, 10);
    }
}
