//! Disk spill for out-of-core execution.
//!
//! When a [`super::memory::MemoryGovernor`] reservation fails, bulky
//! intermediate state moves to disk instead of staying resident:
//!
//! * **Shuffle buckets** — the map side of every wide operator
//!   (reduce/distinct/join/repartition) produces per-partition hash
//!   buckets. A [`BucketSet`] holds them in memory under a reservation —
//!   as rows, or as [`ColumnBatch`]es when a column-keyed wide operator
//!   bucketed batch-native — or as one [`SpillFile`] whose per-bucket
//!   segments are merge-read back on the reduce side, one bucket at a
//!   time, in the exact input partition order the in-memory path uses —
//!   so collected output is byte-identical with spilling forced on or
//!   off, and with batch transport on or off.
//! * **Sorted runs** — the external merge sort's map side pre-sorts each
//!   partition (or micro-batch delta) into a [`SortedRun`]: resident
//!   under a reservation, or spilled as [`RUN_CHUNK_ROWS`]-row colbin
//!   segments. A [`SortedRunSet`] then streams a k-way merge over run
//!   cursors (heap keyed by the user comparator, ties broken by run
//!   index) with bounded read-ahead — byte-identical to a driver-side
//!   stable gather-sort at any budget.
//! * **Streaming blocking-op buffers** — [`SpilledRows`] is the
//!   arrival-order buffer behind raw capture points in
//!   [`super::stream::query`]: an in-memory tail under a growable
//!   reservation, flushed to spill chunks whenever the governor refuses
//!   growth, drained back in arrival order.
//!
//! Spill blobs are the repo's own columnar format ([`crate::io::colbin`])
//! under an all-`Any` schema: every value carries its own type tag, so
//! rows round-trip exactly (including `F64` bit patterns) regardless of
//! how loosely the logical schema was declared. Files live in a unique
//! per-context directory and are deleted as soon as their handle drops;
//! the directory itself is removed when its last holder — the context
//! or any still-live spill handle — goes away.

use super::memory::{MemoryGovernor, MemoryReservation};
use super::row::{ColumnBatch, Field, FieldType, Row, Schema, SchemaRef};
use crate::io::colbin;
use crate::util::error::{DdpError, Result};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Process-wide sequence so every context's spill dir is unique.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// A per-context spill directory: created lazily on first spill, unique
/// under the configured base (or the system temp dir), removed on drop.
#[derive(Debug)]
pub struct SpillDir {
    path: PathBuf,
    counter: AtomicU64,
}

impl SpillDir {
    pub fn new(base: Option<PathBuf>) -> SpillDir {
        let root = base.unwrap_or_else(std::env::temp_dir);
        let path = root.join(format!(
            "ddp-spill-{}-{}",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        SpillDir { path, counter: AtomicU64::new(0) }
    }

    pub fn path(&self) -> &PathBuf {
        &self.path
    }

    fn next_path(&self) -> Result<PathBuf> {
        // idempotent; first spill creates the directory
        std::fs::create_dir_all(&self.path)?;
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        Ok(self.path.join(format!("spill-{n:06}.colbin")))
    }

    /// Spill files written over this directory's lifetime.
    pub fn files_written(&self) -> u64 {
        self.counter.load(Ordering::Relaxed)
    }
}

impl Drop for SpillDir {
    fn drop(&mut self) {
        // best effort; never created = nothing to remove
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Self-describing spill schema: `width` columns, all `Any` (per-value
/// type tags in colbin v2 make the round-trip exact).
fn spill_schema(width: usize) -> SchemaRef {
    let names: Vec<String> = (0..width).map(|i| format!("c{i}")).collect();
    Schema::new(names.iter().map(|n| (n.as_str(), FieldType::Any)).collect())
}

/// Encode rows into one colbin blob: blob bytes, segment width, and
/// per-row true widths when the bucket was ragged. The single row
/// encoder behind both transports — spill segments on disk
/// ([`SpillFile`]) and shuffle payloads on the wire
/// ([`super::net::rows_to_blob`]) are byte-identical for the same rows.
pub(crate) fn encode_rows_blob(bucket: &[Row]) -> Result<(Vec<u8>, usize, Option<Vec<u32>>)> {
    let width = bucket.iter().map(|r| r.fields.len()).max().unwrap_or(0);
    let ragged = bucket.iter().any(|r| r.fields.len() != width);
    let schema = spill_schema(width);
    if ragged {
        // see SegmentMeta::widths: pad to rectangular, remember
        // the true arities so the read restores rows exactly
        let padded: Vec<Row> = bucket
            .iter()
            .map(|r| {
                let mut fields = r.fields.clone();
                fields.resize(width, Field::Null);
                Row::new(fields)
            })
            .collect();
        let widths = bucket.iter().map(|r| r.fields.len() as u32).collect();
        Ok((colbin::encode(&schema, &padded)?, width, Some(widths)))
    } else {
        Ok((colbin::encode(&schema, bucket)?, width, None))
    }
}

/// Decode an [`encode_rows_blob`] blob back to rows, truncating ragged
/// rows to their recorded true widths (the decode twin shared by the
/// spill read path and the network payload path).
pub(crate) fn decode_rows_blob(
    bytes: &[u8],
    width: usize,
    widths: Option<&[u32]>,
) -> Result<Vec<Row>> {
    let mut rows = colbin::decode(&spill_schema(width), bytes)?;
    if let Some(widths) = widths {
        for (row, w) in rows.iter_mut().zip(widths.iter()) {
            let w = usize::try_from(*w).map_err(|_| {
                DdpError::format(
                    "spill",
                    format!("row width {w} overflows usize (corrupt header?)"),
                )
            })?;
            row.fields.truncate(w);
        }
    }
    Ok(rows)
}

/// Byte range of one bucket inside a [`SpillFile`].
#[derive(Debug, Clone)]
struct SegmentMeta {
    offset: u64,
    len: u64,
    rows: u64,
    width: usize,
    /// per-row true widths when the bucket was ragged: the engine never
    /// enforces row arity, so a query that runs in memory must also run
    /// spilled. Ragged buckets are padded to rectangular with `Null` for
    /// encoding and truncated back on read — trailing *real* nulls
    /// survive because truncation uses these recorded widths, not a
    /// null scan.
    widths: Option<Vec<u32>>,
}

/// One spilled task output: per-bucket colbin blobs concatenated into a
/// single file, read back bucket-at-a-time. Deletes its file on drop,
/// and keeps its [`SpillDir`] alive so a context dropped mid-query (a
/// `StreamQuery` outliving its `EngineCtx`) cannot sweep the directory
/// out from under live spill handles.
#[derive(Debug)]
pub struct SpillFile {
    path: PathBuf,
    segments: Vec<SegmentMeta>,
    file_bytes: u64,
    _dir: Arc<SpillDir>,
}

impl SpillFile {
    /// Encode `buckets` (one blob per bucket) into a fresh spill file.
    /// Buckets stream to the file one at a time — this path runs exactly
    /// when memory is exhausted, so at most one bucket's encoding is
    /// resident, never a whole-task blob alongside the live rows.
    pub fn write_buckets(dir: &Arc<SpillDir>, buckets: &[Vec<Row>]) -> Result<SpillFile> {
        let path = dir.next_path()?;
        let out = Self::write_buckets_to(dir, &path, buckets);
        if out.is_err() {
            // don't leave partial files behind on encode/IO failure
            let _ = std::fs::remove_file(&path);
        }
        out
    }

    fn write_buckets_to(
        dir: &Arc<SpillDir>,
        path: &std::path::Path,
        buckets: &[Vec<Row>],
    ) -> Result<SpillFile> {
        let mut file = std::fs::File::create(path)?;
        let mut segments = Vec::with_capacity(buckets.len());
        let mut offset = 0u64;
        for bucket in buckets {
            let (enc, width, widths) = Self::encode_row_bucket(bucket)?;
            file.write_all(&enc)?;
            segments.push(SegmentMeta {
                offset,
                len: enc.len() as u64,
                rows: bucket.len() as u64,
                width,
                widths,
            });
            offset += enc.len() as u64;
        }
        Ok(SpillFile {
            path: path.to_path_buf(),
            segments,
            file_bytes: offset,
            _dir: dir.clone(),
        })
    }

    /// Encode one bucket of rows: blob bytes, segment width, and per-row
    /// true widths when the bucket was ragged.
    fn encode_row_bucket(bucket: &[Row]) -> Result<(Vec<u8>, usize, Option<Vec<u32>>)> {
        encode_rows_blob(bucket)
    }

    /// Encode batch-native buckets (one blob per bucket) into a fresh
    /// spill file. Byte-for-byte identical to [`SpillFile::write_buckets`]
    /// over the same rows: batches are rectangular by construction and
    /// [`colbin::encode_columns`] writes exactly what the row encoder
    /// would — so on-disk size (and therefore spill accounting) cannot
    /// depend on which transport produced the spill.
    pub fn write_bucket_batches(
        dir: &Arc<SpillDir>,
        buckets: &[ColumnBatch],
    ) -> Result<SpillFile> {
        let path = dir.next_path()?;
        let out = Self::write_bucket_batches_to(dir, &path, buckets);
        if out.is_err() {
            // don't leave partial files behind on encode/IO failure
            let _ = std::fs::remove_file(&path);
        }
        out
    }

    fn write_bucket_batches_to(
        dir: &Arc<SpillDir>,
        path: &std::path::Path,
        buckets: &[ColumnBatch],
    ) -> Result<SpillFile> {
        let mut file = std::fs::File::create(path)?;
        let mut segments = Vec::with_capacity(buckets.len());
        let mut offset = 0u64;
        let zero_width = ColumnBatch::new(Vec::new(), 0);
        for bucket in buckets {
            // an empty bucket encodes at width 0 — exactly like the row
            // path, whose width is the max arity over zero rows
            let bucket = if bucket.is_empty() { &zero_width } else { bucket };
            let width = bucket.num_cols();
            let enc = colbin::encode_columns(&spill_schema(width), bucket)?;
            file.write_all(&enc)?;
            segments.push(SegmentMeta {
                offset,
                len: enc.len() as u64,
                rows: bucket.len() as u64,
                width,
                widths: None,
            });
            offset += enc.len() as u64;
        }
        Ok(SpillFile {
            path: path.to_path_buf(),
            segments,
            file_bytes: offset,
            _dir: dir.clone(),
        })
    }

    /// Encode sorted-run chunks, column-native per chunk: a chunk that
    /// transposes cleanly (rectangular, no mixed-type column) is written
    /// through the batch encoder; ragged or mixed chunks keep the exact
    /// row fallback. Bytes are identical either way, so external sort
    /// spills columns without its file size or read-back depending on
    /// which path each chunk took.
    pub fn write_run_chunks(dir: &Arc<SpillDir>, chunks: &[Vec<Row>]) -> Result<SpillFile> {
        let path = dir.next_path()?;
        let out = Self::write_run_chunks_to(dir, &path, chunks);
        if out.is_err() {
            // don't leave partial files behind on encode/IO failure
            let _ = std::fs::remove_file(&path);
        }
        out
    }

    fn write_run_chunks_to(
        dir: &Arc<SpillDir>,
        path: &std::path::Path,
        chunks: &[Vec<Row>],
    ) -> Result<SpillFile> {
        let mut file = std::fs::File::create(path)?;
        let mut segments = Vec::with_capacity(chunks.len());
        let mut offset = 0u64;
        for chunk in chunks {
            // one chunk converts (and drops) at a time, so the transient
            // columnar copy is bounded by RUN_CHUNK_ROWS
            let (enc, width, widths) = match ColumnBatch::try_from_rows(chunk) {
                Some(batch) => {
                    let width = batch.num_cols();
                    (colbin::encode_columns(&spill_schema(width), &batch)?, width, None)
                }
                None => Self::encode_row_bucket(chunk)?,
            };
            file.write_all(&enc)?;
            segments.push(SegmentMeta {
                offset,
                len: enc.len() as u64,
                rows: chunk.len() as u64,
                width,
                widths,
            });
            offset += enc.len() as u64;
        }
        Ok(SpillFile {
            path: path.to_path_buf(),
            segments,
            file_bytes: offset,
            _dir: dir.clone(),
        })
    }

    pub fn num_buckets(&self) -> usize {
        self.segments.len()
    }

    /// Total rows across all buckets.
    pub fn num_rows(&self) -> u64 {
        self.segments.iter().map(|s| s.rows).sum()
    }

    /// Compressed on-disk size.
    pub fn file_bytes(&self) -> u64 {
        self.file_bytes
    }

    /// Rows in one bucket (from the index — no I/O).
    pub fn bucket_rows(&self, b: usize) -> u64 {
        self.segments[b].rows
    }

    /// Decode one bucket's rows (exact round-trip, original order).
    pub fn read_bucket(&self, b: usize) -> Result<Vec<Row>> {
        let mut f = self.open()?;
        self.read_bucket_at(&mut f, b)
    }

    /// Decode one bucket straight into a [`ColumnBatch`] — colbin's
    /// native decode direction, no intermediate rows. Returns `None` for
    /// ragged buckets: those were padded to rectangular for encoding and
    /// must be truncated back per row, so they only exist as rows
    /// ([`SpillFile::read_bucket`] handles them).
    pub fn read_bucket_batch(&self, b: usize) -> Result<Option<ColumnBatch>> {
        let seg = &self.segments[b];
        if seg.widths.is_some() {
            return Ok(None);
        }
        let len = self.seg_len_checked(seg)?;
        let mut f = self.open()?;
        f.seek(SeekFrom::Start(seg.offset))?;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)?;
        Ok(Some(colbin::decode_columns(&spill_schema(seg.width), &buf)?))
    }

    /// Validate a segment's byte extent before allocating or reading: a
    /// corrupt or oversized header must fail with a structured error, not
    /// wrap on a narrow-`usize` cast or read garbage past the file end.
    fn seg_len_checked(&self, seg: &SegmentMeta) -> Result<usize> {
        let len = usize::try_from(seg.len).map_err(|_| {
            DdpError::format(
                "spill",
                format!("segment length {} overflows usize (corrupt header?)", seg.len),
            )
        })?;
        let end = seg.offset.checked_add(seg.len).ok_or_else(|| {
            DdpError::format(
                "spill",
                format!(
                    "segment extent overflows: offset {} + len {} (corrupt header?)",
                    seg.offset, seg.len
                ),
            )
        })?;
        if end > self.file_bytes {
            return Err(DdpError::format(
                "spill",
                format!(
                    "segment [{}..{end}) exceeds spill file size {} (corrupt header?)",
                    seg.offset, self.file_bytes
                ),
            ));
        }
        Ok(len)
    }

    /// Open a read handle for repeated bucket reads — a chunk-streaming
    /// cursor reads many segments from one file and must not pay an
    /// open/close syscall per segment.
    fn open(&self) -> Result<std::fs::File> {
        Ok(std::fs::File::open(&self.path)?)
    }

    /// Decode one bucket's rows through an already-open handle.
    fn read_bucket_at(&self, f: &mut std::fs::File, b: usize) -> Result<Vec<Row>> {
        let seg = &self.segments[b];
        let len = self.seg_len_checked(seg)?;
        f.seek(SeekFrom::Start(seg.offset))?;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)?;
        decode_rows_blob(&buf, seg.width, seg.widths.as_deref())
    }
}

impl Drop for SpillFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

// ---------------------------------------------------------------------
// shuffle-side containers (used by the executor)
// ---------------------------------------------------------------------

/// Map-side output of one shuffle task: the task's hash buckets, either
/// resident (as rows, or as column batches when a column-keyed wide
/// operator bucketed batch-native) under a governor reservation, or
/// spilled to one file.
pub enum BucketSet {
    Mem {
        buckets: Vec<Vec<Row>>,
        row_bytes: u64,
        rows: u64,
        /// released when the last [`Segment`] of this set drops
        res: Option<MemoryReservation>,
    },
    MemBatches {
        batches: Vec<ColumnBatch>,
        row_bytes: u64,
        rows: u64,
        /// released when the last [`Segment`] of this set drops
        res: Option<MemoryReservation>,
    },
    Spilled {
        file: Arc<SpillFile>,
        row_bytes: u64,
        rows: u64,
    },
}

impl BucketSet {
    /// Reserve-or-spill: keep `buckets` resident if the governor admits
    /// their approximate byte size, else write them to `dir`.
    pub fn build(
        gov: &Arc<MemoryGovernor>,
        dir: &Arc<SpillDir>,
        buckets: Vec<Vec<Row>>,
    ) -> Result<BucketSet> {
        let mut row_bytes = 0u64;
        let mut rows = 0u64;
        for b in &buckets {
            rows += b.len() as u64;
            row_bytes += b.iter().map(|r| r.approx_size() as u64).sum::<u64>();
        }
        match MemoryGovernor::try_reserve(gov, row_bytes as usize) {
            Some(res) => Ok(BucketSet::Mem { buckets, row_bytes, rows, res: Some(res) }),
            None => {
                let file = SpillFile::write_buckets(dir, &buckets)?;
                Ok(BucketSet::Spilled { file: Arc::new(file), row_bytes, rows })
            }
        }
    }

    /// Reserve-or-spill for batch-native shuffle state. Byte accounting
    /// ([`ColumnBatch::approx_rows_size`]) and spilled file contents
    /// ([`colbin::encode_columns`]) are exact row-path equivalents, so
    /// the governor's spill decision — and everything downstream of it —
    /// cannot depend on the transport representation.
    pub fn build_batches(
        gov: &Arc<MemoryGovernor>,
        dir: &Arc<SpillDir>,
        batches: Vec<ColumnBatch>,
    ) -> Result<BucketSet> {
        let mut row_bytes = 0u64;
        let mut rows = 0u64;
        for b in &batches {
            rows += b.len() as u64;
            row_bytes += b.approx_rows_size() as u64;
        }
        match MemoryGovernor::try_reserve(gov, row_bytes as usize) {
            Some(res) => Ok(BucketSet::MemBatches { batches, row_bytes, rows, res: Some(res) }),
            None => {
                let file = SpillFile::write_bucket_batches(dir, &batches)?;
                Ok(BucketSet::Spilled { file: Arc::new(file), row_bytes, rows })
            }
        }
    }

    /// Uncompressed row bytes this task contributes to the shuffle
    /// (identical whether the set spilled or stayed resident).
    pub fn row_bytes(&self) -> u64 {
        match self {
            BucketSet::Mem { row_bytes, .. }
            | BucketSet::MemBatches { row_bytes, .. }
            | BucketSet::Spilled { row_bytes, .. } => *row_bytes,
        }
    }

    pub fn records(&self) -> u64 {
        match self {
            BucketSet::Mem { rows, .. }
            | BucketSet::MemBatches { rows, .. }
            | BucketSet::Spilled { rows, .. } => *rows,
        }
    }

    /// On-disk bytes when spilled.
    pub fn spilled_file_bytes(&self) -> Option<u64> {
        match self {
            BucketSet::Mem { .. } | BucketSet::MemBatches { .. } => None,
            BucketSet::Spilled { file, .. } => Some(file.file_bytes()),
        }
    }
}

/// One input partition's slice of one reduce bucket: resident rows or a
/// resident column batch (sharing their set's reservation), or a segment
/// of a spill file.
pub enum Segment {
    Mem(Vec<Row>, Option<Arc<MemoryReservation>>),
    MemBatch(ColumnBatch, Option<Arc<MemoryReservation>>),
    Disk(Arc<SpillFile>, usize),
}

/// A segment's payload in its native representation.
pub enum SegmentData {
    Rows(Vec<Row>),
    Batch(ColumnBatch),
}

impl Segment {
    /// Materialize this segment's rows (original order).
    pub fn take_rows(self) -> Result<Vec<Row>> {
        Ok(match self.take_data()? {
            SegmentData::Rows(rows) => rows,
            SegmentData::Batch(batch) => batch.into_rows(),
        })
    }

    /// Materialize in whichever representation the segment already has:
    /// resident batches and rectangular spill segments come back as
    /// column batches ([`SpillFile::read_bucket_batch`] is the primary
    /// read path — colbin is column-major on disk); only row-resident
    /// and ragged spilled segments come back as rows.
    pub fn take_data(self) -> Result<SegmentData> {
        match self {
            Segment::Mem(rows, _res) => Ok(SegmentData::Rows(rows)),
            Segment::MemBatch(batch, _res) => Ok(SegmentData::Batch(batch)),
            Segment::Disk(file, b) => match file.read_bucket_batch(b)? {
                Some(batch) => Ok(SegmentData::Batch(batch)),
                None => Ok(SegmentData::Rows(file.read_bucket(b)?)),
            },
        }
    }
}

/// Regroup per-partition bucket sets into per-bucket segment lists,
/// preserving input partition order — the reduce side consumes bucket
/// `b` as `[part0's b, part1's b, ...]` exactly like the in-memory
/// transpose, so spilling cannot reorder output.
pub fn transpose_segments(sets: Vec<BucketSet>, num_parts: usize) -> Vec<Vec<Segment>> {
    let mut out: Vec<Vec<Segment>> = (0..num_parts).map(|_| Vec::new()).collect();
    for set in sets {
        match set {
            BucketSet::Mem { buckets, res, .. } => {
                let res = res.map(Arc::new);
                for (b, rows) in buckets.into_iter().enumerate() {
                    // empty slices contribute nothing to the merge
                    if !rows.is_empty() {
                        out[b].push(Segment::Mem(rows, res.clone()));
                    }
                }
            }
            BucketSet::MemBatches { batches, res, .. } => {
                let res = res.map(Arc::new);
                for (b, batch) in batches.into_iter().enumerate() {
                    // empty batches are skipped exactly like empty row
                    // slices, so segment order is mode-independent
                    if !batch.is_empty() {
                        out[b].push(Segment::MemBatch(batch, res.clone()));
                    }
                }
            }
            BucketSet::Spilled { file, .. } => {
                for (b, slot) in out.iter_mut().enumerate().take(file.num_buckets()) {
                    // skipping zero-row segments avoids a file open +
                    // decode per empty bucket (skewed keys make many)
                    if file.bucket_rows(b) > 0 {
                        slot.push(Segment::Disk(file.clone(), b));
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// external merge sort: sorted runs + k-way merge
// ---------------------------------------------------------------------

/// Rows per segment when a sorted run spills — and therefore the merge
/// side's read-ahead unit. One chunk per live cursor is the most the
/// merge ever holds from a spilled run, so reduce-side memory stays
/// bounded by `fan_in * RUN_CHUNK_ROWS` rows regardless of run length.
pub const RUN_CHUNK_ROWS: usize = 1024;

/// One sorted run of the external merge sort: a map task's partition (or
/// a streaming micro-batch delta), stably pre-sorted by the user
/// comparator, either resident under a governor reservation or spilled
/// to a chunked spill file ([`RUN_CHUNK_ROWS`] rows per colbin segment)
/// so the merge side can stream it back with bounded read-ahead.
pub enum SortedRun {
    Mem {
        rows: Vec<Row>,
        row_bytes: u64,
        /// released when the merge cursor (or the run itself) drops
        res: Option<MemoryReservation>,
    },
    Spilled {
        file: SpillFile,
        row_bytes: u64,
        rows: u64,
    },
}

impl SortedRun {
    /// Reserve-or-spill: keep the (already sorted) `rows` resident if the
    /// governor admits their approximate byte size, else write them to
    /// `dir` in [`RUN_CHUNK_ROWS`]-row segments.
    pub fn build(
        gov: &Arc<MemoryGovernor>,
        dir: &Arc<SpillDir>,
        rows: Vec<Row>,
    ) -> Result<SortedRun> {
        let row_bytes: u64 = rows.iter().map(|r| r.approx_size() as u64).sum();
        match MemoryGovernor::try_reserve(gov, row_bytes as usize) {
            Some(res) => Ok(SortedRun::Mem { rows, row_bytes, res: Some(res) }),
            None => {
                // this path runs exactly when memory is exhausted, so the
                // rows are MOVED into chunk vecs (no row deep-copy — only
                // the chunk headers are new allocations) before encoding
                let n = rows.len() as u64;
                let mut chunks: Vec<Vec<Row>> =
                    Vec::with_capacity((n as usize).div_ceil(RUN_CHUNK_ROWS).max(1));
                let mut it = rows.into_iter().peekable();
                while it.peek().is_some() {
                    chunks.push(it.by_ref().take(RUN_CHUNK_ROWS).collect());
                }
                let file = SpillFile::write_run_chunks(dir, &chunks)?;
                Ok(SortedRun::Spilled { file, row_bytes, rows: n })
            }
        }
    }

    pub fn len_rows(&self) -> usize {
        match self {
            SortedRun::Mem { rows, .. } => rows.len(),
            SortedRun::Spilled { rows, .. } => *rows as usize,
        }
    }

    /// Uncompressed row bytes (identical whether the run spilled or not).
    pub fn row_bytes(&self) -> u64 {
        match self {
            SortedRun::Mem { row_bytes, .. } | SortedRun::Spilled { row_bytes, .. } => *row_bytes,
        }
    }

    /// On-disk bytes when spilled.
    pub fn spilled_file_bytes(&self) -> Option<u64> {
        match self {
            SortedRun::Mem { .. } => None,
            SortedRun::Spilled { file, .. } => Some(file.file_bytes()),
        }
    }

    fn into_cursor(self, gov: &Arc<MemoryGovernor>) -> RunCursor {
        match self {
            SortedRun::Mem { rows, res, .. } => {
                RunCursor::Mem { rows: rows.into_iter(), _res: res }
            }
            SortedRun::Spilled { file, .. } => RunCursor::Disk {
                file,
                handle: None,
                next_chunk: 0,
                buf: Vec::new().into_iter(),
                res: MemoryGovernor::open(gov),
            },
        }
    }
}

/// Streaming reader over one sorted run: resident rows verbatim (the
/// run's reservation rides along until the cursor drops), or chunk-at-a-
/// time from the run's spill file with the in-flight chunk charged to
/// the governor. A refused charge still proceeds — the merge must
/// advance — so the worst transient overdraft is one bounded chunk per
/// live cursor.
enum RunCursor {
    Mem {
        rows: std::vec::IntoIter<Row>,
        _res: Option<MemoryReservation>,
    },
    Disk {
        file: SpillFile,
        /// one handle for the whole run — opened on the first chunk read,
        /// seeked per chunk (no open/close syscall per segment)
        handle: Option<std::fs::File>,
        next_chunk: usize,
        buf: std::vec::IntoIter<Row>,
        res: MemoryReservation,
    },
}

impl RunCursor {
    fn next(&mut self) -> Result<Option<Row>> {
        match self {
            RunCursor::Mem { rows, .. } => Ok(rows.next()),
            RunCursor::Disk { file, handle, next_chunk, buf, res } => loop {
                if let Some(r) = buf.next() {
                    return Ok(Some(r));
                }
                if *next_chunk >= file.num_buckets() {
                    res.release_all();
                    return Ok(None);
                }
                if handle.is_none() {
                    *handle = Some(file.open()?);
                }
                let rows = file.read_bucket_at(handle.as_mut().unwrap(), *next_chunk)?;
                *next_chunk += 1;
                res.release_all();
                let bytes: usize = rows.iter().map(|r| r.approx_size()).sum();
                let _ = res.try_grow(bytes);
                *buf = rows.into_iter();
            },
        }
    }
}

/// The map-side output of one external merge sort: every sorted run
/// feeding one merge, in input-partition (batch) / arrival (streaming)
/// order. The sibling of [`BucketSet`] for order-preserving exchanges.
#[derive(Default)]
pub struct SortedRunSet {
    runs: Vec<SortedRun>,
}

impl SortedRunSet {
    pub fn new() -> SortedRunSet {
        SortedRunSet::default()
    }

    pub fn from_runs(runs: Vec<SortedRun>) -> SortedRunSet {
        SortedRunSet { runs }
    }

    pub fn push(&mut self, run: SortedRun) {
        self.runs.push(run);
    }

    pub fn num_runs(&self) -> usize {
        self.runs.len()
    }

    /// Total rows across all runs.
    pub fn len_rows(&self) -> usize {
        self.runs.iter().map(SortedRun::len_rows).sum()
    }

    /// Uncompressed row bytes across all runs (mode-independent).
    pub fn row_bytes(&self) -> u64 {
        self.runs.iter().map(SortedRun::row_bytes).sum()
    }

    /// On-disk bytes across spilled runs.
    pub fn spilled_bytes(&self) -> u64 {
        self.runs.iter().filter_map(SortedRun::spilled_file_bytes).sum()
    }

    /// Number of spilled runs (= spill files written).
    pub fn spilled_files(&self) -> u64 {
        self.runs
            .iter()
            .filter(|r| r.spilled_file_bytes().is_some())
            .count() as u64
    }

    /// Streaming k-way merge over run cursors: a binary min-heap of run
    /// heads keyed by the user comparator with **run-index tie-breaking**
    /// (among equal heads the earlier run wins, and rows within a run
    /// keep their order). Merging stably pre-sorted runs this way
    /// reproduces the stable sort of their concatenation byte for byte,
    /// at any memory budget — spilled runs stream back one
    /// [`RUN_CHUNK_ROWS`] segment at a time, charged to `gov`.
    pub fn merge<C>(self, gov: &Arc<MemoryGovernor>, cmp: &C) -> Result<Vec<Row>>
    where
        C: Fn(&Row, &Row) -> std::cmp::Ordering + ?Sized,
    {
        use std::cmp::Ordering;
        let total = self.len_rows();
        let mut cursors: Vec<RunCursor> = Vec::with_capacity(self.runs.len());
        for run in self.runs {
            cursors.push(run.into_cursor(gov));
        }
        let mut heap: Vec<(Row, usize)> = Vec::with_capacity(cursors.len());
        for (i, c) in cursors.iter_mut().enumerate() {
            if let Some(row) = c.next()? {
                heap.push((row, i));
            }
        }
        let less = |a: &(Row, usize), b: &(Row, usize)| match cmp(&a.0, &b.0) {
            Ordering::Less => true,
            Ordering::Greater => false,
            Ordering::Equal => a.1 < b.1,
        };
        for i in (0..heap.len() / 2).rev() {
            sift_down(&mut heap, i, &less);
        }
        let mut out = Vec::with_capacity(total);
        while !heap.is_empty() {
            let run = heap[0].1;
            match cursors[run].next()? {
                Some(next) => {
                    let (row, _) = std::mem::replace(&mut heap[0], (next, run));
                    out.push(row);
                }
                None => {
                    let (row, _) = heap.swap_remove(0);
                    out.push(row);
                }
            }
            sift_down(&mut heap, 0, &less);
        }
        Ok(out)
    }
}

/// Restore the min-heap property from slot `i` downward (`less` is the
/// strict ordering over `(row, run-index)` heads). No-op on an empty or
/// single-entry heap.
fn sift_down<F>(h: &mut [(Row, usize)], mut i: usize, less: &F)
where
    F: Fn(&(Row, usize), &(Row, usize)) -> bool,
{
    loop {
        let l = 2 * i + 1;
        let r = 2 * i + 2;
        let mut m = i;
        if l < h.len() && less(&h[l], &h[m]) {
            m = l;
        }
        if r < h.len() && less(&h[r], &h[m]) {
            m = r;
        }
        if m == i {
            return;
        }
        h.swap(i, m);
        i = m;
    }
}

// ---------------------------------------------------------------------
// streaming blocking-op buffer
// ---------------------------------------------------------------------

/// Arrival-order row buffer with governed residency: rows accumulate in
/// an in-memory tail while the governor grants growth; a refused grow
/// flushes the tail to a spill chunk and zeroes the reservation. Drain
/// returns chunks then tail — exact arrival order.
#[derive(Default)]
pub struct SpilledRows {
    tail: Vec<Row>,
    res: Option<MemoryReservation>,
    chunks: Vec<SpillFile>,
    rows_spilled: u64,
    spilled_bytes: u64,
    spilled_files: u64,
}

impl SpilledRows {
    pub fn new() -> SpilledRows {
        SpilledRows::default()
    }

    /// Buffered rows (resident tail + spilled chunks).
    pub fn len_rows(&self) -> usize {
        self.tail.len() + self.rows_spilled as usize
    }

    /// Total bytes written to spill chunks so far.
    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes
    }

    pub fn spilled_files(&self) -> u64 {
        self.spilled_files
    }

    /// Append `rows`; returns `(spill_bytes_delta, spill_files_delta)`
    /// for stats accounting (zero when the rows stayed resident).
    pub fn push(
        &mut self,
        gov: &Arc<MemoryGovernor>,
        dir: &Arc<SpillDir>,
        rows: Vec<Row>,
    ) -> Result<(u64, u64)> {
        if rows.is_empty() {
            return Ok((0, 0));
        }
        let add: usize = rows.iter().map(|r| r.approx_size()).sum();
        let res = self.res.get_or_insert_with(|| MemoryGovernor::open(gov));
        if res.try_grow(add) {
            self.tail.extend(rows);
            return Ok((0, 0));
        }
        // refused: everything buffered so far (tail + incoming) becomes
        // one spill chunk, and the reservation returns to zero. State is
        // only committed after the write succeeds — on spill I/O failure
        // (ENOSPC is realistic exactly here) the tail is restored to its
        // reserved size and the incoming batch is DROPPED with the error
        // (not recoverable by the caller; the query is failing anyway),
        // so the buffer never holds rows the governor didn't account for.
        let incoming = rows.len();
        let mut pending = std::mem::take(&mut self.tail);
        pending.extend(rows);
        match SpillFile::write_buckets(dir, std::slice::from_ref(&pending)) {
            Ok(chunk) => {
                let delta = chunk.file_bytes();
                self.rows_spilled += pending.len() as u64;
                self.spilled_bytes += delta;
                self.spilled_files += 1;
                self.chunks.push(chunk);
                res.release_all();
                Ok((delta, 1))
            }
            Err(e) => {
                pending.truncate(pending.len() - incoming);
                self.tail = pending;
                Err(e)
            }
        }
    }

    /// Drain everything in arrival order, deleting chunk files and
    /// releasing the reservation.
    pub fn drain(&mut self) -> Result<Vec<Row>> {
        let mut out = Vec::with_capacity(self.len_rows());
        for chunk in self.chunks.drain(..) {
            out.extend(chunk.read_bucket(0)?);
        }
        out.append(&mut self.tail);
        self.rows_spilled = 0;
        if let Some(res) = &mut self.res {
            res.release_all();
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::{ColumnData, Field};
    use crate::row;

    fn dir() -> Arc<SpillDir> {
        Arc::new(SpillDir::new(None))
    }

    fn gov(budget: Option<usize>) -> Arc<MemoryGovernor> {
        Arc::new(MemoryGovernor::new(budget))
    }

    fn rows(lo: i64, hi: i64) -> Vec<Row> {
        (lo..hi).map(|i| row!(i, format!("v{i}"), (i as f64) / 3.0)).collect()
    }

    #[test]
    fn spill_file_roundtrips_buckets_exactly() {
        let d = dir();
        let buckets = vec![rows(0, 7), Vec::new(), rows(100, 103)];
        let f = SpillFile::write_buckets(&d, &buckets).unwrap();
        assert_eq!(f.num_buckets(), 3);
        assert_eq!(f.num_rows(), 10);
        assert!(f.file_bytes() > 0);
        for (b, want) in buckets.iter().enumerate() {
            assert_eq!(&f.read_bucket(b).unwrap(), want);
        }
        let path = f.path.clone();
        assert!(path.exists());
        drop(f);
        assert!(!path.exists(), "spill file deleted on drop");
    }

    #[test]
    fn bucket_batch_read_is_column_native() {
        let d = dir();
        let buckets = vec![rows(0, 9), Vec::new()];
        let f = SpillFile::write_buckets(&d, &buckets).unwrap();
        let batch =
            f.read_bucket_batch(0).unwrap().expect("rectangular bucket reads as a batch");
        assert_eq!(batch.len(), 9);
        // the all-Any spill schema still lands typed columns: each column
        // of these rows is homogeneous, so decode densifies it
        assert!(matches!(batch.cols[0].data, ColumnData::I64(_)));
        assert!(matches!(batch.cols[1].data, ColumnData::Str(_)));
        assert!(matches!(batch.cols[2].data, ColumnData::F64(_)));
        assert_eq!(batch.into_rows(), buckets[0]);
        let empty = f.read_bucket_batch(1).unwrap().expect("empty bucket is rectangular");
        assert_eq!(empty.len(), 0);

        // ragged buckets have no columnar representation — the row read
        // (which truncates pad Nulls back off) is the only exact path
        let ragged = vec![row!(1i64), Row::new(vec![Field::I64(1), Field::I64(2)])];
        let f2 = SpillFile::write_buckets(&d, std::slice::from_ref(&ragged)).unwrap();
        assert!(f2.read_bucket_batch(0).unwrap().is_none());
        assert_eq!(f2.read_bucket(0).unwrap(), ragged);
    }

    #[test]
    fn batch_written_spill_file_is_byte_identical_to_row_written() {
        // the same buckets written batch-native and row-native must be
        // the same file, byte for byte — including empty buckets (the
        // row path encodes them at width 0) and all-null columns
        let d = dir();
        let mut with_nulls = rows(0, 6);
        with_nulls.push(Row::new(vec![Field::Null, Field::Null, Field::Null]));
        let all_null_col: Vec<Row> = (0..4)
            .map(|i| Row::new(vec![Field::I64(i), Field::Null, Field::F64(i as f64)]))
            .collect();
        let buckets = vec![with_nulls, Vec::new(), all_null_col];
        let from_rows = SpillFile::write_buckets(&d, &buckets).unwrap();
        let batches: Vec<ColumnBatch> = buckets
            .iter()
            .map(|b| ColumnBatch::try_from_rows(b).expect("rectangular typed buckets"))
            .collect();
        let from_batches = SpillFile::write_bucket_batches(&d, &batches).unwrap();
        assert_eq!(
            std::fs::read(&from_rows.path).unwrap(),
            std::fs::read(&from_batches.path).unwrap(),
            "batch and row writers must produce identical files"
        );
        assert_eq!(from_rows.file_bytes(), from_batches.file_bytes());
        for (b, want) in buckets.iter().enumerate() {
            assert_eq!(&from_batches.read_bucket(b).unwrap(), want);
        }
        // the all-null column reads back in canonical representation
        let rt = from_batches.read_bucket_batch(2).unwrap().unwrap();
        assert!(rt.cols[1].nulls.is_none(), "all-null column decodes to canonical Any");
        assert_eq!(rt.cols[1], batches[2].cols[1], "round-trip representation is stable");
    }

    #[test]
    fn run_chunk_writer_matches_row_writer_bytes() {
        let d = dir();
        // clean chunks go columnar, the ragged chunk falls back to rows —
        // both byte-identical to the plain row writer
        let clean = rows(0, 20);
        let ragged = vec![row!(1i64), Row::new(vec![Field::I64(1), Field::I64(2)])];
        let chunks = vec![clean, ragged];
        let a = SpillFile::write_buckets(&d, &chunks).unwrap();
        let b = SpillFile::write_run_chunks(&d, &chunks).unwrap();
        assert_eq!(std::fs::read(&a.path).unwrap(), std::fs::read(&b.path).unwrap());
        for (i, want) in chunks.iter().enumerate() {
            assert_eq!(&b.read_bucket(i).unwrap(), want);
        }
    }

    #[test]
    fn corrupted_segment_header_fails_loudly() {
        let d = dir();
        let mut f = SpillFile::write_buckets(&d, &[rows(0, 5)]).unwrap();
        // length past the end of the file: must be a structured error,
        // not a giant allocation or a short read
        f.segments[0].len = f.file_bytes + 1;
        let err = f.read_bucket(0).unwrap_err().to_string();
        assert!(err.contains("spill") && err.contains("corrupt"), "{err}");
        let err = f.read_bucket_batch(0).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
        // u64::MAX length: the old `as usize` cast accepted this silently
        f.segments[0].len = u64::MAX;
        let err = f.read_bucket(0).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
        // offset + len overflowing u64 is caught before any allocation
        f.segments[0].offset = u64::MAX;
        let err = f.read_bucket(0).unwrap_err().to_string();
        assert!(err.contains("overflow") || err.contains("corrupt"), "{err}");
    }

    #[test]
    fn mem_batch_segments_transpose_in_partition_order() {
        let d = dir();
        let g_mem = gov(None);
        let g_spill = gov(Some(1));
        let to_batches = |buckets: &[Vec<Row>]| -> Vec<ColumnBatch> {
            buckets.iter().map(|b| ColumnBatch::try_from_rows(b).unwrap()).collect()
        };
        // part 0 resident batch-native, part 1 spilled batch-native:
        // bucket b must still read p0 then p1, like the row transpose
        let p0 = BucketSet::build_batches(&g_mem, &d, to_batches(&[rows(0, 3), rows(10, 12)]))
            .unwrap();
        assert!(p0.spilled_file_bytes().is_none());
        assert!(g_mem.reserved_bytes() > 0, "resident batches hold a reservation");
        let p1 = BucketSet::build_batches(&g_spill, &d, to_batches(&[rows(3, 5), rows(12, 15)]))
            .unwrap();
        assert!(p1.spilled_file_bytes().is_some());
        // row-byte accounting is identical to the row path
        let row_set = BucketSet::build(&g_mem, &d, vec![rows(0, 3), rows(10, 12)]).unwrap();
        assert_eq!(p0.row_bytes(), row_set.row_bytes());
        assert_eq!(p0.records(), row_set.records());
        drop(row_set);

        let per_bucket = transpose_segments(vec![p0, p1], 2);
        let mut merged: Vec<Vec<Row>> = Vec::new();
        for segs in per_bucket {
            let mut out = Vec::new();
            for s in segs {
                match s.take_data().unwrap() {
                    SegmentData::Batch(b) => out.extend(b.into_rows()),
                    SegmentData::Rows(r) => panic!("batch-native segments expected, got {r:?}"),
                }
            }
            merged.push(out);
        }
        assert_eq!(merged[0], rows(0, 5));
        let mut want1 = rows(10, 12);
        want1.extend(rows(12, 15));
        assert_eq!(merged[1], want1);
        assert_eq!(g_mem.reserved_bytes(), 0, "reservation released with the segments");
    }

    #[test]
    fn ragged_rows_roundtrip_exactly() {
        // the engine never enforces row arity, so spilling must accept
        // whatever the in-memory path accepts — including a trailing
        // *real* Null, which must not be confused with pad Nulls
        let d = dir();
        let bucket = vec![
            row!(1i64),
            Row::new(vec![Field::I64(1), Field::I64(2)]),
            Row::new(vec![]),
            Row::new(vec![Field::Null, Field::Str("x".into()), Field::Null]),
        ];
        let f = SpillFile::write_buckets(&d, std::slice::from_ref(&bucket)).unwrap();
        assert_eq!(f.read_bucket(0).unwrap(), bucket);
    }

    #[test]
    fn bucket_set_spills_only_when_refused() {
        let d = dir();
        let big = gov(Some(1 << 20));
        let set = BucketSet::build(&big, &d, vec![rows(0, 20)]).unwrap();
        assert!(set.spilled_file_bytes().is_none());
        assert!(big.reserved_bytes() > 0);
        let bytes = set.row_bytes();
        assert_eq!(set.records(), 20);
        drop(set);
        assert_eq!(big.reserved_bytes(), 0, "reservation released with the set");

        let tiny = gov(Some(8));
        let set = BucketSet::build(&tiny, &d, vec![rows(0, 20)]).unwrap();
        assert!(set.spilled_file_bytes().is_some());
        assert_eq!(set.row_bytes(), bytes, "row-byte accounting identical spilled or not");
        assert_eq!(tiny.reserved_bytes(), 0);
    }

    #[test]
    fn transpose_preserves_partition_order_across_mem_and_disk() {
        let d = dir();
        let g_mem = gov(None);
        let g_spill = gov(Some(1));
        // part 0 resident, part 1 spilled — bucket must still read p0 then p1
        let p0 = BucketSet::build(&g_mem, &d, vec![rows(0, 3), rows(10, 12)]).unwrap();
        let p1 = BucketSet::build(&g_spill, &d, vec![rows(3, 5), rows(12, 15)]).unwrap();
        let per_bucket = transpose_segments(vec![p0, p1], 2);
        let merged: Vec<Vec<Row>> = per_bucket
            .into_iter()
            .map(|segs| {
                let mut out = Vec::new();
                for s in segs {
                    out.extend(s.take_rows().unwrap());
                }
                out
            })
            .collect();
        assert_eq!(merged[0], rows(0, 5));
        let mut want1 = rows(10, 12);
        want1.extend(rows(12, 15));
        assert_eq!(merged[1], want1);
    }

    #[test]
    fn spilled_rows_drain_in_arrival_order_and_release() {
        let d = dir();
        let g = gov(Some(200)); // a handful of rows fit, then chunks flush
        let mut buf = SpilledRows::new();
        let all = rows(0, 50);
        for chunk in all.chunks(7) {
            buf.push(&g, &d, chunk.to_vec()).unwrap();
        }
        assert_eq!(buf.len_rows(), 50);
        assert!(buf.spilled_files() > 0, "tiny budget must have flushed chunks");
        assert!(buf.spilled_bytes() > 0);
        let drained = buf.drain().unwrap();
        assert_eq!(drained, all, "arrival order preserved through spill chunks");
        assert_eq!(g.reserved_bytes(), 0);
        drop(buf);
        assert_eq!(g.reserved_bytes(), 0);
    }

    #[test]
    fn spilled_rows_drop_releases_reservation_and_files() {
        let d = dir();
        let g = gov(None); // unbounded: everything resident
        let mut buf = SpilledRows::new();
        buf.push(&g, &d, rows(0, 30)).unwrap();
        assert!(g.reserved_bytes() > 0);
        drop(buf);
        assert_eq!(g.reserved_bytes(), 0, "no leak after buffer drop");
    }

    fn by_col0(a: &Row, b: &Row) -> std::cmp::Ordering {
        a.get(0).canonical_cmp(b.get(0))
    }

    #[test]
    fn sorted_runs_merge_like_a_stable_sort() {
        // two stably pre-sorted runs, one resident and one spilled, with
        // duplicate keys across runs: the merge must interleave by cmp
        // with run-order tie-breaking — exactly the stable sort of the
        // concatenation
        let d = dir();
        let g = gov(None);
        let g_tiny = gov(Some(1));
        let a = vec![row!(0i64, "a0"), row!(2i64, "a1"), row!(2i64, "a2"), row!(5i64, "a3")];
        let b = vec![row!(0i64, "b0"), row!(2i64, "b1"), row!(3i64, "b2")];
        let run_a = SortedRun::build(&g, &d, a.clone()).unwrap();
        assert!(run_a.spilled_file_bytes().is_none());
        assert!(g.reserved_bytes() > 0, "resident run holds a reservation");
        let run_b = SortedRun::build(&g_tiny, &d, b.clone()).unwrap();
        assert!(run_b.spilled_file_bytes().is_some(), "one-byte budget must spill");
        assert_eq!(run_a.len_rows() + run_b.len_rows(), 7);

        let set = SortedRunSet::from_runs(vec![run_a, run_b]);
        assert_eq!(set.num_runs(), 2);
        assert_eq!(set.spilled_files(), 1);
        assert!(set.spilled_bytes() > 0);
        let merged = set.merge(&g, &by_col0).unwrap();
        let mut want = a;
        want.extend(b);
        want.sort_by(by_col0); // Vec::sort_by is stable — the reference semantics
        assert_eq!(merged, want);
        assert_eq!(g.reserved_bytes(), 0, "cursor released the resident run");
        assert_eq!(g_tiny.reserved_bytes(), 0);
    }

    #[test]
    fn spilled_run_streams_back_in_bounded_chunks() {
        let d = dir();
        let tiny = gov(Some(1));
        let n = (RUN_CHUNK_ROWS * 2 + 100) as i64;
        let rows: Vec<Row> = (0..n).map(|i| row!(i)).collect();
        let run = SortedRun::build(&tiny, &d, rows.clone()).unwrap();
        match &run {
            SortedRun::Spilled { file, .. } => {
                assert_eq!(file.num_buckets(), 3, "run split into chunk segments");
            }
            SortedRun::Mem { .. } => panic!("one-byte budget must spill the run"),
        }
        let merged = SortedRunSet::from_runs(vec![run]).merge(&tiny, &by_col0).unwrap();
        assert_eq!(merged, rows);
        assert_eq!(tiny.reserved_bytes(), 0, "chunk charges released with the cursor");
    }

    #[test]
    fn empty_run_set_merges_to_nothing() {
        let g = gov(None);
        let merged = SortedRunSet::new().merge(&g, &by_col0).unwrap();
        assert!(merged.is_empty());
        let d = dir();
        let empty_run = SortedRun::build(&g, &d, Vec::new()).unwrap();
        let merged = SortedRunSet::from_runs(vec![empty_run]).merge(&g, &by_col0).unwrap();
        assert!(merged.is_empty());
    }

    #[test]
    fn spill_dir_removed_on_drop() {
        let d = dir();
        let f = SpillFile::write_buckets(&d, &[rows(0, 3)]).unwrap();
        let dir_path = d.path().clone();
        assert!(dir_path.is_dir());
        drop(f);
        drop(d);
        assert!(!dir_path.exists());
    }

    #[test]
    fn spill_file_keeps_dir_alive_past_context_drop() {
        // a StreamQuery can outlive the EngineCtx whose SpillDir it wrote
        // into; live spill handles must keep the directory (and their
        // data) readable until they drop
        let d = dir();
        let want = rows(0, 10);
        let f = SpillFile::write_buckets(&d, std::slice::from_ref(&want)).unwrap();
        let dir_path = d.path().clone();
        drop(d); // last *context* handle gone
        assert!(dir_path.is_dir(), "dir survives while a spill file lives");
        assert_eq!(f.read_bucket(0).unwrap(), want);
        drop(f);
        assert!(!dir_path.exists(), "dir removed with the last holder");
    }
}
