//! Stage-oriented plan executor.
//!
//! Evaluation walks the plan DAG: runs of narrow transformations fuse into
//! one per-partition pipeline (no intermediate materialization — the
//! paper's "chained via system memory" property); wide transformations
//! (reduce/join/distinct/sort/repartition) become shuffle boundaries with
//! map-side combining. Shuffle state is governed by a shared
//! [`MemoryGovernor`] budget: map-side buckets that don't fit spill to
//! disk ([`super::spill`]) and are merge-read back per reduce partition,
//! and `Sort` runs as an external merge sort (per-partition sorted runs,
//! spilled when refused, k-way merged with input-order tie-breaking) —
//! so corpora larger than the budget complete instead of OOMing, with
//! byte-identical output either way. Tasks run on a fixed thread pool
//! with bounded retries; injected faults exercise lineage recomputation.
//! Every task is optionally recorded into a [`TaskTrace`] (with real
//! measured output/shuffle bytes) that the virtual-time cluster
//! simulator replays at other cluster sizes.

use super::cache::CacheManager;
use super::dataset::{Dataset, JoinKind, PartRef, Partitioned, Plan};
use super::distributed::{DistCounters, NarrowDesc, WorkerPool};
use super::expr;
use super::fault::FaultInjector;
use super::memory::{self, MemoryGovernor};
use super::optimizer::{self, RewriteCounts};
use super::row::{ColumnBatch, Field, Row};
use super::spill::{
    transpose_segments, BucketSet, SegmentData, SortedRun, SortedRunSet, SpillDir,
};
use super::stats::{EngineStats, Stat};
use super::trace::{SpanKind, Tracer};
use crate::util::error::{DdpError, Result};
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// worker threads in the local executor
    pub workers: usize,
    /// default partition count for sources created through the context
    pub default_partitions: usize,
    /// cache budget in bytes (explicit state management, §3.2)
    pub cache_budget_bytes: usize,
    /// fuse narrow chains (ablation switch; `false` materializes each op)
    pub fusion: bool,
    /// run the rule-based plan optimizer before execution (ablation
    /// switch, like `fusion`; default honours the `DDP_OPTIMIZE` env var —
    /// `0`/`false` disables)
    pub optimize: bool,
    /// evaluate structured narrow steps (`filter_expr` / `project`)
    /// column-at-a-time over [`super::row::ColumnBatch`]es, falling back
    /// to row-wise execution at opaque-closure boundaries and for inputs
    /// that cannot form a typed batch (ragged arity / mixed-type
    /// columns). Ablation switch like `optimize`; default honours the
    /// `DDP_VECTORIZE` env var — `0`/`false` disables.
    pub vectorize: bool,
    /// max attempts per task (1 = no retry)
    pub max_task_attempts: u32,
    /// record a task trace for the cluster simulator
    pub record_trace: bool,
    /// process memory the engine may hold in bulky intermediate state
    /// (shuffle buckets, streaming blocking-op buffers, cache entries —
    /// one shared [`MemoryGovernor`] budget). `None` = unbounded; the
    /// default honours the `DDP_MEMORY_BUDGET` env var (bytes, with
    /// optional `k`/`m`/`g` suffix; `0` = unbounded). When a reservation
    /// fails, the state spills to disk instead of OOMing.
    pub memory_budget_bytes: Option<usize>,
    /// base directory for spill files (a unique per-context subdirectory
    /// is created under it). Default: system temp dir, or `DDP_SPILL_DIR`.
    pub spill_dir: Option<std::path::PathBuf>,
    /// record structured execution spans (run → pipe → stage → task /
    /// micro-batch) with per-span counter attribution
    /// ([`super::trace`]). Off by default — the hot path then takes a
    /// single branch per site; the default honours the `DDP_TRACE` env
    /// var (`1`/`true` enables).
    pub trace: bool,
    /// statically analyze plans before executing them
    /// ([`super::analyze`]): the driver rejects plans with
    /// error-severity diagnostics before any task runs. Plan-walk cost
    /// only (proportional to plan size, never data size); disabling adds
    /// no per-row/per-batch work either way. Default honours the
    /// `DDP_ANALYZE` env var — `0`/`false` disables.
    pub analyze: bool,
    /// addresses of already-running `ddp worker` processes to dispatch
    /// eligible tasks to ([`super::distributed`]). Empty = no remote
    /// dispatch. Default honours `DDP_WORKERS_REMOTE` (comma-separated
    /// `host:port` list).
    pub remote_workers: Vec<String>,
    /// spawn this many local `ddp worker` processes and dispatch to
    /// them (ignored when `remote_workers` is non-empty). Default
    /// honours `DDP_SPAWN_WORKERS`.
    pub spawn_workers: usize,
    /// path to the `ddp` binary used for spawned workers; default
    /// honours `DDP_WORKER_BIN`, then falls back to the current
    /// executable (see [`super::distributed::resolve_worker_binary`]).
    pub worker_binary: Option<std::path::PathBuf>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 4,
            default_partitions: 8,
            cache_budget_bytes: 512 << 20,
            fusion: true,
            optimize: std::env::var("DDP_OPTIMIZE")
                .map(|v| v != "0" && !v.eq_ignore_ascii_case("false"))
                .unwrap_or(true),
            vectorize: std::env::var("DDP_VECTORIZE")
                .map(|v| v != "0" && !v.eq_ignore_ascii_case("false"))
                .unwrap_or(true),
            max_task_attempts: 3,
            record_trace: false,
            memory_budget_bytes: memory::budget_from_env("DDP_MEMORY_BUDGET"),
            spill_dir: std::env::var("DDP_SPILL_DIR")
                .ok()
                .map(std::path::PathBuf::from),
            trace: std::env::var("DDP_TRACE")
                .map(|v| v != "0" && !v.eq_ignore_ascii_case("false"))
                .unwrap_or(false),
            analyze: std::env::var("DDP_ANALYZE")
                .map(|v| v != "0" && !v.eq_ignore_ascii_case("false"))
                .unwrap_or(true),
            remote_workers: std::env::var("DDP_WORKERS_REMOTE")
                .map(|v| {
                    v.split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect()
                })
                .unwrap_or_default(),
            spawn_workers: std::env::var("DDP_SPAWN_WORKERS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(0),
            worker_binary: std::env::var("DDP_WORKER_BIN")
                .ok()
                .map(std::path::PathBuf::from),
        }
    }
}

/// One executed task, as recorded for the simulator.
#[derive(Debug, Clone, Copy)]
pub struct TaskRecord {
    pub stage_id: u64,
    pub duration_secs: f64,
    pub input_rows: u64,
    pub output_bytes: u64,
    /// bytes this task contributed to a shuffle (0 for result tasks)
    pub shuffle_bytes: u64,
}

/// Ordered list of task records from a real run.
pub type TaskTrace = Vec<TaskRecord>;

/// Execution context ("SparkContext"): thread pool + cache + stats +
/// memory governor (out-of-core spill arbiter).
pub struct EngineCtx {
    pub cfg: EngineConfig,
    pub pool: ThreadPool,
    pub cache: CacheManager,
    pub stats: EngineStats,
    pub fault: Option<Arc<FaultInjector>>,
    /// shared byte budget for shuffle state, streaming buffers and cache
    pub governor: Arc<MemoryGovernor>,
    /// per-context spill directory (lazy; removed when the context drops)
    pub spill: Arc<SpillDir>,
    /// span recorder ([`super::trace`]; inert unless `cfg.trace`)
    pub tracer: Arc<Tracer>,
    /// worker fleet for real multi-process dispatch
    /// ([`super::distributed`]); `None` = single-process
    pub(crate) dist: Option<Arc<WorkerPool>>,
    trace: Mutex<TaskTrace>,
    rewrites: Mutex<RewriteCounts>,
}

impl EngineCtx {
    pub fn new(cfg: EngineConfig) -> Arc<EngineCtx> {
        EngineCtx::build(cfg, None)
    }

    pub fn with_faults(cfg: EngineConfig, fault: FaultInjector) -> Arc<EngineCtx> {
        EngineCtx::build(cfg, Some(Arc::new(fault)))
    }

    /// Context with an explicit worker fleet (tests and examples; the
    /// env-driven path is `cfg.remote_workers` / `cfg.spawn_workers`).
    pub fn with_workers(cfg: EngineConfig, pool: Arc<WorkerPool>) -> Arc<EngineCtx> {
        EngineCtx::build_with(cfg, None, Some(pool))
    }

    fn build(cfg: EngineConfig, fault: Option<Arc<FaultInjector>>) -> Arc<EngineCtx> {
        let dist = super::distributed::pool_from_config(&cfg);
        EngineCtx::build_with(cfg, fault, dist)
    }

    fn build_with(
        cfg: EngineConfig,
        fault: Option<Arc<FaultInjector>>,
        dist: Option<Arc<WorkerPool>>,
    ) -> Arc<EngineCtx> {
        let governor = Arc::new(MemoryGovernor::new(cfg.memory_budget_bytes));
        let spill = Arc::new(SpillDir::new(cfg.spill_dir.clone()));
        let tracer = Tracer::new(cfg.trace);
        if cfg.trace {
            // attribute governor admission decisions to the span running
            // on the deciding thread (only pay the hook when tracing)
            governor.set_observer(tracer.clone());
        }
        Arc::new(EngineCtx {
            pool: ThreadPool::new(cfg.workers),
            cache: CacheManager::with_governor(cfg.cache_budget_bytes, governor.clone()),
            stats: EngineStats::new(),
            fault,
            governor,
            spill,
            tracer,
            dist,
            trace: Mutex::new(Vec::new()),
            rewrites: Mutex::new(RewriteCounts::default()),
            cfg,
        })
    }

    /// The worker fleet this context dispatches to, if any.
    pub fn worker_pool(&self) -> Option<Arc<WorkerPool>> {
        self.dist.clone()
    }

    /// Charge one counter globally *and* to the thread's current span —
    /// the single path every stat increment takes, which is what makes
    /// the global snapshot provably the sum of span-local counters.
    #[inline]
    pub(crate) fn charge(&self, s: Stat, v: u64) {
        self.stats.add_stat(s, v);
        self.tracer.charge_current(s, v);
    }

    /// [`Self::charge`] with explicit span attribution (task results are
    /// charged from the driver-side collection loop, after the worker
    /// thread's scope has exited).
    #[inline]
    fn charge_span(&self, span: u64, s: Stat, v: u64) {
        self.stats.add_stat(s, v);
        self.tracer.charge(span, s, v);
    }

    /// Export recorded spans as Chrome trace-event JSON (openable in
    /// `chrome://tracing` / Perfetto). Empty trace when `cfg.trace` is
    /// off.
    pub fn write_chrome_trace(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.tracer.write_chrome_trace(path).map_err(DdpError::Io)
    }

    /// Deterministic text profile over recorded spans (top-`top_n`
    /// stages by time, spill/fallback hotspots, critical path).
    pub fn profile_report(&self, top_n: usize) -> String {
        self.tracer.profile_report(top_n)
    }

    /// Mark a dataset for caching (Spark `persist`).
    pub fn persist(&self, ds: &Dataset) {
        self.cache.register(ds.id);
    }

    /// Explicitly drop a cached dataset (paper §3.2 cleanup registration).
    pub fn unpersist(&self, ds: &Dataset) {
        self.cache.unpersist(ds.id);
    }

    /// Materialize a dataset.
    pub fn collect(&self, ds: &Dataset) -> Result<Partitioned> {
        let ds = self.prepare(ds);
        self.eval(&ds)
    }

    /// Materialize and flatten to driver-side rows.
    pub fn collect_rows(&self, ds: &Dataset) -> Result<Vec<Row>> {
        Ok(self.collect(ds)?.rows())
    }

    /// Materialize without the optimizer pass — for callers that already
    /// optimized the plan they hold (the streaming runtime optimizes its
    /// template once at compile; re-walking the rewriter on every
    /// micro-batch would cost latency for zero rewrites).
    pub(crate) fn collect_unprepared(&self, ds: &Dataset) -> Result<Partitioned> {
        self.eval(ds)
    }

    pub fn count(&self, ds: &Dataset) -> Result<usize> {
        Ok(self.collect(ds)?.num_rows())
    }

    /// Run the logical optimizer over the plan (when enabled), charging
    /// rewrite counts to stats. Persisted datasets are passed as rewrite
    /// barriers so cache registrations stay attached to their node ids.
    fn prepare(&self, ds: &Dataset) -> Dataset {
        if !self.cfg.optimize {
            return ds.clone();
        }
        let out = optimizer::optimize(ds, &|id| self.cache.is_registered(id));
        let total = out.counts.total();
        if total > 0 {
            self.charge(Stat::PlanRewrites, total);
            self.rewrites.lock().unwrap().merge(&out.counts);
        }
        out.plan
    }

    /// Accumulated per-rule rewrite counts for this context.
    pub fn rewrite_counts(&self) -> RewriteCounts {
        *self.rewrites.lock().unwrap()
    }

    /// Drain the recorded task trace.
    pub fn take_trace(&self) -> TaskTrace {
        std::mem::take(&mut *self.trace.lock().unwrap())
    }

    // ------------------------------------------------------------------
    // evaluation
    // ------------------------------------------------------------------

    fn eval(&self, ds: &Dataset) -> Result<Partitioned> {
        if self.cache.is_registered(ds.id) {
            if let Some(hit) = self.cache.get(ds.id) {
                self.charge(Stat::CacheHits, 1);
                return Ok(hit);
            }
            self.charge(Stat::CacheMisses, 1);
        }
        let out = self.eval_uncached(ds)?;
        if self.cache.is_registered(ds.id) {
            self.cache.put(ds.id, out.clone());
        }
        Ok(out)
    }

    fn eval_uncached(&self, ds: &Dataset) -> Result<Partitioned> {
        match &*ds.node {
            Plan::Source { data, .. } => Ok(data.clone()),
            Plan::Map { .. }
            | Plan::Filter { .. }
            | Plan::FilterExpr { .. }
            | Plan::Project { .. }
            | Plan::FlatMap { .. }
            | Plan::MapPartitions { .. } => self.eval_narrow_chain(ds),
            Plan::ReduceByKey { input, key, reduce, num_parts, key_col } => {
                let inp = self.eval(input)?;
                self.exec_reduce_by_key(ds, inp, key.clone(), reduce.clone(), *num_parts, *key_col)
            }
            Plan::Distinct { input, num_parts } => {
                let inp = self.eval(input)?;
                self.exec_distinct(ds, inp, *num_parts)
            }
            Plan::Join { left, right, lkey, rkey, kind, num_parts, schema, lkey_col, rkey_col } => {
                let l = self.eval(left)?;
                let r = self.eval(right)?;
                self.exec_join(
                    ds,
                    l,
                    r,
                    lkey.clone(),
                    rkey.clone(),
                    *kind,
                    *num_parts,
                    schema.clone(),
                    *lkey_col,
                    *rkey_col,
                )
            }
            Plan::Union { inputs } => {
                let mut parts: Vec<PartRef> = Vec::new();
                for i in inputs {
                    parts.extend(self.eval(i)?.parts);
                }
                Ok(Partitioned { schema: ds.schema.clone(), parts })
            }
            Plan::Sort { input, cmp } => {
                let inp = self.eval(input)?;
                self.exec_sort(ds, inp, cmp.clone())
            }
            Plan::Repartition { input, num_parts } => {
                let inp = self.eval(input)?;
                self.exec_repartition(ds, inp, *num_parts)
            }
        }
    }

    /// Walk up through narrow ops, collecting the fused pipeline. The chain
    /// breaks at sources, wide ops, and *registered cache points* (a cached
    /// intermediate must be materialized so siblings can reuse it).
    fn eval_narrow_chain(&self, ds: &Dataset) -> Result<Partitioned> {
        let mut steps: Vec<Step> = Vec::new();
        let mut cur = ds.clone();
        let base = loop {
            // a registered cache point below the top must materialize
            if cur.id != ds.id && self.cache.is_registered(cur.id) {
                break cur;
            }
            match &*cur.node {
                Plan::Map { input, f, .. } => {
                    steps.push(Step::Map(f.clone()));
                    cur = input.clone();
                }
                Plan::Filter { input, f } => {
                    steps.push(Step::Filter(f.clone()));
                    cur = input.clone();
                }
                // expression-backed steps stay structured so the stage can
                // run them column-at-a-time (closure steps are opaque and
                // always execute row-wise); each carries its highest
                // referenced column so out-of-range references fail as
                // structured errors instead of index panics
                Plan::FilterExpr { input, expr } => {
                    let bound = expr::max_col(expr).map(|(idx, name)| ColBound {
                        idx,
                        name: name.to_string(),
                        op: "filter predicate",
                    });
                    steps.push(Step::FilterExpr(expr.clone(), bound));
                    cur = input.clone();
                }
                Plan::Project { input, cols, .. } => {
                    let bound = cols.iter().copied().max().map(|idx| ColBound {
                        idx,
                        name: if idx < input.schema.len() {
                            input.schema.field(idx).0.to_string()
                        } else {
                            "?".to_string()
                        },
                        op: "projection",
                    });
                    steps.push(Step::Project(cols.clone(), bound));
                    cur = input.clone();
                }
                Plan::FlatMap { input, f, .. } => {
                    steps.push(Step::FlatMap(f.clone()));
                    cur = input.clone();
                }
                Plan::MapPartitions { input, f, .. } => {
                    steps.push(Step::PartWise(f.clone()));
                    cur = input.clone();
                }
                _ => break cur,
            }
        };
        steps.reverse();
        let base_data = self.eval(&base)?;
        self.run_partition_stage(ds.id, base_data, ds.schema.clone(), steps)
    }

    fn run_partition_stage(
        &self,
        stage_id: u64,
        input: Partitioned,
        schema: super::row::SchemaRef,
        steps: Vec<Step>,
    ) -> Result<Partitioned> {
        let span = self.tracer.begin(SpanKind::Stage, || format!("narrow#{stage_id}"), None);
        let _scope = self.tracer.scope(span);
        self.charge(Stat::StagesRun, 1);
        let steps = Arc::new(steps);
        let fusion = self.cfg.fusion;
        let vectorize = self.cfg.vectorize;
        // a structured chain (all FilterExpr/Project) can execute on a
        // remote worker; opaque closures cannot cross the process
        // boundary, so those stages stay local and count a fallback
        let desc = match &self.dist {
            Some(_) if fusion => NarrowDesc::try_build(&steps, vectorize).map(Arc::new),
            _ => None,
        };
        if self.dist.is_some() && desc.is_none() {
            self.charge(Stat::DistFallbacks, 1);
        }
        let tasks: Vec<_> = input
            .parts
            .iter()
            .enumerate()
            .map(|(ti, part)| {
                let part = part.clone();
                let steps = steps.clone();
                let pool = desc.as_ref().and_then(|_| self.dist.clone());
                let desc = desc.clone();
                let tracer = self.tracer.clone();
                move || -> Result<ChainOut> {
                    let mut d = DistCounters::default();
                    if let (Some(pool), Some(desc)) = (pool.as_ref(), desc.as_ref()) {
                        // an Err here is a worker-*reported* compute error
                        // — deterministic, so re-running locally below
                        // surfaces the identical error; Ok(None) means no
                        // live workers remain
                        if let Ok(Some((rows, vec_batches, vec_fallbacks))) =
                            pool.narrow(&tracer, ti, &part, desc, &mut d)
                        {
                            return Ok(ChainOut { rows, vec_batches, vec_fallbacks, dist: d });
                        }
                    }
                    let mut out = if fusion && vectorize {
                        apply_chain_vectorized(&part, &steps)?
                    } else if fusion {
                        ChainOut::rows_only(apply_chain_fused(&part, &steps)?)
                    } else {
                        // materialize-per-step ablation stays row-wise
                        ChainOut::rows_only(apply_chain_materialized(&part, &steps)?)
                    };
                    out.dist = d;
                    Ok(out)
                }
            })
            .collect();
        let outs = collect_results(self.run_tasks(stage_id, tasks, &input)?)?;
        let (mut batches, mut fallbacks) = (0u64, 0u64);
        let mut dc = DistCounters::default();
        let parts = outs
            .into_iter()
            .map(|o| {
                batches += o.vec_batches;
                fallbacks += o.vec_fallbacks;
                dc.merge(&o.dist);
                Arc::new(o.rows)
            })
            .collect();
        if batches > 0 {
            self.charge(Stat::VectorizedBatches, batches);
        }
        if fallbacks > 0 {
            self.charge(Stat::VectorizedFallbacks, fallbacks);
        }
        self.charge_dist(&dc);
        Ok(Partitioned { schema, parts })
    }

    /// Charge one stage's aggregated distribution counters — driver-side,
    /// inside the stage span's scope, so the global-equals-sum-of-spans
    /// trace invariant holds for the dist stats too. Worker failovers are
    /// real task retries (the lineage machinery re-running a task's work
    /// elsewhere), so they charge [`Stat::TasksRetried`].
    fn charge_dist(&self, d: &DistCounters) {
        if d.remote > 0 {
            self.charge(Stat::DistTasksRemote, d.remote);
        }
        if d.tx > 0 {
            self.charge(Stat::DistBytesTx, d.tx);
        }
        if d.rx > 0 {
            self.charge(Stat::DistBytesRx, d.rx);
        }
        if d.lost > 0 {
            self.charge(Stat::DistWorkersLost, d.lost);
        }
        if d.retried > 0 {
            self.charge(Stat::TasksRetried, d.retried);
        }
    }

    /// Run tasks with retry + fault injection + stats + tracing.
    fn run_tasks<T, F>(&self, stage_id: u64, tasks: Vec<F>, input: &Partitioned) -> Result<Vec<T>>
    where
        T: Send + 'static + TaskMeasure,
        F: FnOnce() -> T + Send + 'static,
    {
        let fault = self.fault.clone();
        let max_attempts = self.cfg.max_task_attempts;
        let input_rows: Vec<u64> = input.parts.iter().map(|p| p.len() as u64).collect();
        // the caller's stage span (current on this thread) parents the
        // per-task spans the pool workers open; each task scope-enters
        // its span so in-task charges (governor admissions) land on it
        let stage_span = self.tracer.current();
        let wrapped: Vec<_> = tasks
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let fault = fault.clone();
                let tracer = self.tracer.clone();
                move || -> (T, f64, u32, u64) {
                    // injected faults strike before the body runs, so the
                    // task body itself executes exactly once (FnOnce —
                    // spill-consuming tasks move their segments)
                    let mut attempt = 0u32;
                    while fault
                        .as_ref()
                        .map(|f| f.should_fail(attempt))
                        .unwrap_or(false)
                    {
                        attempt += 1;
                        if attempt >= max_attempts {
                            panic!("task failed after {attempt} attempts (injected)");
                        }
                    }
                    let span = tracer.begin(
                        SpanKind::Task,
                        || format!("task#{stage_id}.{i}"),
                        Some(stage_span),
                    );
                    let _scope = tracer.scope(span);
                    let start = Instant::now();
                    let out = t();
                    (out, start.elapsed().as_secs_f64(), attempt, span)
                }
            })
            .collect();
        let n = wrapped.len();
        let results = self.pool.map(wrapped);
        let mut outs = Vec::with_capacity(n);
        let mut trace_rows = Vec::new();
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Some((v, dur, retries, span)) => {
                    self.charge_span(span, Stat::TasksLaunched, 1 + retries as u64);
                    self.charge_span(span, Stat::TasksRetried, retries as u64);
                    self.charge_span(span, Stat::TaskNanos, (dur * 1e9) as u64);
                    self.charge_span(span, Stat::RowsRead, input_rows.get(i).copied().unwrap_or(0));
                    self.charge_span(span, Stat::RowsWritten, v.out_rows());
                    if self.cfg.record_trace {
                        // real measured bytes, so trace replay through the
                        // cluster simulator sees per-task costs and skew
                        let (output_bytes, shuffle_bytes) = v.measured();
                        trace_rows.push(TaskRecord {
                            stage_id,
                            duration_secs: dur,
                            input_rows: input_rows.get(i).copied().unwrap_or(0),
                            output_bytes,
                            shuffle_bytes,
                        });
                    }
                    outs.push(v);
                }
                None => {
                    return Err(DdpError::TaskFailed {
                        attempts: max_attempts,
                        msg: format!("stage {stage_id}, task {i}"),
                    })
                }
            }
        }
        if self.cfg.record_trace {
            self.trace.lock().unwrap().extend(trace_rows);
        }
        Ok(outs)
    }

    // ------------------------------------------------------------------
    // wide (shuffle) operators
    // ------------------------------------------------------------------

    /// Charge shuffle/spill stats for the map side of a wide operator.
    /// `row_bytes` (uncompressed) is identical whether a set spilled or
    /// stayed resident, so shuffle-byte assertions hold in both modes.
    fn charge_shuffle(&self, sets: &[BucketSet], with_records: bool) {
        let mut moved = 0u64;
        let mut recs = 0u64;
        let mut spill_bytes = 0u64;
        let mut spill_files = 0u64;
        for s in sets {
            moved += s.row_bytes();
            recs += s.records();
            if let Some(fb) = s.spilled_file_bytes() {
                spill_bytes += fb;
                spill_files += 1;
            }
        }
        self.charge(Stat::ShuffleBytes, moved);
        if with_records {
            self.charge(Stat::ShuffleRecords, recs);
        }
        if spill_files > 0 {
            self.charge(Stat::SpillBytes, spill_bytes);
            self.charge(Stat::SpillFiles, spill_files);
        }
    }

    /// Hash-bucket every input partition into `num_parts` buckets (the map
    /// side of a shuffle), charging shuffle bytes to stats. Each task's
    /// buckets stay resident under a governor reservation or spill to
    /// disk (out-of-core mode) — the reduce side reads both identically.
    fn shuffle_buckets(
        &self,
        stage_id: u64,
        input: &Partitioned,
        num_parts: usize,
        key: super::dataset::KeyFn,
        ship: ShipKey,
    ) -> Result<Vec<BucketSet>> {
        let gov = self.governor.clone();
        let dir = self.spill.clone();
        // whole-row-keyed map sides can run on a worker (the hash is a
        // function of the row bytes, identical in any process); opaque
        // key closures pin the map side local
        let dist = match ship {
            ShipKey::WholeRow => self.dist.clone(),
            ShipKey::Opaque => None,
        };
        if self.dist.is_some() && dist.is_none() {
            self.charge(Stat::DistFallbacks, 1);
        }
        let tasks: Vec<_> = input
            .parts
            .iter()
            .enumerate()
            .map(|(ti, part)| {
                let part = part.clone();
                let key = key.clone();
                let gov = gov.clone();
                let dir = dir.clone();
                let dist = dist.clone();
                let tracer = self.tracer.clone();
                move || -> Result<ShuffleOut> {
                    let mut d = DistCounters::default();
                    if let Some(pool) = dist.as_ref() {
                        if let Ok(Some(buckets)) =
                            pool.bucket(&tracer, ti, &part, num_parts, None, &mut d)
                        {
                            return Ok(ShuffleOut {
                                set: BucketSet::build(&gov, &dir, buckets)?,
                                batched: false,
                                dist: d,
                            });
                        }
                    }
                    let mut buckets: Vec<Vec<Row>> = (0..num_parts).map(|_| Vec::new()).collect();
                    for row in part.iter() {
                        let k = key(row);
                        buckets[bucket_of(&k, num_parts)].push(row.clone());
                    }
                    Ok(ShuffleOut {
                        set: BucketSet::build(&gov, &dir, buckets)?,
                        batched: false,
                        dist: d,
                    })
                }
            })
            .collect();
        let outs = collect_results(self.run_tasks(stage_id, tasks, input)?)?;
        let mut dc = DistCounters::default();
        for o in &outs {
            dc.merge(&o.dist);
        }
        self.charge_dist(&dc);
        let sets: Vec<BucketSet> = outs.into_iter().map(|o| o.set).collect();
        self.charge_shuffle(&sets, true);
        Ok(sets)
    }

    /// Column-keyed variant of [`Self::shuffle_buckets`]: each map
    /// partition forms a typed [`ColumnBatch`], hashes the key column
    /// ([`super::row::Column::hash_values`] reproduces [`field_hash`]
    /// slot for slot), gathers per-bucket row indices in input order and
    /// splits with a column-level take — no row materialization at the
    /// shuffle boundary. A partition that cannot form a typed batch
    /// (ragged arity, mixed-type column, key column out of range) falls
    /// back to the row path — same buckets, same bytes — and counts a
    /// `vectorized_shuffle_fallbacks`.
    fn shuffle_buckets_by_col(
        &self,
        stage_id: u64,
        input: &Partitioned,
        num_parts: usize,
        key: super::dataset::KeyFn,
        key_col: usize,
    ) -> Result<Vec<BucketSet>> {
        let gov = self.governor.clone();
        let dir = self.spill.clone();
        let tasks: Vec<_> = input
            .parts
            .iter()
            .enumerate()
            .map(|(ti, part)| {
                let part = part.clone();
                let key = key.clone();
                let gov = gov.clone();
                let dir = dir.clone();
                let dist = self.dist.clone();
                let tracer = self.tracer.clone();
                move || -> Result<ShuffleOut> {
                    // remote map side ships rows and receives the same
                    // buckets the local paths would build (row transport;
                    // the governor/spill decision stays driver-side)
                    let mut d = DistCounters::default();
                    if let Some(pool) = dist.as_ref() {
                        if let Ok(Some(buckets)) =
                            pool.bucket(&tracer, ti, &part, num_parts, Some(key_col), &mut d)
                        {
                            return Ok(ShuffleOut {
                                set: BucketSet::build(&gov, &dir, buckets)?,
                                batched: false,
                                dist: d,
                            });
                        }
                    }
                    if let Some(batches) = batch_buckets(&part, num_parts, key_col) {
                        return Ok(ShuffleOut {
                            set: BucketSet::build_batches(&gov, &dir, batches)?,
                            batched: true,
                            dist: d,
                        });
                    }
                    let mut buckets: Vec<Vec<Row>> = (0..num_parts).map(|_| Vec::new()).collect();
                    for row in part.iter() {
                        let k = key(row);
                        buckets[bucket_of(&k, num_parts)].push(row.clone());
                    }
                    Ok(ShuffleOut {
                        set: BucketSet::build(&gov, &dir, buckets)?,
                        batched: false,
                        dist: d,
                    })
                }
            })
            .collect();
        let outs = collect_results(self.run_tasks(stage_id, tasks, input)?)?;
        self.charge_shuffle_vectorization(&outs);
        let mut dc = DistCounters::default();
        for o in &outs {
            dc.merge(&o.dist);
        }
        self.charge_dist(&dc);
        let sets: Vec<BucketSet> = outs.into_iter().map(|o| o.set).collect();
        self.charge_shuffle(&sets, true);
        Ok(sets)
    }

    /// Charge the batch-native shuffle counters for one column-keyed map
    /// side: one `vectorized_shuffle_batches` per partition whose buckets
    /// traveled as column batches, one `vectorized_shuffle_fallbacks` per
    /// partition that was eligible but fell back to row transport.
    fn charge_shuffle_vectorization(&self, outs: &[ShuffleOut]) {
        let batched = outs.iter().filter(|o| o.batched).count() as u64;
        // a map side that executed remotely used row transport by design
        // — that is remote dispatch, not a vectorization fallback
        let remote = outs.iter().filter(|o| !o.batched && o.dist.remote > 0).count() as u64;
        let fell = outs.len() as u64 - batched - remote;
        if batched > 0 {
            self.charge(Stat::VectorizedShuffleBatches, batched);
        }
        if fell > 0 {
            self.charge(Stat::VectorizedShuffleFallbacks, fell);
        }
    }

    fn exec_reduce_by_key(
        &self,
        ds: &Dataset,
        input: Partitioned,
        key: super::dataset::KeyFn,
        reduce: super::dataset::ReduceFn,
        num_parts: usize,
        key_col: Option<usize>,
    ) -> Result<Partitioned> {
        let span = self.tracer.begin(SpanKind::Stage, || format!("reduce#{}", ds.id), None);
        let _scope = self.tracer.scope(span);
        self.charge(Stat::StagesRun, 1);
        // map-side combine, then bucket (reserve-or-spill per task).
        // When the key is a declared column and vectorization is on, the
        // partition is hash-split by a column-level gather and combined
        // per bucket slice, and the buckets travel as column batches.
        // The combine folds the user's reduce closure — unserializable,
        // so this map side never ships (skipping the combine would change
        // the fold's association and with it the bytes).
        if self.dist.is_some() {
            self.charge(Stat::DistFallbacks, 1);
        }
        let col_key = key_col.filter(|_| self.cfg.vectorize);
        let combine_key = key.clone();
        let combine_reduce = reduce.clone();
        let gov = self.governor.clone();
        let dir = self.spill.clone();
        let tasks: Vec<_> = input
            .parts
            .iter()
            .map(|part| {
                let part = part.clone();
                let key = combine_key.clone();
                let reduce = combine_reduce.clone();
                let gov = gov.clone();
                let dir = dir.clone();
                move || -> Result<ShuffleOut> {
                    if let Some(kc) = col_key {
                        if let Some(batches) = reduce_map_batches(&part, num_parts, kc, &reduce) {
                            return Ok(ShuffleOut {
                                set: BucketSet::build_batches(&gov, &dir, batches)?,
                                batched: true,
                                dist: DistCounters::default(),
                            });
                        }
                    }
                    let mut local: HashMap<Field, Row> = HashMap::new();
                    for row in part.iter() {
                        let k = key(row);
                        match local.remove(&k) {
                            Some(acc) => {
                                local.insert(k, reduce(acc, row));
                            }
                            None => {
                                local.insert(k, row.clone());
                            }
                        }
                    }
                    let mut buckets: Vec<Vec<Row>> = (0..num_parts).map(|_| Vec::new()).collect();
                    for (k, row) in local {
                        buckets[bucket_of(&k, num_parts)].push(row);
                    }
                    Ok(ShuffleOut {
                        set: BucketSet::build(&gov, &dir, buckets)?,
                        batched: false,
                        dist: DistCounters::default(),
                    })
                }
            })
            .collect();
        let outs = collect_results(self.run_tasks(ds.id, tasks, &input)?)?;
        if col_key.is_some() {
            self.charge_shuffle_vectorization(&outs);
        }
        let bucketed: Vec<BucketSet> = outs.into_iter().map(|o| o.set).collect();
        self.charge_shuffle(&bucketed, false);

        // reduce side: merge-read each bucket's segments in partition
        // order (memory or disk — same rows, same order)
        let exchanged = transpose_segments(bucketed, num_parts);
        let reduce2 = reduce.clone();
        let key2 = key.clone();
        let rtasks: Vec<_> = exchanged
            .into_iter()
            .map(|segments| {
                let reduce = reduce2.clone();
                let key = key2.clone();
                move || -> Result<Vec<Row>> {
                    let mut agg: HashMap<Field, Row> = HashMap::new();
                    let fold = |k: Field, row: Row, agg: &mut HashMap<Field, Row>| {
                        match agg.remove(&k) {
                            Some(acc) => {
                                agg.insert(k, reduce(acc, &row));
                            }
                            None => {
                                agg.insert(k, row);
                            }
                        }
                    };
                    for seg in segments {
                        match seg.take_data()? {
                            // batch segments (resident or decoded from
                            // colbin) fold slot-wise: the key comes off
                            // the key column, not a materialized row
                            SegmentData::Batch(batch)
                                if col_key.is_some_and(|kc| kc < batch.num_cols()) =>
                            {
                                let kc = col_key.unwrap();
                                for i in 0..batch.len() {
                                    fold(batch.cols[kc].field_at(i), batch.row_at(i), &mut agg);
                                }
                            }
                            data => {
                                let rows = match data {
                                    SegmentData::Rows(rows) => rows,
                                    SegmentData::Batch(batch) => batch.into_rows(),
                                };
                                for row in rows {
                                    let k = key(&row);
                                    fold(k, row, &mut agg);
                                }
                            }
                        }
                    }
                    // canonical key order: output must not depend on the
                    // hash map's population (the optimizer may legally
                    // change it by pre-filtering groups)
                    let mut pairs: Vec<(Field, Row)> = agg.into_iter().collect();
                    pairs.sort_by(|a, b| a.0.canonical_cmp(&b.0));
                    Ok(pairs.into_iter().map(|(_, r)| r).collect())
                }
            })
            .collect();
        let empty = Partitioned { schema: ds.schema.clone(), parts: vec![] };
        let outs = collect_results(self.run_tasks(ds.id, rtasks, &empty)?)?;
        Ok(Partitioned {
            schema: ds.schema.clone(),
            parts: outs.into_iter().map(Arc::new).collect(),
        })
    }

    fn exec_distinct(&self, ds: &Dataset, input: Partitioned, num_parts: usize) -> Result<Partitioned> {
        let span = self.tracer.begin(SpanKind::Stage, || format!("distinct#{}", ds.id), None);
        let _scope = self.tracer.scope(span);
        self.charge(Stat::StagesRun, 1);
        let key: super::dataset::KeyFn = Arc::new(whole_row_key);
        let bucketed = self.shuffle_buckets(ds.id, &input, num_parts, key, ShipKey::WholeRow)?;
        let exchanged = transpose_segments(bucketed, num_parts);
        let tasks: Vec<_> = exchanged
            .into_iter()
            .map(|segments| {
                move || -> Result<Vec<Row>> {
                    // first-seen order over segments in partition order —
                    // identical to the in-memory path. Rows are shared
                    // (`Arc`) between the seen-set and the output so each
                    // distinct row is held once, then unwrapped copy-free
                    // once the set drops (same trick as the streaming
                    // Distinct frontier).
                    let mut seen: std::collections::HashSet<Arc<Row>> =
                        std::collections::HashSet::new();
                    let mut out: Vec<Arc<Row>> = Vec::new();
                    for seg in segments {
                        for row in seg.take_rows()? {
                            let row = Arc::new(row);
                            if seen.insert(row.clone()) {
                                out.push(row);
                            }
                        }
                    }
                    drop(seen);
                    Ok(out
                        .into_iter()
                        .map(|r| Arc::try_unwrap(r).unwrap_or_else(|a| (*a).clone()))
                        .collect())
                }
            })
            .collect();
        let empty = Partitioned { schema: ds.schema.clone(), parts: vec![] };
        let outs = collect_results(self.run_tasks(ds.id, tasks, &empty)?)?;
        Ok(Partitioned {
            schema: ds.schema.clone(),
            parts: outs.into_iter().map(Arc::new).collect(),
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_join(
        &self,
        ds: &Dataset,
        left: Partitioned,
        right: Partitioned,
        lkey: super::dataset::KeyFn,
        rkey: super::dataset::KeyFn,
        kind: JoinKind,
        num_parts: usize,
        schema: super::row::SchemaRef,
        lkey_col: Option<usize>,
        rkey_col: Option<usize>,
    ) -> Result<Partitioned> {
        let span = self.tracer.begin(SpanKind::Stage, || format!("join#{}", ds.id), None);
        let _scope = self.tracer.scope(span);
        self.charge(Stat::StagesRun, 1);
        // each side shuffles batch-native when its key is a declared
        // column (the build/probe side still materializes rows — join
        // output is concatenated rows either way)
        let lb = match lkey_col.filter(|_| self.cfg.vectorize) {
            Some(kc) => self.shuffle_buckets_by_col(ds.id, &left, num_parts, lkey.clone(), kc)?,
            None => {
                self.shuffle_buckets(ds.id, &left, num_parts, lkey.clone(), ShipKey::Opaque)?
            }
        };
        let rb = match rkey_col.filter(|_| self.cfg.vectorize) {
            Some(kc) => self.shuffle_buckets_by_col(ds.id, &right, num_parts, rkey.clone(), kc)?,
            None => {
                self.shuffle_buckets(ds.id, &right, num_parts, rkey.clone(), ShipKey::Opaque)?
            }
        };
        let lex = transpose_segments(lb, num_parts);
        let rex = transpose_segments(rb, num_parts);
        let right_width = right.schema.len();
        let tasks: Vec<_> = lex
            .into_iter()
            .zip(rex)
            .map(|(lsegs, rsegs)| {
                let lkey = lkey.clone();
                let rkey = rkey.clone();
                move || -> Result<Vec<Row>> {
                    // build from right, probe from left; right rows are
                    // materialized once per bucket (memory or disk)
                    let mut rrows: Vec<Row> = Vec::new();
                    for seg in rsegs {
                        rrows.extend(seg.take_rows()?);
                    }
                    let mut table: HashMap<Field, Vec<usize>> = HashMap::new();
                    for (i, row) in rrows.iter().enumerate() {
                        table.entry(rkey(row)).or_default().push(i);
                    }
                    let mut out = Vec::new();
                    for seg in lsegs {
                        for lrow in seg.take_rows()? {
                            let k = lkey(&lrow);
                            match table.get(&k) {
                                Some(matches) => {
                                    for &i in matches {
                                        let mut fields = lrow.fields.clone();
                                        fields.extend(rrows[i].fields.iter().cloned());
                                        out.push(Row::new(fields));
                                    }
                                }
                                None => {
                                    if kind == JoinKind::Left {
                                        let mut fields = lrow.fields.clone();
                                        fields.extend((0..right_width).map(|_| Field::Null));
                                        out.push(Row::new(fields));
                                    }
                                }
                            }
                        }
                    }
                    Ok(out)
                }
            })
            .collect();
        let empty = Partitioned { schema: schema.clone(), parts: vec![] };
        let outs = collect_results(self.run_tasks(ds.id, tasks, &empty)?)?;
        Ok(Partitioned { schema, parts: outs.into_iter().map(Arc::new).collect() })
    }

    /// External merge sort. The map stage stably pre-sorts each input
    /// partition into a governed [`SortedRun`] — resident under a
    /// reservation, or spilled as chunked colbin segments when the
    /// budget refuses — so per-partition sort cost and skew show up as
    /// real per-task output/shuffle bytes in the trace instead of being
    /// hidden inside one driver-side gather. The merge stage then
    /// streams a k-way merge over run cursors (heap keyed by the user
    /// comparator, ties broken by run index), which reproduces the
    /// stable sort of the concatenation byte for byte at any budget.
    /// Output stays a single totally-ordered partition — the `Sort`
    /// contract every consumer (and the streaming drain) relies on.
    fn exec_sort(
        &self,
        ds: &Dataset,
        input: Partitioned,
        cmp: super::dataset::CmpFn,
    ) -> Result<Partitioned> {
        // map stage: per-partition sorted runs
        let map_span = self.tracer.begin(SpanKind::Stage, || format!("sort#{}", ds.id), None);
        let map_scope = self.tracer.scope(map_span);
        self.charge(Stat::StagesRun, 1);
        // the user comparator is an opaque closure — sort never ships
        if self.dist.is_some() {
            self.charge(Stat::DistFallbacks, 1);
        }
        let gov = self.governor.clone();
        let dir = self.spill.clone();
        let sort_cmp = cmp.clone();
        let tasks: Vec<_> = input
            .parts
            .iter()
            .map(|part| {
                let part = part.clone();
                let cmp = sort_cmp.clone();
                let gov = gov.clone();
                let dir = dir.clone();
                move || -> Result<SortedRun> {
                    let mut rows = (*part).clone();
                    rows.sort_by(|a, b| cmp(a, b));
                    SortedRun::build(&gov, &dir, rows)
                }
            })
            .collect();
        let runs =
            SortedRunSet::from_runs(collect_results(self.run_tasks(ds.id, tasks, &input)?)?);
        // the runs are this stage's exchange to the merge side: charge
        // them to shuffle_bytes so the global counter reconciles with the
        // per-task TaskRecord shuffle bytes (mode-independent — row bytes
        // are identical whether a run spilled or stayed resident)
        self.charge(Stat::ShuffleBytes, runs.row_bytes());
        self.charge(Stat::SortRuns, runs.num_runs() as u64);
        let (spill_bytes, spill_files) = (runs.spilled_bytes(), runs.spilled_files());
        if spill_files > 0 {
            self.charge(Stat::SortSpillBytes, spill_bytes);
            self.charge(Stat::SpillBytes, spill_bytes);
            self.charge(Stat::SpillFiles, spill_files);
        }
        drop(map_scope);

        // merge stage: one reduce task streams the k-way merge
        let merge_span =
            self.tracer.begin(SpanKind::Stage, || format!("sort_merge#{}", ds.id), None);
        let _merge_scope = self.tracer.scope(merge_span);
        self.charge(Stat::StagesRun, 1);
        let merge_tasks = vec![move || -> Result<Vec<Row>> { runs.merge(&gov, &*cmp) }];
        let empty = Partitioned { schema: ds.schema.clone(), parts: vec![] };
        let outs = collect_results(self.run_tasks(ds.id, merge_tasks, &empty)?)?;
        Ok(Partitioned {
            schema: ds.schema.clone(),
            parts: outs.into_iter().map(Arc::new).collect(),
        })
    }

    fn exec_repartition(&self, ds: &Dataset, input: Partitioned, num_parts: usize) -> Result<Partitioned> {
        let span = self.tracer.begin(SpanKind::Stage, || format!("repartition#{}", ds.id), None);
        let _scope = self.tracer.scope(span);
        self.charge(Stat::StagesRun, 1);
        // round-robin by row hash for determinism
        let key: super::dataset::KeyFn = Arc::new(whole_row_key);
        let bucketed = self.shuffle_buckets(ds.id, &input, num_parts, key, ShipKey::WholeRow)?;
        let exchanged = transpose_segments(bucketed, num_parts);
        let mut parts: Vec<PartRef> = Vec::with_capacity(num_parts);
        for segments in exchanged {
            let mut rows = Vec::new();
            for seg in segments {
                rows.extend(seg.take_rows()?);
            }
            parts.push(Arc::new(rows));
        }
        Ok(Partitioned { schema: ds.schema.clone(), parts })
    }
}

// ---------------------------------------------------------------------
// narrow-chain machinery
// ---------------------------------------------------------------------

/// The highest column index a structured step references, with that
/// column's display name — checked against each input row / batch width
/// so an out-of-range reference surfaces as a structured engine error
/// on every execution path (vectorized, fused, materialized) instead of
/// an index panic. `None` bound (column-free expression) skips the
/// check entirely.
pub(crate) struct ColBound {
    pub(crate) idx: usize,
    pub(crate) name: String,
    pub(crate) op: &'static str,
}

impl ColBound {
    #[inline]
    fn check(&self, width: usize) -> Result<()> {
        if self.idx < width {
            Ok(())
        } else {
            Err(DdpError::engine(format!(
                "{} references column {} ('{}'), but the input has only {} column(s)",
                self.op, self.idx, self.name, width
            )))
        }
    }
}

pub(crate) enum Step {
    Map(super::dataset::MapFn),
    Filter(super::dataset::PredFn),
    /// structured predicate — vectorizable
    FilterExpr(Arc<expr::Expr>, Option<ColBound>),
    /// structured column selection — vectorizable
    Project(Vec<usize>, Option<ColBound>),
    FlatMap(super::dataset::FlatMapFn),
    PartWise(super::dataset::PartFn),
}

/// True for steps the columnar evaluator can run over a whole batch.
fn is_vectorizable(s: &Step) -> bool {
    matches!(s, Step::FilterExpr(..) | Step::Project(..))
}

/// A narrow stage task's output: the rows plus vectorization counters
/// (how many column batches ran, how many segments fell back to rows)
/// and the task's distribution counters (zero when it ran in-process).
pub(crate) struct ChainOut {
    pub(crate) rows: Vec<Row>,
    pub(crate) vec_batches: u64,
    pub(crate) vec_fallbacks: u64,
    pub(crate) dist: DistCounters,
}

impl ChainOut {
    pub(crate) fn rows_only(rows: Vec<Row>) -> ChainOut {
        ChainOut { rows, vec_batches: 0, vec_fallbacks: 0, dist: DistCounters::default() }
    }
}

/// Vectorized execution: maximal runs of expression-backed steps
/// ([`Step::FilterExpr`] / [`Step::Project`]) evaluate column-at-a-time
/// over a [`ColumnBatch`]; opaque-closure steps run row-wise between
/// batch segments (the closure-boundary fallback rule). A vectorizable
/// segment whose input cannot form a typed batch — ragged arity or a
/// column mixing concrete types — falls back to the row path for that
/// segment and counts a `vec_fallbacks`. Byte-identical to
/// [`apply_chain_fused`] by construction: the kernels share the scalar
/// core with `expr::eval` (pinned by the vectorize differential suite).
pub(crate) fn apply_chain_vectorized(part: &[Row], steps: &[Step]) -> Result<ChainOut> {
    if steps.is_empty() {
        return Ok(ChainOut::rows_only(part.to_vec()));
    }
    let mut batches = 0u64;
    let mut fallbacks = 0u64;
    let mut cur: Option<Vec<Row>> = None;
    let mut i = 0;
    while i < steps.len() {
        if is_vectorizable(&steps[i]) {
            let start = i;
            while i < steps.len() && is_vectorizable(&steps[i]) {
                i += 1;
            }
            let run = &steps[start..i];
            let input: &[Row] = cur.as_deref().unwrap_or(part);
            if input.is_empty() {
                // trivially vectorized: filters/projections of nothing
                batches += 1;
                cur = Some(Vec::new());
                continue;
            }
            match ColumnBatch::try_from_rows(input) {
                Some(mut batch) => {
                    batches += 1;
                    for step in run {
                        batch = match step {
                            Step::FilterExpr(e, bound) => {
                                if let Some(b) = bound {
                                    b.check(batch.num_cols())?;
                                }
                                let keep = expr::eval_mask(e, &batch);
                                batch.filter(&keep)
                            }
                            Step::Project(cols, bound) => {
                                if let Some(b) = bound {
                                    b.check(batch.num_cols())?;
                                }
                                batch.project(cols)
                            }
                            _ => unreachable!("segment holds only vectorizable steps"),
                        };
                    }
                    cur = Some(batch.into_rows());
                }
                None => {
                    fallbacks += 1;
                    let mut out = Vec::with_capacity(input.len());
                    for row in input {
                        push_rowwise(row.clone(), run, &mut out)?;
                    }
                    cur = Some(out);
                }
            }
        } else if let Step::PartWise(f) = &steps[i] {
            let input = cur.take().unwrap_or_else(|| part.to_vec());
            cur = Some(f(input));
            i += 1;
        } else {
            // a maximal run of opaque row-wise closures
            let start = i;
            while i < steps.len()
                && !is_vectorizable(&steps[i])
                && !matches!(steps[i], Step::PartWise(_))
            {
                i += 1;
            }
            let run = &steps[start..i];
            let input: &[Row] = cur.as_deref().unwrap_or(part);
            let mut out = Vec::with_capacity(input.len());
            for row in input {
                push_rowwise(row.clone(), run, &mut out)?;
            }
            cur = Some(out);
        }
    }
    Ok(ChainOut {
        rows: cur.unwrap_or_else(|| part.to_vec()),
        vec_batches: batches,
        vec_fallbacks: fallbacks,
        dist: DistCounters::default(),
    })
}

/// Fused execution: rows stream through consecutive row-wise steps without
/// intermediate vectors; `PartWise` steps materialize (they need the whole
/// partition).
pub(crate) fn apply_chain_fused(part: &[Row], steps: &[Step]) -> Result<Vec<Row>> {
    if steps.is_empty() {
        return Ok(part.to_vec());
    }
    // `None` means we are still reading straight from the input partition.
    let mut cur: Option<Vec<Row>> = None;
    let mut i = 0;
    while i < steps.len() {
        // a maximal run of row-wise steps fuses into one pass
        let start = i;
        while i < steps.len() && !matches!(steps[i], Step::PartWise(_)) {
            i += 1;
        }
        if i > start {
            let run = &steps[start..i];
            let input: &[Row] = cur.as_deref().unwrap_or(part);
            let mut out = Vec::with_capacity(input.len());
            for row in input {
                push_rowwise(row.clone(), run, &mut out)?;
            }
            cur = Some(out);
        }
        if i < steps.len() {
            if let Step::PartWise(f) = &steps[i] {
                let input = cur.take().unwrap_or_else(|| part.to_vec());
                cur = Some(f(input));
            }
            i += 1;
        }
    }
    Ok(cur.unwrap_or_else(|| part.to_vec()))
}

#[inline]
fn push_rowwise(row: Row, ops: &[Step], out: &mut Vec<Row>) -> Result<()> {
    match ops.split_first() {
        None => out.push(row),
        Some((op, rest)) => match op {
            Step::Map(f) => push_rowwise(f(&row), rest, out)?,
            Step::Filter(f) => {
                if f(&row) {
                    push_rowwise(row, rest, out)?;
                }
            }
            Step::FilterExpr(e, bound) => {
                if let Some(b) = bound {
                    b.check(row.len())?;
                }
                if expr::truthy(&expr::eval(e, &row)) {
                    push_rowwise(row, rest, out)?;
                }
            }
            Step::Project(cols, bound) => {
                if let Some(b) = bound {
                    b.check(row.len())?;
                }
                push_rowwise(
                    Row::new(cols.iter().map(|&i| row.get(i).clone()).collect()),
                    rest,
                    out,
                )?;
            }
            Step::FlatMap(f) => {
                for r in f(&row) {
                    push_rowwise(r, rest, out)?;
                }
            }
            Step::PartWise(_) => unreachable!("PartWise handled at run level"),
        },
    }
    Ok(())
}

/// Ablation mode: materialize the full partition after every step.
fn apply_chain_materialized(part: &[Row], steps: &[Step]) -> Result<Vec<Row>> {
    let mut cur: Vec<Row> = part.to_vec();
    for step in steps {
        cur = match step {
            Step::Map(f) => cur.iter().map(|r| f(r)).collect(),
            Step::Filter(f) => cur.into_iter().filter(|r| f(r)).collect(),
            Step::FilterExpr(e, bound) => {
                let mut out = Vec::with_capacity(cur.len());
                for r in cur {
                    if let Some(b) = bound {
                        b.check(r.len())?;
                    }
                    if expr::truthy(&expr::eval(e, &r)) {
                        out.push(r);
                    }
                }
                out
            }
            Step::Project(cols, bound) => {
                let mut out = Vec::with_capacity(cur.len());
                for r in &cur {
                    if let Some(b) = bound {
                        b.check(r.len())?;
                    }
                    out.push(Row::new(cols.iter().map(|&i| r.get(i).clone()).collect()));
                }
                out
            }
            Step::FlatMap(f) => cur.iter().flat_map(|r| f(r)).collect(),
            Step::PartWise(f) => f(cur),
        };
    }
    Ok(cur)
}

// ---------------------------------------------------------------------
// hashing / bucket helpers
// ---------------------------------------------------------------------

/// Deterministic key hash used for shuffle bucket assignment. Shared with
/// the streaming runtime (`engine::stream`), which must reproduce the
/// exact bucket layout the batch executor would produce.
pub(crate) fn field_hash(f: &Field) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    f.hash(&mut h);
    h.finish()
}

/// Bucket for a precomputed shuffle-key hash. Single definition shared
/// by the row path, the batch-native path (whose per-slot hashes come
/// from [`super::row::Column::hash_values`]) and the streaming runtime —
/// a drift here would silently split keys across reducers.
pub(crate) fn hash_bucket(h: u64, num_parts: usize) -> usize {
    (h % num_parts as u64) as usize
}

/// Bucket for a shuffle key [`Field`].
pub(crate) fn bucket_of(key: &Field, num_parts: usize) -> usize {
    hash_bucket(field_hash(key), num_parts)
}

/// One map partition's shuffle output plus how it traveled (batch-native
/// or row transport) — feeds the `vectorized_shuffle_*` counters.
struct ShuffleOut {
    set: BucketSet,
    batched: bool,
    dist: DistCounters,
}

/// How a shuffle map side's key travels for remote dispatch: a
/// whole-row hash and a declared key column are reproducible in any
/// process; an opaque key closure pins the map side to this one.
enum ShipKey {
    WholeRow,
    Opaque,
}

/// Batch-native map side of a column-keyed shuffle: transpose the
/// partition into a typed [`ColumnBatch`], hash the key column, gather
/// each bucket's row indices in input order, then split with a
/// column-level take. `None` = fall back to row transport (the partition
/// cannot form a typed batch, or the key column is out of range — the
/// row path would panic on the same out-of-range access, so the check
/// only reroutes, it never changes behavior).
fn batch_buckets(part: &[Row], num_parts: usize, key_col: usize) -> Option<Vec<ColumnBatch>> {
    let batch = ColumnBatch::try_from_rows(part)?;
    if batch.is_empty() {
        // trivially batch-native: every bucket of nothing is empty
        return Some((0..num_parts).map(|_| ColumnBatch::new(Vec::new(), 0)).collect());
    }
    if key_col >= batch.num_cols() {
        return None;
    }
    let idxs = expr::bucket_indices(&batch.cols[key_col], num_parts);
    Some(idxs.iter().map(|ix| batch.take(ix)).collect())
}

/// Batch-native map side of a column-keyed reduce: hash-split the
/// partition with a column-level gather (as [`batch_buckets`]), then run
/// the map-side combine over each bucket's batch slice, reading keys off
/// the key column. Per-key fold order equals input order — exactly the
/// row path's fold — so combined rows are identical; only the transport
/// representation changes. `None` = fall back to the row path (untyped
/// input, key column out of range, or a reducer whose output rows cannot
/// re-form a typed batch).
fn reduce_map_batches(
    part: &[Row],
    num_parts: usize,
    key_col: usize,
    reduce: &super::dataset::ReduceFn,
) -> Option<Vec<ColumnBatch>> {
    let batch = ColumnBatch::try_from_rows(part)?;
    if batch.is_empty() {
        return Some((0..num_parts).map(|_| ColumnBatch::new(Vec::new(), 0)).collect());
    }
    if key_col >= batch.num_cols() {
        return None;
    }
    let idxs = expr::bucket_indices(&batch.cols[key_col], num_parts);
    let mut out = Vec::with_capacity(num_parts);
    for ix in &idxs {
        let slice = batch.take(ix);
        let kcol = &slice.cols[key_col];
        let mut local: HashMap<Field, Row> = HashMap::new();
        for i in 0..slice.len() {
            let k = kcol.field_at(i);
            match local.remove(&k) {
                Some(acc) => {
                    local.insert(k, reduce(acc, &slice.row_at(i)));
                }
                None => {
                    local.insert(k, slice.row_at(i));
                }
            }
        }
        let combined: Vec<Row> = local.into_values().collect();
        out.push(ColumnBatch::try_from_rows(&combined)?);
    }
    Some(out)
}

/// Deterministic whole-row hash (distinct / repartition bucketing).
pub(crate) fn row_hash(r: &Row) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    r.hash(&mut h);
    h.finish()
}

/// The whole-row shuffle key `Distinct` and `Repartition` bucket on.
/// Single definition on purpose: the streaming runtime reproduces batch
/// bucket layouts with it, so a drift here would silently desynchronize
/// stream drains from batch output.
pub(crate) fn whole_row_key(r: &Row) -> Field {
    Field::I64(row_hash(r) as i64)
}

// ---------------------------------------------------------------------
// task output measurement (real bytes into TaskRecords)
// ---------------------------------------------------------------------

/// Measured bytes of a task's output, recorded into [`TaskRecord`]s so
/// the cluster simulator replays real per-task costs (and sees partition
/// skew) instead of zeros.
pub(crate) trait TaskMeasure {
    /// `(output_bytes, shuffle_bytes)` for this task's output.
    fn measured(&self) -> (u64, u64);

    /// Rows this task produced (feeds the `rows_written` counter; `0`
    /// where the output is not row-shaped, e.g. a sorted-run handle).
    fn out_rows(&self) -> u64 {
        0
    }
}

impl TaskMeasure for Vec<Row> {
    fn measured(&self) -> (u64, u64) {
        let bytes = self.iter().map(|r| r.approx_size() as u64).sum();
        (bytes, 0)
    }

    fn out_rows(&self) -> u64 {
        self.len() as u64
    }
}

impl TaskMeasure for ChainOut {
    fn measured(&self) -> (u64, u64) {
        self.rows.measured()
    }

    fn out_rows(&self) -> u64 {
        self.rows.len() as u64
    }
}

impl TaskMeasure for BucketSet {
    fn measured(&self) -> (u64, u64) {
        // bucketed map-side output *is* the task's shuffle contribution
        (self.row_bytes(), self.row_bytes())
    }

    fn out_rows(&self) -> u64 {
        self.records()
    }
}

impl TaskMeasure for ShuffleOut {
    fn measured(&self) -> (u64, u64) {
        // byte accounting is transport-independent (batch sets report
        // exact row-equivalent bytes), so traces don't see the toggle
        self.set.measured()
    }

    fn out_rows(&self) -> u64 {
        self.set.out_rows()
    }
}

impl TaskMeasure for SortedRun {
    fn measured(&self) -> (u64, u64) {
        // a sorted run is handed whole to the merge stage: it is both
        // this task's output and its contribution to the sort exchange —
        // per-partition, so the simulator sees sort skew
        (self.row_bytes(), self.row_bytes())
    }
}

impl<T: TaskMeasure> TaskMeasure for Result<T> {
    fn measured(&self) -> (u64, u64) {
        match self {
            Ok(v) => v.measured(),
            Err(_) => (0, 0),
        }
    }

    fn out_rows(&self) -> u64 {
        match self {
            Ok(v) => v.out_rows(),
            Err(_) => 0,
        }
    }
}

/// Surface the first in-task error (spill IO) as the stage's failure.
fn collect_results<T>(outs: Vec<Result<T>>) -> Result<Vec<T>> {
    outs.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::{FieldType, Schema};
    use crate::row;

    fn ctx() -> Arc<EngineCtx> {
        EngineCtx::new(EngineConfig { workers: 2, ..Default::default() })
    }

    fn nums(n: i64, parts: usize) -> Dataset {
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        Dataset::from_rows("nums", schema, (0..n).map(|i| row!(i)).collect(), parts)
    }

    #[test]
    fn map_filter_collect() {
        let c = ctx();
        let ds = nums(100, 4);
        let out = ds
            .map(ds.schema.clone(), |r| row!(r.get(0).as_i64().unwrap() * 2))
            .filter(|r| r.get(0).as_i64().unwrap() % 4 == 0);
        let mut rows: Vec<i64> = c
            .collect_rows(&out)
            .unwrap()
            .iter()
            .map(|r| r.get(0).as_i64().unwrap())
            .collect();
        rows.sort_unstable();
        assert_eq!(rows, (0..100).map(|i| i * 2).filter(|v| v % 4 == 0).collect::<Vec<_>>());
    }

    #[test]
    fn flat_map_expands() {
        let c = ctx();
        let ds = nums(10, 2);
        let out = ds.flat_map(ds.schema.clone(), |r| {
            let v = r.get(0).as_i64().unwrap();
            vec![row!(v), row!(v + 1000)]
        });
        assert_eq!(c.count(&out).unwrap(), 20);
    }

    #[test]
    fn map_partitions_sees_whole_partition() {
        let c = ctx();
        let ds = nums(100, 4);
        let out = ds.map_partitions(ds.schema.clone(), |rows| {
            // emit one row with the partition size
            vec![row!(rows.len() as i64)]
        });
        let sizes: i64 = c
            .collect_rows(&out)
            .unwrap()
            .iter()
            .map(|r| r.get(0).as_i64().unwrap())
            .sum();
        assert_eq!(sizes, 100);
    }

    #[test]
    fn reduce_by_key_counts() {
        let c = ctx();
        let schema = Schema::new(vec![("k", FieldType::Str), ("n", FieldType::I64)]);
        let rows = (0..90)
            .map(|i| row!(format!("k{}", i % 3), 1i64))
            .collect();
        let ds = Dataset::from_rows("kv", schema.clone(), rows, 5);
        let out = ds.reduce_by_key(
            4,
            |r| r.get(0).clone(),
            |acc, r| row!(acc.get(0).as_str().unwrap(), acc.get(1).as_i64().unwrap() + r.get(1).as_i64().unwrap()),
        );
        let rows = c.collect_rows(&out).unwrap();
        assert_eq!(rows.len(), 3);
        for r in rows {
            assert_eq!(r.get(1).as_i64(), Some(30));
        }
    }

    #[test]
    fn distinct_dedupes() {
        let c = ctx();
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        let rows = (0..100).map(|i| row!(i % 10)).collect();
        let ds = Dataset::from_rows("dups", schema, rows, 4);
        assert_eq!(c.count(&ds.distinct(3)).unwrap(), 10);
    }

    #[test]
    fn inner_and_left_join() {
        let c = ctx();
        let ls = Schema::new(vec![("id", FieldType::I64), ("l", FieldType::Str)]);
        let rs = Schema::new(vec![("id2", FieldType::I64), ("r", FieldType::Str)]);
        let left = Dataset::from_rows(
            "l",
            ls,
            vec![row!(1i64, "a"), row!(2i64, "b"), row!(3i64, "c")],
            2,
        );
        let right = Dataset::from_rows("r", rs, vec![row!(1i64, "x"), row!(3i64, "y"), row!(3i64, "z")], 2);
        let out_schema = Schema::of_names(&["id", "l", "id2", "r"]);
        let inner = left.join(
            &right,
            out_schema.clone(),
            JoinKind::Inner,
            3,
            |r| r.get(0).clone(),
            |r| r.get(0).clone(),
        );
        let rows = c.collect_rows(&inner).unwrap();
        assert_eq!(rows.len(), 3); // (1,x), (3,y), (3,z)

        let leftj = left.join(
            &right,
            out_schema,
            JoinKind::Left,
            3,
            |r| r.get(0).clone(),
            |r| r.get(0).clone(),
        );
        let rows = c.collect_rows(&leftj).unwrap();
        assert_eq!(rows.len(), 4); // + (2, null)
        let nulls = rows.iter().filter(|r| r.get(2).is_null()).count();
        assert_eq!(nulls, 1);
    }

    #[test]
    fn union_and_sort() {
        let c = ctx();
        let a = nums(5, 2);
        let b = nums(5, 2);
        let u = a.union(&[b]);
        assert_eq!(c.count(&u).unwrap(), 10);
        let sorted = u.sort_by(|x, y| {
            x.get(0).as_i64().unwrap().cmp(&y.get(0).as_i64().unwrap())
        });
        let rows = c.collect_rows(&sorted).unwrap();
        let vals: Vec<i64> = rows.iter().map(|r| r.get(0).as_i64().unwrap()).collect();
        assert_eq!(vals, vec![0, 0, 1, 1, 2, 2, 3, 3, 4, 4]);
    }

    #[test]
    fn repartition_changes_layout_not_data() {
        let c = ctx();
        let ds = nums(50, 2);
        let rp = ds.repartition(7);
        let out = c.collect(&rp).unwrap();
        assert_eq!(out.parts.len(), 7);
        assert_eq!(out.num_rows(), 50);
        let mut vals: Vec<i64> = out.rows().iter().map(|r| r.get(0).as_i64().unwrap()).collect();
        vals.sort_unstable();
        assert_eq!(vals, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn caching_avoids_recompute() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let c = ctx();
        let ds = nums(10, 2);
        let calls = Arc::new(AtomicU32::new(0));
        let calls2 = calls.clone();
        let mapped = ds.map(ds.schema.clone(), move |r| {
            calls2.fetch_add(1, Ordering::SeqCst);
            r.clone()
        });
        c.persist(&mapped);
        let d1 = mapped.filter(|_| true);
        let d2 = mapped.filter(|_| false);
        c.count(&d1).unwrap();
        c.count(&d2).unwrap();
        assert_eq!(calls.load(Ordering::SeqCst), 10, "map ran once thanks to cache");
        c.unpersist(&mapped);
        c.count(&d1).unwrap();
        assert_eq!(calls.load(Ordering::SeqCst), 20, "recomputed after unpersist");
    }

    #[test]
    fn fused_and_materialized_agree() {
        let mk = |fusion: bool| {
            let c = EngineCtx::new(EngineConfig { workers: 2, fusion, ..Default::default() });
            let ds = nums(200, 4);
            let out = ds
                .map(ds.schema.clone(), |r| row!(r.get(0).as_i64().unwrap() + 1))
                .filter(|r| r.get(0).as_i64().unwrap() % 3 != 0)
                .flat_map(ds.schema.clone(), |r| vec![r.clone(), r.clone()])
                .map_partitions(ds.schema.clone(), |rows| {
                    rows.into_iter().take(5).collect()
                });
            let mut v: Vec<i64> = c
                .collect_rows(&out)
                .unwrap()
                .iter()
                .map(|r| r.get(0).as_i64().unwrap())
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(mk(true), mk(false));
    }

    #[test]
    fn fault_injection_retries_succeed() {
        let cfg = EngineConfig { workers: 2, max_task_attempts: 4, ..Default::default() };
        let c = EngineCtx::with_faults(cfg, FaultInjector::new(7, 0.5, 2));
        let ds = nums(100, 8);
        let out = ds.map(ds.schema.clone(), |r| r.clone());
        assert_eq!(c.count(&out).unwrap(), 100);
        assert!(c.stats.snapshot().tasks_retried > 0, "some retries should have happened");
    }

    #[test]
    fn fault_injection_exhaustion_errors() {
        let cfg = EngineConfig { workers: 2, max_task_attempts: 2, ..Default::default() };
        // always fail first 5 attempts > max 2 attempts
        let c = EngineCtx::with_faults(cfg, FaultInjector::new(7, 1.0, 5));
        let ds = nums(10, 1);
        let out = ds.map(ds.schema.clone(), |r| r.clone());
        assert!(c.count(&out).is_err());
    }

    #[test]
    fn trace_recorded_when_enabled() {
        let c = EngineCtx::new(EngineConfig { workers: 2, record_trace: true, ..Default::default() });
        let ds = nums(100, 4);
        c.count(&ds.map(ds.schema.clone(), |r| r.clone())).unwrap();
        let trace = c.take_trace();
        assert_eq!(trace.len(), 4);
        assert!(trace.iter().all(|t| t.duration_secs >= 0.0));
    }

    #[test]
    fn shuffle_bytes_accounted() {
        let c = ctx();
        let ds = nums(100, 4);
        c.count(&ds.distinct(4)).unwrap();
        assert!(c.stats.snapshot().shuffle_bytes > 0);
    }

    #[test]
    fn trace_records_real_bytes() {
        let c = EngineCtx::new(EngineConfig { workers: 2, record_trace: true, ..Default::default() });
        let ds = nums(100, 4);
        c.count(&ds.map(ds.schema.clone(), |r| r.clone()).distinct(3)).unwrap();
        let trace = c.take_trace();
        assert!(
            trace.iter().any(|t| t.output_bytes > 0),
            "task records must charge real output bytes"
        );
        assert!(
            trace.iter().any(|t| t.shuffle_bytes > 0),
            "shuffle map tasks must record their shuffle contribution"
        );
        // narrow map tasks move no shuffle bytes
        assert!(trace.iter().any(|t| t.shuffle_bytes == 0 && t.output_bytes > 0));
    }

    fn wide_chain_layout(budget: Option<usize>) -> (Vec<Vec<Row>>, crate::engine::stats::StatsSnapshot) {
        let c = EngineCtx::new(EngineConfig {
            workers: 2,
            memory_budget_bytes: budget,
            ..Default::default()
        });
        let schema = Schema::new(vec![("k", FieldType::I64), ("pad", FieldType::Str)]);
        let rows = (0..400i64).map(|i| row!(i % 37, format!("{i:0>64}"))).collect();
        let ds = Dataset::from_rows("kv", schema, rows, 5);
        let out = ds
            .distinct(4)
            .reduce_by_key_col(3, 0, |acc: Row, _r: &Row| acc)
            .repartition(6);
        let parts = c
            .collect(&out)
            .unwrap()
            .parts
            .iter()
            .map(|p| (**p).clone())
            .collect();
        let snap = c.stats.snapshot();
        assert_eq!(c.governor.reserved_bytes(), 0, "all reservations released after collect");
        (parts, snap)
    }

    #[test]
    fn forced_spill_is_byte_identical_to_in_memory() {
        let (mem_parts, mem_stats) = wide_chain_layout(None);
        let (spill_parts, spill_stats) = wide_chain_layout(Some(1024));
        assert_eq!(mem_parts, spill_parts, "spilling must not change collected output");
        assert_eq!(mem_stats.spill_bytes, 0);
        assert_eq!(mem_stats.spill_files, 0);
        assert!(spill_stats.spill_bytes > 0, "tiny budget must spill");
        assert!(spill_stats.spill_files > 0);
        assert_eq!(
            mem_stats.shuffle_bytes, spill_stats.shuffle_bytes,
            "shuffle accounting is mode-independent"
        );
    }

    #[test]
    fn filter_expr_and_project_execute() {
        use crate::engine::expr::{BinOp, Expr};
        let c = ctx();
        let schema = Schema::new(vec![("x", FieldType::I64), ("y", FieldType::I64)]);
        let rows = (0..50i64).map(|i| row!(i, i * 10)).collect();
        let ds = Dataset::from_rows("xy", schema, rows, 3);
        let pred = Expr::Binary(
            BinOp::Ge,
            Box::new(Expr::Col(0, "x".into())),
            Box::new(Expr::Lit(Field::F64(40.0))),
        );
        let out = ds.filter_expr(pred).project(vec![1]);
        let got = c.collect_rows(&out).unwrap();
        assert_eq!(got.len(), 10);
        assert!(got.iter().all(|r| r.fields.len() == 1));
        assert!(got.iter().all(|r| r.get(0).as_i64().unwrap() >= 400));
        assert_eq!(out.schema.names(), vec!["y"]);
    }

    #[test]
    fn optimizer_toggle_preserves_output_and_cuts_shuffle() {
        use crate::engine::expr::{BinOp, Expr};
        let run = |optimize: bool| {
            let c = EngineCtx::new(EngineConfig { workers: 2, optimize, ..Default::default() });
            let schema = Schema::new(vec![("k", FieldType::I64), ("v", FieldType::Str)]);
            let rows = (0..200i64).map(|i| row!(i % 20, format!("padding-{i:06}"))).collect();
            let ds = Dataset::from_rows("kv", schema, rows, 4);
            let agg = ds.reduce_by_key_col(4, 0, |acc, _| acc);
            let pred = Expr::Binary(
                BinOp::Lt,
                Box::new(Expr::Col(0, "k".into())),
                Box::new(Expr::Lit(Field::F64(3.0))),
            );
            let out = agg.filter_expr(pred);
            let parts: Vec<Vec<Row>> = c
                .collect(&out)
                .unwrap()
                .parts
                .iter()
                .map(|p| (**p).clone())
                .collect();
            (parts, c.stats.snapshot())
        };
        let (on_parts, on_stats) = run(true);
        let (off_parts, off_stats) = run(false);
        assert_eq!(on_parts, off_parts, "optimizer changed collected output");
        assert!(on_stats.plan_rewrites > 0);
        assert_eq!(off_stats.plan_rewrites, 0);
        assert!(
            on_stats.shuffle_bytes < off_stats.shuffle_bytes,
            "pushdown should cut shuffle bytes ({} vs {})",
            on_stats.shuffle_bytes,
            off_stats.shuffle_bytes
        );
    }

    #[test]
    fn vectorize_toggle_identical_and_counted() {
        use crate::engine::expr::{BinOp, Expr};
        let run = |vectorize: bool| {
            let c = EngineCtx::new(EngineConfig { workers: 2, vectorize, ..Default::default() });
            let schema = Schema::new(vec![("x", FieldType::I64), ("y", FieldType::I64)]);
            let rows = (0..120i64).map(|i| row!(i, i * 3)).collect();
            let ds = Dataset::from_rows("xy", schema, rows, 4);
            let pred = Expr::Binary(
                BinOp::Gt,
                Box::new(Expr::Col(1, "y".into())),
                Box::new(Expr::Lit(Field::I64(30))),
            );
            let out = ds.filter_expr(pred).project(vec![1, 0]);
            let parts: Vec<Vec<Row>> = c
                .collect(&out)
                .unwrap()
                .parts
                .iter()
                .map(|p| (**p).clone())
                .collect();
            (parts, c.stats.snapshot())
        };
        let (on_parts, on_stats) = run(true);
        let (off_parts, off_stats) = run(false);
        assert_eq!(on_parts, off_parts, "vectorization changed collected output");
        assert!(on_stats.vectorized_batches > 0, "columnar path must have run");
        assert_eq!(on_stats.vectorized_fallbacks, 0, "typed input needs no fallback");
        assert_eq!(off_stats.vectorized_batches, 0, "row path must not count batches");
        assert_eq!(off_stats.vectorized_fallbacks, 0);
    }

    #[test]
    fn mixed_type_column_falls_back_to_rows() {
        use crate::engine::expr::{BinOp, Expr};
        // explicit vectorize=true: the default honours DDP_VECTORIZE, and
        // this test must observe the fallback counter under any CI matrix
        let c = EngineCtx::new(EngineConfig { workers: 2, vectorize: true, ..Default::default() });
        let schema = Schema::new(vec![("v", FieldType::Any)]);
        // one column alternating I64/Str: no typed batch possible
        let rows = (0..40i64)
            .map(|i| if i % 2 == 0 { row!(i) } else { row!(format!("s{i}")) })
            .collect();
        let ds = Dataset::from_rows("mixed", schema, rows, 2);
        let pred = Expr::Binary(
            BinOp::Ne,
            Box::new(Expr::Col(0, "v".into())),
            Box::new(Expr::Lit(Field::I64(0))),
        );
        let got = c.collect_rows(&ds.filter_expr(pred)).unwrap();
        assert_eq!(got.len(), 39); // only the literal 0 row is dropped
        let snap = c.stats.snapshot();
        assert!(snap.vectorized_fallbacks > 0, "mixed column must fall back");
        assert_eq!(snap.vectorized_batches, 0);
    }

    #[test]
    fn reduce_output_order_is_canonical() {
        let c = ctx();
        let schema = Schema::new(vec![("k", FieldType::I64), ("n", FieldType::I64)]);
        let rows = (0..60i64).map(|i| row!(i % 6, 1i64)).collect();
        let ds = Dataset::from_rows("kv", schema, rows, 3);
        let agg = ds.reduce_by_key_col(
            1,
            0,
            |acc, r| row!(acc.get(0).as_i64().unwrap(), acc.get(1).as_i64().unwrap() + r.get(1).as_i64().unwrap()),
        );
        let keys: Vec<i64> = c
            .collect_rows(&agg)
            .unwrap()
            .iter()
            .map(|r| r.get(0).as_i64().unwrap())
            .collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "single-bucket reduce output sorted by key");
    }

    #[test]
    fn column_keyed_shuffle_is_batch_native_and_identical() {
        let run = |vectorize: bool| {
            let c = EngineCtx::new(EngineConfig { workers: 2, vectorize, ..Default::default() });
            let schema = Schema::new(vec![("k", FieldType::I64), ("v", FieldType::I64)]);
            let rows = (0..240i64).map(|i| row!(i % 17, i)).collect();
            let ds = Dataset::from_rows("kv", schema, rows, 5);
            let agg = ds.reduce_by_key_col(4, 0, |acc, r| {
                row!(
                    acc.get(0).as_i64().unwrap(),
                    acc.get(1).as_i64().unwrap() + r.get(1).as_i64().unwrap()
                )
            });
            let rs = Schema::new(vec![("k2", FieldType::I64), ("w", FieldType::I64)]);
            let right =
                Dataset::from_rows("r", rs, (0..17i64).map(|i| row!(i, i * 100)).collect(), 3);
            let out = agg.join_on(
                &right,
                Schema::of_names(&["k", "v", "k2", "w"]),
                JoinKind::Inner,
                3,
                0,
                0,
            );
            let parts: Vec<Vec<Row>> = c
                .collect(&out)
                .unwrap()
                .parts
                .iter()
                .map(|p| (**p).clone())
                .collect();
            (parts, c.stats.snapshot())
        };
        let (on_parts, on) = run(true);
        let (off_parts, off) = run(false);
        assert_eq!(on_parts, off_parts, "batch-native shuffle changed collected output");
        assert!(on.vectorized_shuffle_batches > 0, "column-keyed wide ops must move batches");
        assert_eq!(on.vectorized_shuffle_fallbacks, 0, "typed key columns need no fallback");
        assert_eq!(off.vectorized_shuffle_batches, 0, "row mode must not count batches");
        assert_eq!(off.vectorized_shuffle_fallbacks, 0, "row mode is never eligible");
    }

    #[test]
    fn mixed_key_column_shuffle_falls_back_to_rows() {
        let c = EngineCtx::new(EngineConfig { workers: 2, vectorize: true, ..Default::default() });
        let schema = Schema::new(vec![("k", FieldType::Any), ("n", FieldType::I64)]);
        // key column mixes I64 and Str: no typed batch is possible, so
        // the transport must fall back — and still reduce correctly
        let rows = (0..60i64)
            .map(|i| {
                if i % 2 == 0 {
                    row!(i % 6, 1i64)
                } else {
                    row!(format!("s{}", i % 5), 1i64)
                }
            })
            .collect();
        let ds = Dataset::from_rows("kv", schema, rows, 3);
        let agg = ds.reduce_by_key_col(2, 0, |acc, r| {
            row!(
                acc.get(0).clone(),
                acc.get(1).as_i64().unwrap() + r.get(1).as_i64().unwrap()
            )
        });
        let rows = c.collect_rows(&agg).unwrap();
        // even rows: keys 0,2,4 (10 each); odd rows: keys s0..s4 (6 each)
        assert_eq!(rows.len(), 8);
        let total: i64 = rows.iter().map(|r| r.get(1).as_i64().unwrap()).sum();
        assert_eq!(total, 60);
        let snap = c.stats.snapshot();
        assert!(snap.vectorized_shuffle_fallbacks > 0, "mixed key column must fall back");
        assert_eq!(snap.vectorized_shuffle_batches, 0);
    }

    #[test]
    fn null_key_and_placeholder_key_stay_distinct_through_batch_shuffle() {
        let c = EngineCtx::new(EngineConfig { workers: 2, vectorize: true, ..Default::default() });
        let schema = Schema::new(vec![("k", FieldType::I64), ("n", FieldType::I64)]);
        // typed key column whose null slots store the 0 placeholder:
        // nulls must group apart from the real 0s (mask is authoritative
        // in the key hash, never the placeholder value)
        let rows = (0..40i64)
            .map(|i| if i % 2 == 0 { row!(0i64, 1i64) } else { row!(Field::Null, 1i64) })
            .collect();
        let ds = Dataset::from_rows("kv", schema, rows, 4);
        let agg = ds.reduce_by_key_col(1, 0, |acc, r| {
            row!(
                acc.get(0).clone(),
                acc.get(1).as_i64().unwrap() + r.get(1).as_i64().unwrap()
            )
        });
        let out = c.collect_rows(&agg).unwrap();
        assert_eq!(out.len(), 2, "null keys and I64(0) keys are different groups");
        // canonical key order puts the null group first
        assert!(out[0].get(0).is_null());
        assert_eq!(out[0].get(1).as_i64(), Some(20));
        assert_eq!(out[1].get(0).as_i64(), Some(0));
        assert_eq!(out[1].get(1).as_i64(), Some(20));
        let snap = c.stats.snapshot();
        assert!(snap.vectorized_shuffle_batches > 0, "typed key column must move batches");
        assert_eq!(snap.vectorized_shuffle_fallbacks, 0);
    }
}
