//! Static plan analyzer: schema/type inference, expression checking and
//! an optimizer invariant guard — the *validate-then-execute* layer.
//!
//! [`analyze`] walks a [`Plan`] DAG once (memoized over shared subtrees,
//! so cost is proportional to plan size, never data size) and:
//!
//! 1. infers a per-column [`ColType`] (the Bool/I64/F64/Str/Bytes/Any
//!    lattice plus nullability) for every node — trusting the declared
//!    [`SchemaRef`](super::row::SchemaRef) at opaque closures
//!    (`Map`/`FlatMap`/`MapPartitions`)
//!    and computing exactly through the structured operators;
//! 2. type-checks every [`Expr`] against its inferred input schema —
//!    column indices in range, operand type compatibility, function
//!    arity — producing structured [`Diagnostic`]s instead of runtime
//!    panics;
//! 3. optionally runs the rule-based [`lint`] framework over the
//!    analyzed DAG (dead columns, single-consumer persists, pushdown
//!    blockers, vectorization-fallback predictions).
//!
//! The same inference doubles as the **optimizer invariant guard**
//! ([`assert_rewrite_preserves_schema`]): after every rewrite rule fires
//! the optimizer re-infers the pre/post plan and panics on any schema
//! drift, turning every differential suite into a machine-checked proof
//! that rewrites are schema-preserving. The guard is on in debug builds
//! and whenever `DDP_ANALYZE=1` (see [`guard_enabled`]).
//!
//! ## Diagnostic codes
//!
//! | code | severity | meaning |
//! |------|----------|---------|
//! | E001 | error    | column index out of range (expr / project / key) |
//! | E002 | error    | function arity mismatch |
//! | E003 | error    | comparison between incompatible types (always false) |
//! | E004 | error    | arithmetic / negation on a non-numeric type (always null) |
//! | E005 | error    | join key columns have mismatched types (never hash-match) |
//! | E006 | error    | union inputs disagree on column count |
//! | E007 | error    | join declares a schema narrower/wider than left+right |
//! | E008 | error    | pipe contract: required column missing on an input (§3.8) |
//! | E009 | error    | pipe contract: column declared with a conflicting type |
//! | W101 | warning  | duplicate column names in a schema |
//! | W102 | warning  | ordered comparison with a null literal (always false) |
//! | W103 | warning  | persisted dataset with a single consumer |
//! | W104 | warning  | columns never referenced downstream (suggest projection) |
//! | W105 | warning  | union column mixes concrete types (degrades to `any`) |
//! | W106 | warning  | non-string argument to a string function (always null) |
//! | N201 | note     | opaque closure blocks predicate pushdown |
//! | N202 | note     | vectorized segment may fall back row-wise (`any` columns) |

pub mod lint;

use super::dataset::{Dataset, JoinKind, Plan};
use super::expr::{BinOp, Expr, Func, UnOp};
use super::row::{Field, FieldType, Schema};
use crate::json::Value;
use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;
use std::sync::OnceLock;

// ----------------------------- diagnostics ---------------------------

/// Diagnostic severity; only `Error` aborts execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Error,
    Warning,
    Note,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
            Severity::Note => "note",
        }
    }
}

/// One analyzer finding: a stable code, a severity, the plan-node path
/// it anchors to (`join/left/filter_expr`) and a human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub code: &'static str,
    pub severity: Severity,
    pub path: String,
    pub message: String,
}

impl Diagnostic {
    fn new(code: &'static str, severity: Severity, path: &str, message: String) -> Diagnostic {
        Diagnostic { code, severity, path: path.to_string(), message }
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("code", Value::from(self.code)),
            ("severity", Value::from(self.severity.name())),
            ("path", Value::from(self.path.as_str())),
            ("message", Value::from(self.message.as_str())),
        ])
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}] at {}: {}",
            self.severity.name(),
            self.code,
            self.path,
            self.message
        )
    }
}

// ------------------------------ lattice ------------------------------

/// A column's inferred type: the base [`FieldType`] lattice point plus
/// nullability. `Any` is the lattice top (unknown / mixed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColType {
    pub base: FieldType,
    pub nullable: bool,
}

impl ColType {
    pub fn new(base: FieldType, nullable: bool) -> ColType {
        ColType { base, nullable }
    }

    /// Lattice top: anything, possibly null.
    pub fn any() -> ColType {
        ColType { base: FieldType::Any, nullable: true }
    }

    /// From a declared schema column. Declared types admit `Null`
    /// (`Schema::validate_row` lets nulls pass), so declared columns are
    /// conservatively nullable.
    pub fn declared(base: FieldType) -> ColType {
        ColType { base, nullable: true }
    }

    /// The type of a literal value.
    pub fn of_field(f: &Field) -> ColType {
        match f {
            Field::Null => ColType::any(),
            Field::Bool(_) => ColType::new(FieldType::Bool, false),
            Field::I64(_) => ColType::new(FieldType::I64, false),
            Field::F64(_) => ColType::new(FieldType::F64, false),
            Field::Str(_) => ColType::new(FieldType::Str, false),
            Field::Bytes(_) => ColType::new(FieldType::Bytes, false),
        }
    }

    /// Least upper bound: equal bases keep the base, anything else
    /// degrades to `Any`; nullability unions.
    pub fn lub(&self, other: &ColType) -> ColType {
        let base = if self.base == other.base { self.base } else { FieldType::Any };
        ColType { base, nullable: self.nullable || other.nullable }
    }

    /// Whether a runtime value is admissible under this type. `Null` is
    /// always admissible (matching `FieldType::matches`).
    pub fn admits(&self, f: &Field) -> bool {
        self.base.matches(f)
    }

    fn is_numeric(&self) -> bool {
        matches!(self.base, FieldType::I64 | FieldType::F64 | FieldType::Any)
    }
}

impl fmt::Display for ColType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", self.base.name(), if self.nullable { "?" } else { "" })
    }
}

/// One inferred column: name plus [`ColType`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ColInfo {
    pub name: String,
    pub ty: ColType,
}

/// An inferred node schema.
pub type ColSchema = Arc<Vec<ColInfo>>;

fn schema_cols(schema: &Schema) -> Vec<ColInfo> {
    (0..schema.len())
        .map(|i| {
            let (name, ty) = schema.field(i);
            ColInfo { name: name.to_string(), ty: ColType::declared(ty) }
        })
        .collect()
}

/// Render an inferred schema as `name: type, ...` (diagnostics, guard
/// failure messages, `ddp lint` output).
pub fn render_cols(cols: &[ColInfo]) -> String {
    cols.iter()
        .map(|c| format!("{}: {}", c.name, c.ty))
        .collect::<Vec<_>>()
        .join(", ")
}

// ------------------------------ analysis -----------------------------

/// One analyzed plan node, collected for the lint pass.
pub struct NodeMeta {
    pub id: u64,
    pub ds: Dataset,
    /// path from the analysis root, `/`-joined node names
    pub path: String,
    /// inferred output columns of this node
    pub cols: ColSchema,
    /// number of consumers *within the analyzed DAG*
    pub consumers: usize,
}

/// The result of analyzing one plan.
pub struct Analysis {
    /// inferred output columns of the analysis root
    pub output: ColSchema,
    pub diagnostics: Vec<Diagnostic>,
    /// distinct plan nodes visited (shared subtrees count once)
    pub node_count: usize,
}

impl Analysis {
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error)
    }

    pub fn count(&self, sev: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == sev).count()
    }

    /// No error-severity diagnostics.
    pub fn is_clean(&self) -> bool {
        self.count(Severity::Error) == 0
    }

    /// All error messages, one per line (feeds `DdpError::validation`).
    pub fn error_summary(&self) -> String {
        self.errors().map(|d| d.to_string()).collect::<Vec<_>>().join("\n  ")
    }

    /// Machine-readable form (stable key order via the in-tree JSON
    /// module's BTreeMap objects).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            (
                "schema",
                Value::Arr(
                    self.output
                        .iter()
                        .map(|c| {
                            Value::obj(vec![
                                ("name", Value::from(c.name.as_str())),
                                ("type", Value::from(c.ty.base.name())),
                                ("nullable", Value::from(c.ty.nullable)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "diagnostics",
                Value::Arr(self.diagnostics.iter().map(|d| d.to_json()).collect()),
            ),
            ("errors", Value::from(self.count(Severity::Error))),
            ("warnings", Value::from(self.count(Severity::Warning))),
            ("notes", Value::from(self.count(Severity::Note))),
            ("nodes", Value::from(self.node_count)),
        ])
    }

    /// Human-readable report: the plan, its inferred schema and every
    /// diagnostic.
    pub fn render(&self, ds: &Dataset) -> String {
        let mut out = String::new();
        out.push_str(&ds.plan_display());
        out.push_str(&format!("inferred schema: [{}]\n", render_cols(&self.output)));
        if self.diagnostics.is_empty() {
            out.push_str("no diagnostics\n");
        } else {
            for d in &self.diagnostics {
                out.push_str(&format!("{d}\n"));
            }
        }
        out
    }
}

/// Analyze a plan: schema/type inference plus expression checking.
/// Cost is proportional to plan size (nodes × expression size), never to
/// data size — sources are never scanned.
pub fn analyze(ds: &Dataset) -> Analysis {
    let mut cx = Infer::new(true);
    let output = cx.infer(ds, "");
    cx.finish(output)
}

/// [`analyze`] plus the rule-based lint pass. `is_persisted` reports
/// cache registration (the driver passes the engine cache; pass
/// `&|_| false` when no cache context exists).
pub fn analyze_with_lints(ds: &Dataset, is_persisted: &dyn Fn(u64) -> bool) -> Analysis {
    let mut cx = Infer::new(true);
    let output = cx.infer(ds, "");
    let mut diags = Vec::new();
    lint::run(&cx.nodes, is_persisted, &mut diags);
    cx.diags.extend(diags);
    cx.finish(output)
}

/// Quiet inference: output column types only, no diagnostics collected.
/// This is the guard's fast path.
pub fn infer(ds: &Dataset) -> ColSchema {
    let mut cx = Infer::new(false);
    cx.infer(ds, "")
}

// --------------------------- invariant guard --------------------------

/// True when the optimizer invariant guard should run: debug builds and
/// test runs by default, any build under `DDP_ANALYZE=1` (and explicitly
/// off under `DDP_ANALYZE=0`).
pub fn guard_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| match std::env::var("DDP_ANALYZE") {
        Ok(v) => v != "0" && !v.eq_ignore_ascii_case("false"),
        Err(_) => cfg!(debug_assertions),
    })
}

/// Compare the inferred output schemas of a pre/post rewrite pair.
/// `Err` describes the drift; `Ok` means the rewrite is schema-preserving.
pub fn rewrite_schema_delta(pre: &Dataset, post: &Dataset) -> std::result::Result<(), String> {
    if pre.schema.names() != post.schema.names() {
        return Err(format!(
            "declared output columns changed: [{}] -> [{}]\npre plan:\n{}post plan:\n{}",
            pre.schema.names().join(", "),
            post.schema.names().join(", "),
            pre.plan_display(),
            post.plan_display()
        ));
    }
    let a = infer(pre);
    let b = infer(post);
    if a != b {
        return Err(format!(
            "inferred output schema changed: [{}] -> [{}]\npre plan:\n{}post plan:\n{}",
            render_cols(&a),
            render_cols(&b),
            pre.plan_display(),
            post.plan_display()
        ));
    }
    Ok(())
}

/// The optimizer's invariant guard: a rewrite that changes the inferred
/// output schema is an engine bug, so it panics (differential suites run
/// with the guard live — see [`guard_enabled`]).
pub fn assert_rewrite_preserves_schema(pre: &Dataset, post: &Dataset) {
    if let Err(msg) = rewrite_schema_delta(pre, post) {
        panic!("optimizer invariant violated: {msg}");
    }
}

// ------------------------- §3.8 contract checks ------------------------

/// The driver's §3.8 pipe-contract check as analyzer diagnostics: every
/// column a pipe's contract wants must exist on the declared input anchor
/// schema (E008) with a compatible declared type (E009). Message text is
/// the driver's long-standing error contract.
pub fn check_contract(
    pipe_name: &str,
    want: &Schema,
    input_id: &str,
    have: &Schema,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let path = format!("pipe:{pipe_name}/input:{input_id}");
    for wi in 0..want.len() {
        let (wname, wty) = want.field(wi);
        match have.idx(wname) {
            None => out.push(Diagnostic::new(
                "E008",
                Severity::Error,
                &path,
                format!(
                    "pipe '{pipe_name}' requires column '{wname}' on input '{input_id}', which declares only [{}]",
                    have.names().join(", ")
                ),
            )),
            Some(hi) => {
                let hty = have.field_type(hi);
                if wty != FieldType::Any && hty != FieldType::Any && wty != hty {
                    out.push(Diagnostic::new(
                        "E009",
                        Severity::Error,
                        &path,
                        format!(
                            "pipe '{pipe_name}' needs '{wname}: {}' on '{input_id}', declared as {}",
                            wty.name(),
                            hty.name()
                        ),
                    ));
                }
            }
        }
    }
    out
}

// ----------------------------- inference ------------------------------

struct Infer {
    memo: HashMap<u64, ColSchema>,
    diags: Vec<Diagnostic>,
    /// analyzed nodes in first-visit (DFS preorder) order
    nodes: Vec<NodeMeta>,
    /// index into `nodes` by node id
    by_id: HashMap<u64, usize>,
    collect: bool,
}

impl Infer {
    fn new(collect: bool) -> Infer {
        Infer {
            memo: HashMap::new(),
            diags: Vec::new(),
            nodes: Vec::new(),
            by_id: HashMap::new(),
            collect,
        }
    }

    fn finish(self, output: ColSchema) -> Analysis {
        Analysis { output, diagnostics: self.diags, node_count: self.memo.len() }
    }

    fn error(&mut self, code: &'static str, path: &str, msg: String) {
        self.push(code, Severity::Error, path, msg);
    }

    fn push(&mut self, code: &'static str, sev: Severity, path: &str, msg: String) {
        if self.collect {
            self.diags.push(Diagnostic::new(code, sev, path, msg));
        }
    }

    fn infer(&mut self, ds: &Dataset, parent_path: &str) -> ColSchema {
        if let Some(done) = self.memo.get(&ds.id).cloned() {
            // a shared subtree: count the extra consumer, reuse the types
            if self.collect {
                if let Some(&ix) = self.by_id.get(&ds.id) {
                    self.nodes[ix].consumers += 1;
                }
            }
            return done;
        }
        let path = if parent_path.is_empty() {
            ds.name()
        } else {
            format!("{parent_path}/{}", ds.name())
        };
        let cols = self.infer_node(ds, &path);
        self.memo.insert(ds.id, cols.clone());
        if self.collect {
            self.by_id.insert(ds.id, self.nodes.len());
            self.nodes.push(NodeMeta {
                id: ds.id,
                ds: ds.clone(),
                path,
                cols: cols.clone(),
                consumers: 1,
            });
        }
        cols
    }

    fn infer_node(&mut self, ds: &Dataset, path: &str) -> ColSchema {
        match &*ds.node {
            Plan::Source { .. } => Arc::new(schema_cols(&ds.schema)),
            // opaque closures: trust the declared output schema
            Plan::Map { input, .. }
            | Plan::FlatMap { input, .. }
            | Plan::MapPartitions { input, .. } => {
                self.infer(input, path);
                Arc::new(schema_cols(&ds.schema))
            }
            Plan::Filter { input, .. }
            | Plan::Distinct { input, .. }
            | Plan::Sort { input, .. }
            | Plan::Repartition { input, .. } => self.infer(input, path),
            Plan::FilterExpr { input, expr } => {
                let t_in = self.infer(input, path);
                self.check_expr(expr, &t_in, path);
                t_in
            }
            Plan::Project { input, cols, schema } => {
                let t_in = self.infer(input, path);
                let out: Vec<ColInfo> = cols
                    .iter()
                    .enumerate()
                    .map(|(pos, &c)| {
                        let name = if pos < schema.len() {
                            schema.field(pos).0.to_string()
                        } else {
                            format!("c{pos}")
                        };
                        match t_in.get(c) {
                            Some(info) => ColInfo { name, ty: info.ty },
                            None => {
                                self.error(
                                    "E001",
                                    path,
                                    format!(
                                        "projection references column {c}, but the input has only {} column(s)",
                                        t_in.len()
                                    ),
                                );
                                ColInfo { name, ty: ColType::any() }
                            }
                        }
                    })
                    .collect();
                Arc::new(out)
            }
            Plan::ReduceByKey { input, key_col, .. } => {
                // the reduce contract preserves row shape: output columns
                // are the input columns
                let t_in = self.infer(input, path);
                if let Some(kc) = key_col {
                    if *kc >= t_in.len() {
                        self.error(
                            "E001",
                            path,
                            format!(
                                "reduce key column {kc} is out of range (input has {} column(s))",
                                t_in.len()
                            ),
                        );
                    }
                }
                t_in
            }
            Plan::Join { left, right, kind, schema, lkey_col, rkey_col, .. } => {
                let tl = self.infer(left, &format!("{path}/left"));
                let tr = self.infer(right, &format!("{path}/right"));
                self.check_join_keys(&tl, &tr, *lkey_col, *rkey_col, path);
                // output rows are left fields ++ right fields; a Left join
                // null-extends the right side
                let mut types: Vec<ColType> = tl.iter().map(|c| c.ty).collect();
                types.extend(tr.iter().map(|c| ColType {
                    base: c.ty.base,
                    nullable: c.ty.nullable || *kind == JoinKind::Left,
                }));
                if schema.len() != types.len() {
                    self.error(
                        "E007",
                        path,
                        format!(
                            "join declares {} output column(s) but left+right provide {}",
                            schema.len(),
                            types.len()
                        ),
                    );
                }
                let out: Vec<ColInfo> = (0..schema.len())
                    .map(|i| ColInfo {
                        name: schema.field(i).0.to_string(),
                        ty: types
                            .get(i)
                            .copied()
                            .unwrap_or_else(|| ColType::declared(schema.field(i).1)),
                    })
                    .collect();
                Arc::new(out)
            }
            Plan::Union { inputs } => {
                let mut iter = inputs.iter();
                let first = match iter.next() {
                    Some(i) => self.infer(i, path),
                    None => return Arc::new(schema_cols(&ds.schema)),
                };
                let mut out: Vec<ColInfo> = first.as_ref().clone();
                for input in iter {
                    let t = self.infer(input, path);
                    if t.len() != out.len() {
                        self.error(
                            "E006",
                            path,
                            format!(
                                "union inputs disagree on column count: {} vs {}",
                                out.len(),
                                t.len()
                            ),
                        );
                        continue;
                    }
                    for (i, (a, b)) in out.iter_mut().zip(t.iter()).enumerate() {
                        let lub = a.ty.lub(&b.ty);
                        if lub.base == FieldType::Any
                            && a.ty.base != FieldType::Any
                            && b.ty.base != FieldType::Any
                        {
                            self.push(
                                "W105",
                                Severity::Warning,
                                path,
                                format!(
                                    "union column {i} ('{}') mixes {} and {}; the column degrades to any",
                                    a.name,
                                    a.ty.base.name(),
                                    b.ty.base.name()
                                ),
                            );
                        }
                        a.ty = lub;
                    }
                }
                Arc::new(out)
            }
        }
    }

    fn check_join_keys(
        &mut self,
        tl: &[ColInfo],
        tr: &[ColInfo],
        lkey_col: Option<usize>,
        rkey_col: Option<usize>,
        path: &str,
    ) {
        for (side, cols, key) in [("left", tl, lkey_col), ("right", tr, rkey_col)] {
            if let Some(k) = key {
                if k >= cols.len() {
                    self.error(
                        "E001",
                        path,
                        format!(
                            "{side} join key column {k} is out of range ({side} input has {} column(s))",
                            cols.len()
                        ),
                    );
                }
            }
        }
        if let (Some(lk), Some(rk)) = (lkey_col, rkey_col) {
            if let (Some(l), Some(r)) = (tl.get(lk), tr.get(rk)) {
                let (lb, rb) = (l.ty.base, r.ty.base);
                if lb != FieldType::Any && rb != FieldType::Any && lb != rb {
                    self.error(
                        "E005",
                        path,
                        format!(
                            "join keys have incompatible types: left column {lk} ('{}': {}) vs right column {rk} ('{}': {}) — cross-type keys never hash-match",
                            l.name,
                            lb.name(),
                            r.name,
                            rb.name()
                        ),
                    );
                }
            }
        }
    }

    // ------------------------ expression checks -----------------------

    fn check_expr(&mut self, e: &Expr, input: &[ColInfo], path: &str) -> ColType {
        match e {
            Expr::Lit(f) => ColType::of_field(f),
            Expr::Col(i, name) => match input.get(*i) {
                Some(c) => c.ty,
                None => {
                    self.error(
                        "E001",
                        path,
                        format!(
                            "expression references column {i} ('{name}'), but the input has only {} column(s)",
                            input.len()
                        ),
                    );
                    ColType::any()
                }
            },
            Expr::Unary(UnOp::Not, x) => {
                self.check_expr(x, input, path);
                ColType::new(FieldType::Bool, false)
            }
            Expr::Unary(UnOp::Neg, x) => {
                let t = self.check_expr(x, input, path);
                if !t.is_numeric() {
                    self.error(
                        "E004",
                        path,
                        format!("negating a {} value always yields null", t.base.name()),
                    );
                }
                ColType { base: t.base, nullable: true }
            }
            Expr::Binary(op, a, b) => {
                let ta = self.check_expr(a, input, path);
                let tb = self.check_expr(b, input, path);
                self.check_binary(*op, &ta, &tb, a, b, path)
            }
            Expr::Call(f, args) => {
                let ts: Vec<ColType> =
                    args.iter().map(|a| self.check_expr(a, input, path)).collect();
                self.check_call(*f, &ts, path)
            }
        }
    }

    fn check_binary(
        &mut self,
        op: BinOp,
        ta: &ColType,
        tb: &ColType,
        a: &Expr,
        b: &Expr,
        path: &str,
    ) -> ColType {
        let bool_t = ColType::new(FieldType::Bool, false);
        match op {
            BinOp::Or | BinOp::And => bool_t,
            BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge => {
                let ordered = !matches!(op, BinOp::Eq | BinOp::Ne);
                if ordered
                    && (matches!(a, Expr::Lit(Field::Null)) || matches!(b, Expr::Lit(Field::Null)))
                {
                    self.push(
                        "W102",
                        Severity::Warning,
                        path,
                        format!("ordered comparison '{op}' with a null literal is always false"),
                    );
                } else if !compare_compatible(ta.base, tb.base, ordered) {
                    self.error(
                        "E003",
                        path,
                        format!(
                            "comparison '{op}' between {} and {} is always false",
                            ta.base.name(),
                            tb.base.name()
                        ),
                    );
                }
                bool_t
            }
            BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div => {
                for t in [ta, tb] {
                    if !t.is_numeric() {
                        self.error(
                            "E004",
                            path,
                            format!(
                                "arithmetic '{op}' on a {} value always yields null",
                                t.base.name()
                            ),
                        );
                    }
                }
                // the scalar core coerces both operands through f64
                ColType::new(FieldType::F64, true)
            }
        }
    }

    fn check_call(&mut self, f: Func, args: &[ColType], path: &str) -> ColType {
        let (name, arity) = match f {
            Func::Length => ("length", 1),
            Func::Lower => ("lower", 1),
            Func::Upper => ("upper", 1),
            Func::Contains => ("contains", 2),
            Func::StartsWith => ("starts_with", 2),
        };
        if args.len() != arity {
            self.error(
                "E002",
                path,
                format!("{name}() expects {arity} argument(s), got {}", args.len()),
            );
        }
        for t in args.iter().take(arity) {
            if !matches!(t.base, FieldType::Str | FieldType::Any) {
                self.push(
                    "W106",
                    Severity::Warning,
                    path,
                    format!(
                        "{name}() applied to a {} value always yields {}",
                        t.base.name(),
                        if matches!(f, Func::Contains | Func::StartsWith) {
                            "false"
                        } else {
                            "null"
                        }
                    ),
                );
            }
        }
        match f {
            Func::Length => ColType::new(FieldType::I64, true),
            Func::Lower | Func::Upper => ColType::new(FieldType::Str, true),
            Func::Contains | Func::StartsWith => ColType::new(FieldType::Bool, false),
        }
    }
}

/// Whether two base types can meaningfully compare. `Any` is always
/// compatible (unknown); numeric pairs coerce exactly; ordered
/// comparison additionally requires an ordered type (`field_cmp` returns
/// `None` for bool/bytes).
fn compare_compatible(a: FieldType, b: FieldType, ordered: bool) -> bool {
    use FieldType::*;
    if a == Any || b == Any {
        return true;
    }
    let numeric = |t: FieldType| matches!(t, I64 | F64);
    if numeric(a) && numeric(b) {
        return true;
    }
    if a != b {
        return false;
    }
    // same concrete type; ordered comparison needs an ordered domain
    !ordered || matches!(a, I64 | F64 | Str)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::row;

    fn src() -> Dataset {
        let schema = Schema::new(vec![
            ("id", FieldType::I64),
            ("name", FieldType::Str),
            ("score", FieldType::F64),
        ]);
        Dataset::from_rows("t", schema, vec![row!(1i64, "a", 0.5f64)], 2)
    }

    fn col(i: usize, n: &str) -> Expr {
        Expr::Col(i, n.into())
    }

    #[test]
    fn source_types_flow_through_narrow_ops() {
        let ds = src().filter(|_| true).repartition(2);
        let a = analyze(&ds);
        assert!(a.is_clean(), "{}", a.error_summary());
        assert_eq!(render_cols(&a.output), "id: i64?, name: str?, score: f64?");
    }

    #[test]
    fn project_selects_types() {
        let ds = src().project(vec![2, 0]);
        let a = analyze(&ds);
        assert!(a.is_clean());
        assert_eq!(render_cols(&a.output), "score: f64?, id: i64?");
    }

    #[test]
    fn oob_column_is_e001() {
        let ds = src().filter_expr(col(7, "ghost"));
        let a = analyze(&ds);
        assert_eq!(a.count(Severity::Error), 1);
        let d = a.errors().next().unwrap();
        assert_eq!(d.code, "E001");
        assert!(d.message.contains("column 7"), "{d}");
    }

    #[test]
    fn str_vs_int_comparison_is_e003() {
        let ds = src().filter_expr(Expr::Binary(
            BinOp::Gt,
            Box::new(col(1, "name")),
            Box::new(Expr::Lit(Field::I64(3))),
        ));
        let a = analyze(&ds);
        assert!(a.errors().any(|d| d.code == "E003"), "{}", a.error_summary());
    }

    #[test]
    fn numeric_cross_type_comparison_is_fine() {
        let ds = src().filter_expr(Expr::Binary(
            BinOp::Lt,
            Box::new(col(0, "id")),
            Box::new(Expr::Lit(Field::F64(3.5))),
        ));
        assert!(analyze(&ds).is_clean());
    }

    #[test]
    fn arity_mismatch_is_e002() {
        let ds = src().filter_expr(Expr::Call(Func::Contains, vec![col(1, "name")]));
        let a = analyze(&ds);
        assert!(a.errors().any(|d| d.code == "E002"), "{}", a.error_summary());
    }

    #[test]
    fn arithmetic_on_string_is_e004() {
        let ds = src().filter_expr(Expr::Binary(
            BinOp::Add,
            Box::new(col(1, "name")),
            Box::new(Expr::Lit(Field::I64(1))),
        ));
        let a = analyze(&ds);
        assert!(a.errors().any(|d| d.code == "E004"), "{}", a.error_summary());
    }

    #[test]
    fn join_key_type_mismatch_is_e005() {
        let l = src();
        let r = src();
        // join id (i64) against name (str)
        let schema = Schema::of_names(&["a", "b", "c", "d", "e", "f"]);
        let ds = l.join_on(&r, schema, JoinKind::Inner, 2, 0, 1);
        let a = analyze(&ds);
        assert!(a.errors().any(|d| d.code == "E005"), "{}", a.error_summary());
    }

    #[test]
    fn left_join_nullifies_right_side() {
        let l = src();
        let r = src();
        let schema = Schema::of_names(&["a", "b", "c", "d", "e", "f"]);
        let ds = l.join_on(&r, schema, JoinKind::Left, 2, 0, 0);
        let a = analyze(&ds);
        assert!(a.is_clean(), "{}", a.error_summary());
        assert!(a.output[3..].iter().all(|c| c.ty.nullable));
        assert_eq!(a.output[3].ty.base, FieldType::I64);
    }

    #[test]
    fn union_type_divergence_degrades_to_any() {
        let a_ds = src();
        let other_schema = Schema::new(vec![
            ("id", FieldType::Str),
            ("name", FieldType::Str),
            ("score", FieldType::F64),
        ]);
        let b_ds = Dataset::from_rows("u", other_schema, vec![row!("x", "b", 1.0f64)], 2);
        let u = a_ds.union(&[b_ds]);
        let a = analyze(&u);
        assert!(a.is_clean());
        assert!(a.diagnostics.iter().any(|d| d.code == "W105"));
        assert_eq!(a.output[0].ty.base, FieldType::Any);
        assert_eq!(a.output[1].ty.base, FieldType::Str);
    }

    #[test]
    fn shared_subtree_analyzed_once() {
        let base = src().filter_expr(Expr::Binary(
            BinOp::Gt,
            Box::new(col(0, "id")),
            Box::new(Expr::Lit(Field::I64(0))),
        ));
        let u = base.union(&[base.clone()]);
        let a = analyze(&u);
        // the shared filter contributes no duplicate diagnostics and is
        // counted once
        assert!(a.is_clean());
        assert_eq!(a.node_count, 3, "source + filter + union");
    }

    #[test]
    fn guard_accepts_identity_and_rejects_drift() {
        let ds = src().project(vec![0, 1]);
        assert!(rewrite_schema_delta(&ds, &ds.clone()).is_ok());
        let other = src().project(vec![0, 2]);
        let err = rewrite_schema_delta(&ds, &other).unwrap_err();
        assert!(err.contains("changed"), "{err}");
    }

    #[test]
    fn contract_messages_match_driver_contract() {
        let want = Schema::new(vec![("text", FieldType::Str)]);
        let have = Schema::new(vec![("id", FieldType::I64)]);
        let diags = check_contract("clean", &want, "In", &have);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, "E008");
        assert_eq!(
            diags[0].message,
            "pipe 'clean' requires column 'text' on input 'In', which declares only [id]"
        );
        let have2 = Schema::new(vec![("text", FieldType::I64)]);
        let diags2 = check_contract("clean", &want, "In", &have2);
        assert_eq!(diags2[0].code, "E009");
        assert_eq!(
            diags2[0].message,
            "pipe 'clean' needs 'text: str' on 'In', declared as i64"
        );
    }

    #[test]
    fn json_shape_is_stable() {
        let ds = src().filter_expr(col(9, "nope"));
        let a = analyze(&ds);
        let j = a.to_json();
        assert_eq!(j.get("errors").and_then(|v| v.as_i64()), Some(1));
        let text = crate::json::to_string(&j);
        assert!(text.contains("\"code\":\"E001\""), "{text}");
    }
}
