//! SQL expression AST + evaluator, engine-resident so the logical plan
//! optimizer can inspect and rewrite structured filters/projections
//! ([`super::dataset::Plan::FilterExpr`] / [`Plan::Project`]).
//!
//! The parser lives with the SQL pipe (`crate::pipes::sql::compile`); this
//! module owns everything the optimizer needs: evaluation, column usage,
//! column remapping, conjunct splitting and constant folding. Constant
//! folding reuses [`eval`] itself on literal-only subtrees, so folded and
//! runtime evaluation can never disagree.

use super::row::{Column, ColumnBatch, ColumnData, Field, Row};
use std::cmp::Ordering;
use std::collections::BTreeSet;
use std::fmt;

// ------------------------------- AST --------------------------------

#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Lit(Field),
    /// column reference: resolved index + source name (kept for display)
    Col(usize, String),
    Unary(UnOp, Box<Expr>),
    Binary(BinOp, Box<Expr>, Box<Expr>),
    Call(Func, Vec<Expr>),
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum UnOp {
    Not,
    Neg,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BinOp {
    Or,
    And,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Add,
    Sub,
    Mul,
    Div,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Func {
    Length,
    Lower,
    Upper,
    Contains,
    StartsWith,
}

// ----------------------------- evaluator ----------------------------

/// Evaluate an expression against a row.
///
/// All operator semantics live in the shared scalar core
/// ([`scalar_unary`] / [`scalar_binary`] / [`scalar_call`]), which the
/// vectorized kernels ([`eval_mask`] / [`eval_batch`]) reuse element-wise
/// for every case they don't fast-path — the two paths cannot diverge.
pub fn eval(e: &Expr, row: &Row) -> Field {
    match e {
        Expr::Lit(f) => f.clone(),
        Expr::Col(i, _) => row.get(*i).clone(),
        Expr::Unary(op, x) => scalar_unary(*op, &eval(x, row)),
        Expr::Binary(op, a, b) => scalar_binary(*op, &eval(a, row), &eval(b, row)),
        Expr::Call(f, args) => {
            let vals: Vec<Field> = args.iter().map(|a| eval(a, row)).collect();
            scalar_call(*f, &vals)
        }
    }
}

/// Scalar semantics of a unary operator.
pub fn scalar_unary(op: UnOp, v: &Field) -> Field {
    match op {
        UnOp::Not => Field::Bool(!truthy(v)),
        UnOp::Neg => match v {
            Field::I64(x) => Field::I64(-x),
            Field::F64(x) => Field::F64(-x),
            _ => Field::Null,
        },
    }
}

/// Scalar semantics of a binary operator. Note `or`/`and` are not
/// short-circuiting (both operands are evaluated before this is called).
pub fn scalar_binary(op: BinOp, va: &Field, vb: &Field) -> Field {
    match op {
        BinOp::Or => Field::Bool(truthy(va) || truthy(vb)),
        BinOp::And => Field::Bool(truthy(va) && truthy(vb)),
        BinOp::Eq => Field::Bool(field_eq(va, vb)),
        BinOp::Ne => Field::Bool(!field_eq(va, vb)),
        BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge => match field_cmp(va, vb) {
            Some(ord) => Field::Bool(match op {
                BinOp::Lt => ord.is_lt(),
                BinOp::Le => ord.is_le(),
                BinOp::Gt => ord.is_gt(),
                _ => ord.is_ge(),
            }),
            None => Field::Bool(false),
        },
        BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div => {
            match (va.as_f64(), vb.as_f64()) {
                (Some(x), Some(y)) => Field::F64(match op {
                    BinOp::Add => x + y,
                    BinOp::Sub => x - y,
                    BinOp::Mul => x * y,
                    _ => x / y,
                }),
                _ => Field::Null,
            }
        }
    }
}

/// Scalar semantics of a function call over already-evaluated arguments.
pub fn scalar_call(f: Func, vals: &[Field]) -> Field {
    match f {
        Func::Length => vals
            .first()
            .and_then(|v| v.as_str())
            .map(|s| Field::I64(s.chars().count() as i64))
            .unwrap_or(Field::Null),
        Func::Lower => vals
            .first()
            .and_then(|v| v.as_str())
            .map(|s| Field::Str(s.to_lowercase()))
            .unwrap_or(Field::Null),
        Func::Upper => vals
            .first()
            .and_then(|v| v.as_str())
            .map(|s| Field::Str(s.to_uppercase()))
            .unwrap_or(Field::Null),
        Func::Contains => match (
            vals.first().and_then(|v| v.as_str()),
            vals.get(1).and_then(|v| v.as_str()),
        ) {
            (Some(s), Some(sub)) => Field::Bool(s.contains(sub)),
            _ => Field::Bool(false),
        },
        Func::StartsWith => match (
            vals.first().and_then(|v| v.as_str()),
            vals.get(1).and_then(|v| v.as_str()),
        ) {
            (Some(s), Some(p)) => Field::Bool(s.starts_with(p)),
            _ => Field::Bool(false),
        },
    }
}

/// SQL-ish truthiness: null/false/0/empty are false, everything else true
/// (note: NaN != 0.0, so NaN is truthy — pinned by tests).
pub fn truthy(f: &Field) -> bool {
    match f {
        Field::Bool(b) => *b,
        Field::Null => false,
        Field::I64(v) => *v != 0,
        Field::F64(v) => *v != 0.0,
        Field::Str(s) => !s.is_empty(),
        Field::Bytes(b) => !b.is_empty(),
    }
}

/// Exact i64-vs-f64 comparison without the lossy `i64 as f64` cast (which
/// rounds at magnitudes ≥ 2^53 and made e.g. `2^53 + 1 = 2^53.0` evaluate
/// true). Returns `None` iff `b` is NaN. Strategy: dispose of non-finite
/// and out-of-i64-range `b` first, then compare `a` against `trunc(b)` as
/// integers (`trunc(b)` is exact for |b| < 2^63) and break integer ties by
/// the sign of `b`'s fractional part.
pub fn cmp_i64_f64(a: i64, b: f64) -> Option<std::cmp::Ordering> {
    use std::cmp::Ordering;
    if b.is_nan() {
        return None;
    }
    const TWO63: f64 = 9_223_372_036_854_775_808.0; // 2^63, exactly representable
    if b >= TWO63 {
        return Some(Ordering::Less); // a <= i64::MAX < 2^63 <= b (covers +inf)
    }
    if b < -TWO63 {
        return Some(Ordering::Greater); // a >= i64::MIN = -2^63 > b (covers -inf)
    }
    let bt = b.trunc() as i64; // |trunc(b)| <= 2^63 ⇒ exact conversion
    match a.cmp(&bt) {
        Ordering::Equal => {
            let frac = b.fract();
            if frac > 0.0 {
                Some(Ordering::Less) // a == trunc(b) < b
            } else if frac < 0.0 {
                Some(Ordering::Greater)
            } else {
                Some(Ordering::Equal)
            }
        }
        ord => Some(ord),
    }
}

/// Equality with numeric coercion: `I64` vs `F64` compares exactly via
/// [`cmp_i64_f64`]; same-type values compare natively (so large i64s are
/// never rounded, NaN != NaN, and 0.0 == -0.0); everything else is
/// structural (`Null = Null` is true — pinned by tests).
pub fn field_eq(a: &Field, b: &Field) -> bool {
    use std::cmp::Ordering;
    match (a, b) {
        (Field::I64(x), Field::F64(y)) => cmp_i64_f64(*x, *y) == Some(Ordering::Equal),
        (Field::F64(x), Field::I64(y)) => cmp_i64_f64(*y, *x) == Some(Ordering::Equal),
        (Field::F64(x), Field::F64(y)) => x == y,
        _ => a == b,
    }
}

/// Ordering: strings compare lexicographically, numbers numerically (mixed
/// `I64`/`F64` exactly, via [`cmp_i64_f64`]); mismatched / non-comparable
/// types (and NaN operands) return `None` — comparisons on `None` evaluate
/// to false, pinned by tests.
pub fn field_cmp(a: &Field, b: &Field) -> Option<std::cmp::Ordering> {
    match (a, b) {
        (Field::Str(x), Field::Str(y)) => Some(x.cmp(y)),
        (Field::I64(x), Field::I64(y)) => Some(x.cmp(y)),
        (Field::F64(x), Field::F64(y)) => x.partial_cmp(y),
        (Field::I64(x), Field::F64(y)) => cmp_i64_f64(*x, *y),
        (Field::F64(x), Field::I64(y)) => cmp_i64_f64(*y, *x).map(std::cmp::Ordering::reverse),
        _ => None,
    }
}

// ------------------------- vectorized eval --------------------------
//
// Column-at-a-time evaluation over a [`ColumnBatch`]. Typed fast paths
// cover the common numeric/string compare shapes; every other case runs
// the *same scalar core* element-wise, so the vector path is semantically
// identical to `eval` by construction (pinned by a differential property
// test below).

/// Result of evaluating a subexpression over a batch: a borrowed input
/// column, a computed column, or a value constant across the batch.
enum VecVal<'a> {
    Ref(&'a Column),
    Owned(Column),
    Const(Field),
}

impl VecVal<'_> {
    fn col(&self) -> Option<&Column> {
        match self {
            VecVal::Ref(c) => Some(c),
            VecVal::Owned(c) => Some(c),
            VecVal::Const(_) => None,
        }
    }

    fn field_at(&self, i: usize) -> Field {
        match self {
            VecVal::Ref(c) => c.field_at(i),
            VecVal::Owned(c) => c.field_at(i),
            VecVal::Const(f) => f.clone(),
        }
    }
}

/// Truthiness mask of `e` over the batch — the vectorized filter kernel.
pub fn eval_mask(e: &Expr, batch: &ColumnBatch) -> Vec<bool> {
    match eval_v(e, batch) {
        VecVal::Const(f) => vec![truthy(&f); batch.len()],
        VecVal::Ref(c) => truthy_col(c),
        VecVal::Owned(c) => truthy_col(&c),
    }
}

/// Full column result of `e` over the batch (constants broadcast). Mostly
/// useful to tests pinning vector/scalar agreement.
pub fn eval_batch(e: &Expr, batch: &ColumnBatch) -> Column {
    match eval_v(e, batch) {
        VecVal::Const(f) => Column::from_fields(vec![f; batch.len()]),
        VecVal::Ref(c) => c.clone(),
        VecVal::Owned(c) => c,
    }
}

/// Shuffle-partition a key column: per-bucket row-index lists in input
/// order, computed from the column's per-slot key hashes. The hashes
/// ([`Column::hash_values`]) match `field_hash(row key)` slot for slot —
/// a null slot hashes as `Field::Null`, never as the typed placeholder
/// stored under the mask — so the executor's batch-native shuffle lands
/// every row in exactly the bucket the row path would pick, and gathers
/// each bucket with one column-level take over these lists.
pub(crate) fn bucket_indices(key_col: &Column, num_parts: usize) -> Vec<Vec<usize>> {
    let mut idxs: Vec<Vec<usize>> = (0..num_parts).map(|_| Vec::new()).collect();
    for (i, h) in key_col.hash_values().iter().enumerate() {
        idxs[super::executor::hash_bucket(*h, num_parts)].push(i);
    }
    idxs
}

fn eval_v<'a>(e: &Expr, batch: &'a ColumnBatch) -> VecVal<'a> {
    match e {
        Expr::Lit(f) => VecVal::Const(f.clone()),
        Expr::Col(i, _) => VecVal::Ref(&batch.cols[*i]),
        Expr::Unary(op, x) => vunary(*op, &eval_v(x, batch), batch.len()),
        Expr::Binary(op, a, b) => {
            vbinary(*op, &eval_v(a, batch), &eval_v(b, batch), batch.len())
        }
        Expr::Call(f, args) => {
            let vals: Vec<VecVal<'a>> = args.iter().map(|a| eval_v(a, batch)).collect();
            vcall(*f, &vals, batch.len())
        }
    }
}

/// Per-element truthiness of a column (null slots are false).
fn truthy_col(c: &Column) -> Vec<bool> {
    fn pred<T>(data: &[T], nulls: Option<&Vec<bool>>, f: impl Fn(&T) -> bool) -> Vec<bool> {
        match nulls {
            None => data.iter().map(f).collect(),
            Some(m) => data.iter().zip(m).map(|(x, n)| !*n && f(x)).collect(),
        }
    }
    let n = c.nulls.as_ref();
    match &c.data {
        ColumnData::Bool(v) => pred(v, n, |x| *x),
        ColumnData::I64(v) => pred(v, n, |x| *x != 0),
        ColumnData::F64(v) => pred(v, n, |x| *x != 0.0),
        ColumnData::Str(v) => pred(v, n, |x| !x.is_empty()),
        ColumnData::Bytes(v) => pred(v, n, |x| !x.is_empty()),
        ColumnData::Any(v) => v.iter().map(truthy).collect(),
    }
}

fn bool_col(v: Vec<bool>) -> Column {
    Column { data: ColumnData::Bool(v), nulls: None }
}

/// Element-wise fallback through the scalar core — total, used for every
/// shape without a dedicated kernel.
fn elementwise(vals: &[&VecVal<'_>], len: usize, f: impl Fn(&[Field]) -> Field) -> Column {
    let mut buf: Vec<Field> = Vec::with_capacity(vals.len());
    let mut out = Vec::with_capacity(len);
    for i in 0..len {
        buf.clear();
        for v in vals {
            buf.push(v.field_at(i));
        }
        out.push(f(&buf));
    }
    Column::from_fields(out)
}

fn vunary(op: UnOp, v: &VecVal<'_>, len: usize) -> VecVal<'static> {
    if let VecVal::Const(f) = v {
        return VecVal::Const(scalar_unary(op, f));
    }
    let c = v.col().expect("non-const VecVal has a column");
    match op {
        UnOp::Not => {
            let mut m = truthy_col(c);
            for b in &mut m {
                *b = !*b;
            }
            VecVal::Owned(bool_col(m))
        }
        UnOp::Neg => match &c.data {
            ColumnData::I64(xs) => VecVal::Owned(Column {
                // null slots hold placeholder 0; -0 is fine, mask carries
                data: ColumnData::I64(xs.iter().map(|x| -x).collect()),
                nulls: c.nulls.clone(),
            }),
            ColumnData::F64(xs) => VecVal::Owned(Column {
                data: ColumnData::F64(xs.iter().map(|x| -x).collect()),
                nulls: c.nulls.clone(),
            }),
            // typed non-numeric columns negate to Null everywhere (masked
            // nulls also map to Null, so the result is uniformly Null)
            ColumnData::Bool(_) | ColumnData::Str(_) | ColumnData::Bytes(_) => {
                VecVal::Const(Field::Null)
            }
            ColumnData::Any(_) => {
                VecVal::Owned(elementwise(&[v], len, |fs| scalar_unary(op, &fs[0])))
            }
        },
    }
}

/// Map an optional ordering through a comparison operator, with the same
/// `None → false` / `Ne` = `!Eq` rules as the scalar core.
#[inline]
fn ord_op(op: BinOp, ord: Option<Ordering>) -> bool {
    match op {
        BinOp::Eq => ord == Some(Ordering::Equal),
        BinOp::Ne => ord != Some(Ordering::Equal),
        BinOp::Lt => matches!(ord, Some(o) if o.is_lt()),
        BinOp::Le => matches!(ord, Some(o) if o.is_le()),
        BinOp::Gt => matches!(ord, Some(o) if o.is_gt()),
        BinOp::Ge => matches!(ord, Some(o) if o.is_ge()),
        _ => unreachable!("ord_op is only called for comparison operators"),
    }
}

/// Comparison fast path: per-element `Option<Ordering>` against a non-null
/// constant, for the type pairs whose scalar equality coincides with
/// `cmp == Equal` (numeric/numeric and str/str). `swap` means the constant
/// is the left operand.
fn cmp_col_const(op: BinOp, c: &Column, k: &Field, swap: bool) -> Option<Vec<bool>> {
    fn run<T>(
        data: &[T],
        nulls: Option<&Vec<bool>>,
        op: BinOp,
        swap: bool,
        cmp: impl Fn(&T) -> Option<Ordering>,
    ) -> Vec<bool> {
        let fix = |o: Option<Ordering>| if swap { o.map(Ordering::reverse) } else { o };
        match nulls {
            None => data.iter().map(|x| ord_op(op, fix(cmp(x)))).collect(),
            Some(m) => data
                .iter()
                .zip(m)
                .map(|(x, n)| ord_op(op, if *n { None } else { fix(cmp(x)) }))
                .collect(),
        }
    }
    let n = c.nulls.as_ref();
    Some(match (&c.data, k) {
        (ColumnData::I64(v), Field::I64(y)) => run(v, n, op, swap, |x| Some(x.cmp(y))),
        (ColumnData::I64(v), Field::F64(y)) => run(v, n, op, swap, |x| cmp_i64_f64(*x, *y)),
        (ColumnData::F64(v), Field::F64(y)) => run(v, n, op, swap, |x| x.partial_cmp(y)),
        (ColumnData::F64(v), Field::I64(y)) => {
            run(v, n, op, swap, |x| cmp_i64_f64(*y, *x).map(Ordering::reverse))
        }
        (ColumnData::Str(v), Field::Str(y)) => run(v, n, op, swap, |x| Some(x.cmp(y))),
        _ => return None,
    })
}

/// Comparison fast path for two columns of ordering-compatible types.
fn cmp_col_col(op: BinOp, a: &Column, b: &Column) -> Option<Vec<bool>> {
    // scalar semantics at null slots: `Null = Null` is true (structural
    // equality) but ordered comparisons on any null are false (`field_cmp`
    // returns None), so only Eq survives a double-null
    let both_null_res = matches!(op, BinOp::Eq);
    fn run<T, U>(
        xa: &[T],
        na: Option<&Vec<bool>>,
        xb: &[U],
        nb: Option<&Vec<bool>>,
        op: BinOp,
        both_null_res: bool,
        cmp: impl Fn(&T, &U) -> Option<Ordering>,
    ) -> Vec<bool> {
        let null_at = |m: Option<&Vec<bool>>, i: usize| m.is_some_and(|m| m[i]);
        (0..xa.len())
            .map(|i| match (null_at(na, i), null_at(nb, i)) {
                (true, true) => both_null_res,
                (true, false) | (false, true) => ord_op(op, None),
                (false, false) => ord_op(op, cmp(&xa[i], &xb[i])),
            })
            .collect()
    }
    let (na, nb) = (a.nulls.as_ref(), b.nulls.as_ref());
    Some(match (&a.data, &b.data) {
        (ColumnData::I64(x), ColumnData::I64(y)) => {
            run(x, na, y, nb, op, both_null_res, |p, q| Some(p.cmp(q)))
        }
        (ColumnData::I64(x), ColumnData::F64(y)) => {
            run(x, na, y, nb, op, both_null_res, |p, q| cmp_i64_f64(*p, *q))
        }
        (ColumnData::F64(x), ColumnData::I64(y)) => run(x, na, y, nb, op, both_null_res, |p, q| {
            cmp_i64_f64(*q, *p).map(Ordering::reverse)
        }),
        (ColumnData::F64(x), ColumnData::F64(y)) => {
            run(x, na, y, nb, op, both_null_res, |p, q| p.partial_cmp(q))
        }
        (ColumnData::Str(x), ColumnData::Str(y)) => {
            run(x, na, y, nb, op, both_null_res, |p, q| Some(p.cmp(q)))
        }
        _ => return None,
    })
}

fn vbinary(op: BinOp, a: &VecVal<'_>, b: &VecVal<'_>, len: usize) -> VecVal<'static> {
    if let (VecVal::Const(x), VecVal::Const(y)) = (a, b) {
        return VecVal::Const(scalar_binary(op, x, y));
    }
    match op {
        BinOp::And | BinOp::Or => {
            let ta = truthy_vv(a, len);
            let tb = truthy_vv(b, len);
            let v = match op {
                BinOp::And => ta.iter().zip(&tb).map(|(x, y)| *x && *y).collect(),
                _ => ta.iter().zip(&tb).map(|(x, y)| *x || *y).collect(),
            };
            VecVal::Owned(bool_col(v))
        }
        BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge => {
            let fast = match (a, b) {
                (VecVal::Const(k), _) if !k.is_null() => {
                    b.col().and_then(|c| cmp_col_const(op, c, k, true))
                }
                (_, VecVal::Const(k)) if !k.is_null() => {
                    a.col().and_then(|c| cmp_col_const(op, c, k, false))
                }
                _ => match (a.col(), b.col()) {
                    (Some(ca), Some(cb)) => cmp_col_col(op, ca, cb),
                    _ => None,
                },
            };
            match fast {
                Some(v) => VecVal::Owned(bool_col(v)),
                None => VecVal::Owned(elementwise(&[a, b], len, |fs| {
                    scalar_binary(op, &fs[0], &fs[1])
                })),
            }
        }
        BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div => varith(op, a, b, len),
    }
}

fn truthy_vv(v: &VecVal<'_>, len: usize) -> Vec<bool> {
    match v {
        VecVal::Const(f) => vec![truthy(f); len],
        _ => truthy_col(v.col().expect("non-const VecVal has a column")),
    }
}

/// Arithmetic kernel. Operands that can never be numeric (typed
/// non-numeric columns, non-numeric constants) force an all-Null result;
/// `Any` columns fall back to the scalar core element-wise.
fn varith(op: BinOp, a: &VecVal<'_>, b: &VecVal<'_>, len: usize) -> VecVal<'static> {
    enum Cls<'a> {
        I64(&'a [i64], Option<&'a Vec<bool>>),
        F64(&'a [f64], Option<&'a Vec<bool>>),
        Const(f64),
        Never,
        PerElem,
    }
    fn classify<'a>(v: &'a VecVal<'_>) -> Cls<'a> {
        match v {
            VecVal::Const(f) => match f.as_f64() {
                Some(x) => Cls::Const(x),
                None => Cls::Never,
            },
            _ => {
                let c = v.col().expect("non-const VecVal has a column");
                match &c.data {
                    ColumnData::I64(xs) => Cls::I64(xs, c.nulls.as_ref()),
                    ColumnData::F64(xs) => Cls::F64(xs, c.nulls.as_ref()),
                    ColumnData::Bool(_) | ColumnData::Str(_) | ColumnData::Bytes(_) => Cls::Never,
                    ColumnData::Any(_) => Cls::PerElem,
                }
            }
        }
    }
    let (ca, cb) = (classify(a), classify(b));
    if matches!(ca, Cls::Never) || matches!(cb, Cls::Never) {
        return VecVal::Const(Field::Null);
    }
    if matches!(ca, Cls::PerElem) || matches!(cb, Cls::PerElem) {
        return VecVal::Owned(elementwise(&[a, b], len, |fs| {
            scalar_binary(op, &fs[0], &fs[1])
        }));
    }
    // both sides are numeric columns/constants: one f64 pass with a
    // combined null mask (matching scalar `as_f64` coercion for arithmetic)
    fn at(c: &Cls<'_>, i: usize) -> Option<f64> {
        match c {
            Cls::I64(xs, n) => (!n.is_some_and(|m| m[i])).then(|| xs[i] as f64),
            Cls::F64(xs, n) => (!n.is_some_and(|m| m[i])).then(|| xs[i]),
            Cls::Const(x) => Some(*x),
            _ => unreachable!("Never/PerElem handled above"),
        }
    }
    let mut out = Vec::with_capacity(len);
    let mut nulls = vec![false; len];
    let mut any_null = false;
    for i in 0..len {
        match (at(&ca, i), at(&cb, i)) {
            (Some(x), Some(y)) => out.push(match op {
                BinOp::Add => x + y,
                BinOp::Sub => x - y,
                BinOp::Mul => x * y,
                _ => x / y,
            }),
            _ => {
                out.push(0.0);
                nulls[i] = true;
                any_null = true;
            }
        }
    }
    VecVal::Owned(Column { data: ColumnData::F64(out), nulls: any_null.then_some(nulls) })
}

fn vcall(f: Func, vals: &[VecVal<'_>], len: usize) -> VecVal<'static> {
    if vals.iter().all(|v| matches!(v, VecVal::Const(_))) {
        let fields: Vec<Field> = vals.iter().map(|v| v.field_at(0)).collect();
        return VecVal::Const(scalar_call(f, &fields));
    }
    // str-column fast paths; anything else goes element-wise
    let str_col = |v: &VecVal<'_>| -> bool {
        v.col().is_some_and(|c| matches!(c.data, ColumnData::Str(_)))
    };
    match f {
        Func::Length | Func::Lower | Func::Upper if vals.len() == 1 && str_col(&vals[0]) => {
            let c = vals[0].col().unwrap();
            let ColumnData::Str(xs) = &c.data else { unreachable!() };
            let data = match f {
                Func::Length => {
                    ColumnData::I64(xs.iter().map(|s| s.chars().count() as i64).collect())
                }
                Func::Lower => ColumnData::Str(xs.iter().map(|s| s.to_lowercase()).collect()),
                _ => ColumnData::Str(xs.iter().map(|s| s.to_uppercase()).collect()),
            };
            VecVal::Owned(Column { data, nulls: c.nulls.clone() })
        }
        Func::Contains | Func::StartsWith
            if vals.len() == 2
                && str_col(&vals[0])
                && matches!(&vals[1], VecVal::Const(Field::Str(_))) =>
        {
            let c = vals[0].col().unwrap();
            let ColumnData::Str(xs) = &c.data else { unreachable!() };
            let VecVal::Const(Field::Str(pat)) = &vals[1] else { unreachable!() };
            let hit: Box<dyn Fn(&str) -> bool + '_> = match f {
                Func::Contains => Box::new(|s: &str| s.contains(pat.as_str())),
                _ => Box::new(|s: &str| s.starts_with(pat.as_str())),
            };
            let v: Vec<bool> = match &c.nulls {
                // null slot → as_str(Null) is None → scalar returns false
                None => xs.iter().map(|s| hit(s)).collect(),
                Some(m) => xs.iter().zip(m).map(|(s, n)| !*n && hit(s)).collect(),
            };
            VecVal::Owned(bool_col(v))
        }
        _ => {
            let refs: Vec<&VecVal<'_>> = vals.iter().collect();
            let mut buf: Vec<Field> = Vec::with_capacity(vals.len());
            let mut out = Vec::with_capacity(len);
            for i in 0..len {
                buf.clear();
                for v in &refs {
                    buf.push(v.field_at(i));
                }
                out.push(scalar_call(f, &buf));
            }
            VecVal::Owned(Column::from_fields(out))
        }
    }
}

// ------------------------- optimizer helpers ------------------------

/// All column indices referenced by the expression.
pub fn cols_used(e: &Expr) -> BTreeSet<usize> {
    let mut out = BTreeSet::new();
    collect_cols(e, &mut out);
    out
}

fn collect_cols(e: &Expr, out: &mut BTreeSet<usize>) {
    match e {
        Expr::Lit(_) => {}
        Expr::Col(i, _) => {
            out.insert(*i);
        }
        Expr::Unary(_, x) => collect_cols(x, out),
        Expr::Binary(_, a, b) => {
            collect_cols(a, out);
            collect_cols(b, out);
        }
        Expr::Call(_, args) => {
            for a in args {
                collect_cols(a, out);
            }
        }
    }
}

/// The highest column index the expression references, with that
/// column's display name. `None` for column-free expressions. The
/// executor checks this bound against each input row/batch so an
/// out-of-range reference surfaces as a structured engine error on both
/// the row and vectorized paths (instead of an index panic).
pub fn max_col(e: &Expr) -> Option<(usize, &str)> {
    match e {
        Expr::Lit(_) => None,
        Expr::Col(i, n) => Some((*i, n.as_str())),
        Expr::Unary(_, x) => max_col(x),
        Expr::Binary(_, a, b) => match (max_col(a), max_col(b)) {
            (Some(l), Some(r)) => Some(if r.0 > l.0 { r } else { l }),
            (l, r) => l.or(r),
        },
        Expr::Call(_, args) => args
            .iter()
            .filter_map(max_col)
            .max_by_key(|(i, _)| *i),
    }
}

/// Rebuild the expression with every column reference mapped through `f`
/// (index + display name). Used when pushing predicates below projections
/// or into join sides.
pub fn map_cols(e: &Expr, f: &dyn Fn(usize, &str) -> (usize, String)) -> Expr {
    match e {
        Expr::Lit(v) => Expr::Lit(v.clone()),
        Expr::Col(i, n) => {
            let (ni, nn) = f(*i, n);
            Expr::Col(ni, nn)
        }
        Expr::Unary(op, x) => Expr::Unary(*op, Box::new(map_cols(x, f))),
        Expr::Binary(op, a, b) => {
            Expr::Binary(*op, Box::new(map_cols(a, f)), Box::new(map_cols(b, f)))
        }
        Expr::Call(func, args) => {
            Expr::Call(*func, args.iter().map(|a| map_cols(a, f)).collect())
        }
    }
}

/// Split a predicate into top-level AND conjuncts. In filter position only
/// truthiness matters, so `a and b` keeps a row iff both conjuncts are
/// truthy — each can be pushed independently.
pub fn conjuncts(e: &Expr) -> Vec<Expr> {
    match e {
        Expr::Binary(BinOp::And, a, b) => {
            let mut v = conjuncts(a);
            v.extend(conjuncts(b));
            v
        }
        _ => vec![e.clone()],
    }
}

/// Re-join conjuncts with AND (left-associated). Panics on empty input.
pub fn and_all(mut v: Vec<Expr>) -> Expr {
    assert!(!v.is_empty(), "and_all needs at least one conjunct");
    let mut acc = v.remove(0);
    for e in v {
        acc = Expr::Binary(BinOp::And, Box::new(acc), Box::new(e));
    }
    acc
}

/// Constant folding: bottom-up, any operator node whose children are all
/// literals is replaced by its value. The replacement value comes from
/// [`eval`] on an empty row (literal-only subtrees never read the row), so
/// folding is exactly runtime semantics — division by zero, NaN equality,
/// type-mismatch comparisons and all. Returns the folded expression and
/// the number of nodes folded; idempotent (a second pass folds nothing).
pub fn fold(e: &Expr) -> (Expr, u64) {
    let empty = Row::new(Vec::new());
    fold_inner(e, &empty)
}

fn fold_inner(e: &Expr, empty: &Row) -> (Expr, u64) {
    fn is_lit(e: &Expr) -> bool {
        matches!(e, Expr::Lit(_))
    }
    match e {
        Expr::Lit(_) | Expr::Col(..) => (e.clone(), 0),
        Expr::Unary(op, x) => {
            let (fx, n) = fold_inner(x, empty);
            if is_lit(&fx) {
                let node = Expr::Unary(*op, Box::new(fx));
                (Expr::Lit(eval(&node, empty)), n + 1)
            } else {
                (Expr::Unary(*op, Box::new(fx)), n)
            }
        }
        Expr::Binary(op, a, b) => {
            let (fa, na) = fold_inner(a, empty);
            let (fb, nb) = fold_inner(b, empty);
            if is_lit(&fa) && is_lit(&fb) {
                let node = Expr::Binary(*op, Box::new(fa), Box::new(fb));
                (Expr::Lit(eval(&node, empty)), na + nb + 1)
            } else {
                (Expr::Binary(*op, Box::new(fa), Box::new(fb)), na + nb)
            }
        }
        Expr::Call(func, args) => {
            let mut n = 0;
            let folded: Vec<Expr> = args
                .iter()
                .map(|a| {
                    let (fa, na) = fold_inner(a, empty);
                    n += na;
                    fa
                })
                .collect();
            if folded.iter().all(is_lit) {
                let node = Expr::Call(*func, folded);
                (Expr::Lit(eval(&node, empty)), n + 1)
            } else {
                (Expr::Call(*func, folded), n)
            }
        }
    }
}

// ------------------------------ display -----------------------------

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Lit(Field::Str(s)) => {
                // escape so the printed literal re-lexes to the same string
                // (the SQL lexer decodes \' and \\)
                use fmt::Write as _;
                f.write_char('\'')?;
                for ch in s.chars() {
                    match ch {
                        '\'' => f.write_str("\\'")?,
                        '\\' => f.write_str("\\\\")?,
                        _ => f.write_char(ch)?,
                    }
                }
                f.write_char('\'')
            }
            Expr::Lit(v) => write!(f, "{v}"),
            Expr::Col(_, name) => write!(f, "{name}"),
            Expr::Unary(UnOp::Not, x) => write!(f, "not {x}"),
            Expr::Unary(UnOp::Neg, x) => write!(f, "-{x}"),
            Expr::Binary(op, a, b) => write!(f, "({a} {op} {b})"),
            Expr::Call(func, args) => {
                write!(f, "{func}(")?;
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{a}")?;
                }
                write!(f, ")")
            }
        }
    }
}

impl fmt::Display for BinOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            BinOp::Or => "or",
            BinOp::And => "and",
            BinOp::Eq => "=",
            BinOp::Ne => "!=",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
        };
        write!(f, "{s}")
    }
}

impl fmt::Display for Func {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Func::Length => "length",
            Func::Lower => "lower",
            Func::Upper => "upper",
            Func::Contains => "contains",
            Func::StartsWith => "starts_with",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(i: usize, n: &str) -> Expr {
        Expr::Col(i, n.to_string())
    }

    fn lit(f: Field) -> Expr {
        Expr::Lit(f)
    }

    #[test]
    fn cols_used_walks_all_arms() {
        let e = Expr::Binary(
            BinOp::And,
            Box::new(Expr::Binary(BinOp::Gt, Box::new(col(2, "c")), Box::new(lit(Field::F64(1.0))))),
            Box::new(Expr::Call(Func::Contains, vec![col(0, "a"), lit(Field::Str("x".into()))])),
        );
        let used: Vec<usize> = cols_used(&e).into_iter().collect();
        assert_eq!(used, vec![0, 2]);
    }

    #[test]
    fn bucket_indices_match_rowwise_bucketing_with_placeholder_collisions() {
        use crate::engine::executor::bucket_of;
        // typed column where real zeros sit next to nulls (whose storage
        // slots hold the 0 placeholder under the mask): the columnar
        // bucketing must land every slot where the row path would
        let fields = vec![
            Field::I64(0),
            Field::Null,
            Field::I64(7),
            Field::Null,
            Field::I64(0),
            Field::I64(-1),
        ];
        let col = Column::from_fields(fields.clone());
        assert!(col.nulls.is_some(), "masked typed column is the case under test");
        for parts in [1usize, 2, 3, 7] {
            let idxs = bucket_indices(&col, parts);
            let mut expect: Vec<Vec<usize>> = (0..parts).map(|_| Vec::new()).collect();
            for (i, f) in fields.iter().enumerate() {
                expect[bucket_of(f, parts)].push(i);
            }
            assert_eq!(idxs, expect, "bucket layout diverged at {parts} parts");
        }
    }

    #[test]
    fn conjunct_roundtrip() {
        let e = Expr::Binary(
            BinOp::And,
            Box::new(Expr::Binary(
                BinOp::And,
                Box::new(col(0, "a")),
                Box::new(col(1, "b")),
            )),
            Box::new(col(2, "c")),
        );
        let parts = conjuncts(&e);
        assert_eq!(parts.len(), 3);
        let back = and_all(parts);
        let r = crate::row!(true, true, true);
        assert_eq!(eval(&back, &r), eval(&e, &r));
    }

    #[test]
    fn fold_matches_runtime_eval() {
        // (1 + 2) * 3 > 8  →  fully literal, folds to Bool(true)
        let e = Expr::Binary(
            BinOp::Gt,
            Box::new(Expr::Binary(
                BinOp::Mul,
                Box::new(Expr::Binary(
                    BinOp::Add,
                    Box::new(lit(Field::F64(1.0))),
                    Box::new(lit(Field::F64(2.0))),
                )),
                Box::new(lit(Field::F64(3.0))),
            )),
            Box::new(lit(Field::F64(8.0))),
        );
        let empty = Row::new(vec![]);
        let (folded, n) = fold(&e);
        assert_eq!(n, 3);
        assert_eq!(eval(&folded, &empty), eval(&e, &empty));
        assert!(matches!(folded, Expr::Lit(Field::Bool(true))));
        // idempotent
        let (_, n2) = fold(&folded);
        assert_eq!(n2, 0);
    }

    #[test]
    fn fold_preserves_division_by_zero_semantics() {
        // 1/0 → inf (truthy), 0/0 → NaN; NaN = NaN is false at runtime and
        // must stay false after folding
        let div = |a: f64, b: f64| {
            Expr::Binary(BinOp::Div, Box::new(lit(Field::F64(a))), Box::new(lit(Field::F64(b))))
        };
        let empty = Row::new(vec![]);
        let (f1, _) = fold(&div(1.0, 0.0));
        assert!(matches!(&f1, Expr::Lit(Field::F64(v)) if v.is_infinite()));
        let nan_eq = Expr::Binary(BinOp::Eq, Box::new(div(0.0, 0.0)), Box::new(div(0.0, 0.0)));
        let (folded, _) = fold(&nan_eq);
        assert_eq!(eval(&folded, &empty), Field::Bool(false));
        assert_eq!(eval(&nan_eq, &empty), Field::Bool(false));
    }

    #[test]
    fn fold_stops_at_columns() {
        let e = Expr::Binary(
            BinOp::Gt,
            Box::new(col(0, "x")),
            Box::new(Expr::Binary(
                BinOp::Add,
                Box::new(lit(Field::F64(1.0))),
                Box::new(lit(Field::F64(2.0))),
            )),
        );
        let (folded, n) = fold(&e);
        assert_eq!(n, 1);
        match folded {
            Expr::Binary(BinOp::Gt, l, r) => {
                assert!(matches!(*l, Expr::Col(0, _)));
                assert!(matches!(*r, Expr::Lit(Field::F64(v)) if v == 3.0));
            }
            other => panic!("unexpected shape {other:?}"),
        }
    }

    #[test]
    fn map_cols_remaps_index_and_name() {
        let e = Expr::Binary(BinOp::Gt, Box::new(col(1, "b")), Box::new(lit(Field::F64(0.0))));
        let m = map_cols(&e, &|i, _| (i + 10, format!("c{}", i + 10)));
        assert_eq!(cols_used(&m).into_iter().collect::<Vec<_>>(), vec![11]);
        assert_eq!(m.to_string(), "(c11 > 0)");
    }

    #[test]
    fn max_col_picks_highest_index() {
        assert_eq!(max_col(&lit(Field::I64(1))), None);
        assert_eq!(max_col(&col(3, "c")), Some((3, "c")));
        // highest index wins across both binary arms and call args
        let e = Expr::Binary(
            BinOp::And,
            Box::new(Expr::Binary(BinOp::Gt, Box::new(col(1, "a")), Box::new(col(7, "g")))),
            Box::new(Expr::Call(Func::Contains, vec![col(4, "d"), lit(Field::Str("x".into()))])),
        );
        assert_eq!(max_col(&e), Some((7, "g")));
        // literal-only arms don't mask the column-bearing one
        let u = Expr::Unary(
            UnOp::Not,
            Box::new(Expr::Binary(BinOp::Eq, Box::new(lit(Field::I64(0))), Box::new(col(2, "b")))),
        );
        assert_eq!(max_col(&u), Some((2, "b")));
    }

    #[test]
    fn display_shapes() {
        let e = Expr::Unary(
            UnOp::Not,
            Box::new(Expr::Binary(
                BinOp::Eq,
                Box::new(col(0, "id")),
                Box::new(lit(Field::F64(1.0))),
            )),
        );
        assert_eq!(e.to_string(), "not (id = 1)");
        let c = Expr::Call(Func::Contains, vec![col(1, "name"), lit(Field::Str("x".into()))]);
        assert_eq!(c.to_string(), "contains(name, 'x')");
    }

    #[test]
    fn display_escapes_string_literals() {
        // regression: quotes/backslashes used to print verbatim, making
        // plan_display() output ambiguous (`'it's'` / `'a\'`)
        assert_eq!(lit(Field::Str("it's".into())).to_string(), r"'it\'s'");
        assert_eq!(lit(Field::Str(r"a\b".into())).to_string(), r"'a\\b'");
        assert_eq!(lit(Field::Str(r"\'".into())).to_string(), r"'\\\''");
        assert_eq!(lit(Field::Str("plain".into())).to_string(), "'plain'");
    }

    #[test]
    fn cmp_i64_f64_exact_at_2_pow_53() {
        use std::cmp::Ordering::*;
        const P53: i64 = 1 << 53; // 9007199254740992: first integer with f64 neighbors 2 apart
        // regression: `(P53 + 1) as f64 == P53 as f64`, so the old lossy
        // coercion judged these Equal
        assert_eq!(cmp_i64_f64(P53 + 1, P53 as f64), Some(Greater));
        assert_eq!(cmp_i64_f64(P53 - 1, P53 as f64), Some(Less));
        assert_eq!(cmp_i64_f64(P53, P53 as f64), Some(Equal));
        assert_eq!(cmp_i64_f64(-(P53 + 1), -(P53 as f64)), Some(Less));
        // i64 range edges and non-finite right-hand sides
        assert_eq!(cmp_i64_f64(i64::MAX, 9_223_372_036_854_775_808.0), Some(Less));
        assert_eq!(cmp_i64_f64(i64::MIN, -9_223_372_036_854_775_808.0), Some(Equal));
        assert_eq!(cmp_i64_f64(0, f64::INFINITY), Some(Less));
        assert_eq!(cmp_i64_f64(0, f64::NEG_INFINITY), Some(Greater));
        assert_eq!(cmp_i64_f64(0, f64::NAN), None);
        // fractional ties around trunc, both signs
        assert_eq!(cmp_i64_f64(3, 3.5), Some(Less));
        assert_eq!(cmp_i64_f64(-3, -3.5), Some(Greater));
        assert_eq!(cmp_i64_f64(4, 3.5), Some(Greater));
        assert_eq!(cmp_i64_f64(-4, -3.5), Some(Less));
    }

    #[test]
    fn field_compare_exact_regressions() {
        const P53: i64 = 1 << 53;
        // mixed I64/F64: exact, not through a lossy cast
        assert!(!field_eq(&Field::I64(P53 + 1), &Field::F64(P53 as f64)));
        assert!(field_eq(&Field::I64(P53), &Field::F64(P53 as f64)));
        // pure I64: the old path coerced BOTH sides to f64, collapsing
        // 2^53 and 2^53+1
        assert!(!field_eq(&Field::I64(P53), &Field::I64(P53 + 1)));
        assert_eq!(
            field_cmp(&Field::I64(P53), &Field::I64(P53 + 1)),
            Some(std::cmp::Ordering::Less)
        );
        // and end-to-end through eval
        let e = Expr::Binary(
            BinOp::Eq,
            Box::new(col(0, "x")),
            Box::new(lit(Field::F64(P53 as f64))),
        );
        assert_eq!(eval(&e, &crate::row!(P53 + 1)), Field::Bool(false));
        assert_eq!(eval(&e, &crate::row!(P53)), Field::Bool(true));
        // unchanged semantics elsewhere: NaN, zero signs, null equality
        assert!(!field_eq(&Field::F64(f64::NAN), &Field::F64(f64::NAN)));
        assert!(field_eq(&Field::F64(0.0), &Field::F64(-0.0)));
        assert!(field_eq(&Field::Null, &Field::Null));
        assert_eq!(field_cmp(&Field::Bool(true), &Field::Bool(false)), None);
    }

    // ------------------ vector/scalar agreement suite ------------------

    use crate::engine::row::ColumnBatch;
    use crate::util::testkit::{property, Gen};

    fn rand_field(g: &mut Gen, ty: usize) -> Field {
        if g.u64(8) == 0 {
            return Field::Null;
        }
        match ty {
            0 => Field::Bool(g.bool()),
            1 => Field::I64(match g.u64(6) {
                0 => (1 << 53) + g.u64(3) as i64 - 1,
                1 => -(g.u64(100) as i64),
                _ => g.u64(100) as i64,
            }),
            2 => Field::F64(match g.u64(8) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                3 => -0.0,
                4 => 9007199254740992.0,
                _ => g.u64(100) as f64 / 4.0 - 5.0,
            }),
            _ => Field::Str(["", "a", "ab", "it's", "x\\y"][g.u64(5) as usize].to_string()),
        }
    }

    fn rand_expr(g: &mut Gen, width: usize, depth: usize) -> Expr {
        if depth == 0 || g.u64(4) == 0 {
            return if g.bool() {
                col(g.u64(width as u64) as usize, "c")
            } else {
                lit(rand_field(g, g.u64(4) as usize))
            };
        }
        match g.u64(10) {
            0 => Expr::Unary(if g.bool() { UnOp::Not } else { UnOp::Neg },
                Box::new(rand_expr(g, width, depth - 1))),
            1 => Expr::Call(
                [Func::Length, Func::Lower, Func::Upper][g.u64(3) as usize],
                vec![rand_expr(g, width, depth - 1)],
            ),
            2 => Expr::Call(
                if g.bool() { Func::Contains } else { Func::StartsWith },
                vec![rand_expr(g, width, depth - 1), rand_expr(g, width, depth - 1)],
            ),
            _ => {
                let ops = [BinOp::Or, BinOp::And, BinOp::Eq, BinOp::Ne, BinOp::Lt,
                    BinOp::Le, BinOp::Gt, BinOp::Ge, BinOp::Add, BinOp::Sub,
                    BinOp::Mul, BinOp::Div];
                Expr::Binary(
                    ops[g.u64(12) as usize],
                    Box::new(rand_expr(g, width, depth - 1)),
                    Box::new(rand_expr(g, width, depth - 1)),
                )
            }
        }
    }

    /// The load-bearing tentpole property: over random typed batches
    /// (nulls, NaN/±inf, 2^53-boundary ints, tricky strings) a random
    /// expression evaluated column-at-a-time equals row-at-a-time `eval`,
    /// element for element, and `eval_mask` equals per-row truthiness.
    #[test]
    fn vectorized_eval_matches_scalar_eval() {
        property(200, |g| {
            let width = 1 + g.u64(4) as usize;
            // single-row batches included; zero-row batches have no
            // per-column storage to reference (the executor short-circuits
            // empty partitions before the kernels — pinned in executor and
            // tests/vectorize.rs)
            let n = 1 + g.u64(11) as usize;
            // per-column fixed type keeps the batch typed (mixed columns
            // are handled by the executor's row fallback, not kernels)
            let tys: Vec<usize> = (0..width).map(|_| g.u64(4) as usize).collect();
            let rows: Vec<Row> = (0..n)
                .map(|_| Row::new(tys.iter().map(|t| rand_field(g, *t)).collect()))
                .collect();
            let batch = ColumnBatch::try_from_rows(&rows).expect("typed rows form a batch");
            let e = rand_expr(g, width, 3);
            let out = eval_batch(&e, &batch);
            let mask = eval_mask(&e, &batch);
            for (i, row) in rows.iter().enumerate() {
                let want = eval(&e, row);
                let got = out.field_at(i);
                assert_eq!(
                    got.canonical_cmp(&want),
                    std::cmp::Ordering::Equal,
                    "row {i}: vector {got:?} != scalar {want:?} for `{e}`"
                );
                assert_eq!(mask[i], truthy(&want), "mask diverged at row {i} for `{e}`");
            }
        });
    }

    #[test]
    fn vectorized_all_null_column() {
        let rows = vec![
            Row::new(vec![Field::Null, Field::I64(1)]),
            Row::new(vec![Field::Null, Field::I64(2)]),
        ];
        let batch = ColumnBatch::try_from_rows(&rows).unwrap();
        // null = null is true; null < 5 is false; null + 1 is null (falsy)
        let eqe = Expr::Binary(BinOp::Eq, Box::new(col(0, "a")), Box::new(lit(Field::Null)));
        assert_eq!(eval_mask(&eqe, &batch), vec![true, true]);
        let lte = Expr::Binary(BinOp::Lt, Box::new(col(0, "a")), Box::new(lit(Field::I64(5))));
        assert_eq!(eval_mask(&lte, &batch), vec![false, false]);
        let add = Expr::Binary(BinOp::Add, Box::new(col(0, "a")), Box::new(lit(Field::I64(1))));
        assert_eq!(eval_mask(&add, &batch), vec![false, false]);
    }
}
