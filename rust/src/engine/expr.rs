//! SQL expression AST + evaluator, engine-resident so the logical plan
//! optimizer can inspect and rewrite structured filters/projections
//! ([`super::dataset::Plan::FilterExpr`] / [`Plan::Project`]).
//!
//! The parser lives with the SQL pipe (`crate::pipes::sql::compile`); this
//! module owns everything the optimizer needs: evaluation, column usage,
//! column remapping, conjunct splitting and constant folding. Constant
//! folding reuses [`eval`] itself on literal-only subtrees, so folded and
//! runtime evaluation can never disagree.

use super::row::{Field, Row};
use std::collections::BTreeSet;
use std::fmt;

// ------------------------------- AST --------------------------------

#[derive(Debug, Clone)]
pub enum Expr {
    Lit(Field),
    /// column reference: resolved index + source name (kept for display)
    Col(usize, String),
    Unary(UnOp, Box<Expr>),
    Binary(BinOp, Box<Expr>, Box<Expr>),
    Call(Func, Vec<Expr>),
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum UnOp {
    Not,
    Neg,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BinOp {
    Or,
    And,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Add,
    Sub,
    Mul,
    Div,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Func {
    Length,
    Lower,
    Upper,
    Contains,
    StartsWith,
}

// ----------------------------- evaluator ----------------------------

/// Evaluate an expression against a row.
pub fn eval(e: &Expr, row: &Row) -> Field {
    match e {
        Expr::Lit(f) => f.clone(),
        Expr::Col(i, _) => row.get(*i).clone(),
        Expr::Unary(UnOp::Not, x) => Field::Bool(!truthy(&eval(x, row))),
        Expr::Unary(UnOp::Neg, x) => match eval(x, row) {
            Field::I64(v) => Field::I64(-v),
            Field::F64(v) => Field::F64(-v),
            _ => Field::Null,
        },
        Expr::Binary(op, a, b) => {
            let (va, vb) = (eval(a, row), eval(b, row));
            match op {
                BinOp::Or => Field::Bool(truthy(&va) || truthy(&vb)),
                BinOp::And => Field::Bool(truthy(&va) && truthy(&vb)),
                BinOp::Eq => Field::Bool(field_eq(&va, &vb)),
                BinOp::Ne => Field::Bool(!field_eq(&va, &vb)),
                BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge => match field_cmp(&va, &vb) {
                    Some(ord) => Field::Bool(match op {
                        BinOp::Lt => ord.is_lt(),
                        BinOp::Le => ord.is_le(),
                        BinOp::Gt => ord.is_gt(),
                        _ => ord.is_ge(),
                    }),
                    None => Field::Bool(false),
                },
                BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div => {
                    match (va.as_f64(), vb.as_f64()) {
                        (Some(x), Some(y)) => Field::F64(match op {
                            BinOp::Add => x + y,
                            BinOp::Sub => x - y,
                            BinOp::Mul => x * y,
                            _ => x / y,
                        }),
                        _ => Field::Null,
                    }
                }
            }
        }
        Expr::Call(f, args) => {
            let vals: Vec<Field> = args.iter().map(|a| eval(a, row)).collect();
            match f {
                Func::Length => vals
                    .first()
                    .and_then(|v| v.as_str())
                    .map(|s| Field::I64(s.chars().count() as i64))
                    .unwrap_or(Field::Null),
                Func::Lower => vals
                    .first()
                    .and_then(|v| v.as_str())
                    .map(|s| Field::Str(s.to_lowercase()))
                    .unwrap_or(Field::Null),
                Func::Upper => vals
                    .first()
                    .and_then(|v| v.as_str())
                    .map(|s| Field::Str(s.to_uppercase()))
                    .unwrap_or(Field::Null),
                Func::Contains => match (
                    vals.first().and_then(|v| v.as_str()),
                    vals.get(1).and_then(|v| v.as_str()),
                ) {
                    (Some(s), Some(sub)) => Field::Bool(s.contains(sub)),
                    _ => Field::Bool(false),
                },
                Func::StartsWith => match (
                    vals.first().and_then(|v| v.as_str()),
                    vals.get(1).and_then(|v| v.as_str()),
                ) {
                    (Some(s), Some(p)) => Field::Bool(s.starts_with(p)),
                    _ => Field::Bool(false),
                },
            }
        }
    }
}

/// SQL-ish truthiness: null/false/0/empty are false, everything else true
/// (note: NaN != 0.0, so NaN is truthy — pinned by tests).
pub fn truthy(f: &Field) -> bool {
    match f {
        Field::Bool(b) => *b,
        Field::Null => false,
        Field::I64(v) => *v != 0,
        Field::F64(v) => *v != 0.0,
        Field::Str(s) => !s.is_empty(),
        Field::Bytes(b) => !b.is_empty(),
    }
}

/// Equality with numeric coercion (I64 vs F64 compare as f64).
pub fn field_eq(a: &Field, b: &Field) -> bool {
    match (a.as_f64(), b.as_f64()) {
        (Some(x), Some(y)) => x == y,
        _ => a == b,
    }
}

/// Ordering: strings compare lexicographically, numbers numerically;
/// mismatched / non-comparable types return `None` (comparisons on `None`
/// evaluate to false — pinned by tests).
pub fn field_cmp(a: &Field, b: &Field) -> Option<std::cmp::Ordering> {
    match (a, b) {
        (Field::Str(x), Field::Str(y)) => Some(x.cmp(y)),
        _ => match (a.as_f64(), b.as_f64()) {
            (Some(x), Some(y)) => x.partial_cmp(&y),
            _ => None,
        },
    }
}

// ------------------------- optimizer helpers ------------------------

/// All column indices referenced by the expression.
pub fn cols_used(e: &Expr) -> BTreeSet<usize> {
    let mut out = BTreeSet::new();
    collect_cols(e, &mut out);
    out
}

fn collect_cols(e: &Expr, out: &mut BTreeSet<usize>) {
    match e {
        Expr::Lit(_) => {}
        Expr::Col(i, _) => {
            out.insert(*i);
        }
        Expr::Unary(_, x) => collect_cols(x, out),
        Expr::Binary(_, a, b) => {
            collect_cols(a, out);
            collect_cols(b, out);
        }
        Expr::Call(_, args) => {
            for a in args {
                collect_cols(a, out);
            }
        }
    }
}

/// Rebuild the expression with every column reference mapped through `f`
/// (index + display name). Used when pushing predicates below projections
/// or into join sides.
pub fn map_cols(e: &Expr, f: &dyn Fn(usize, &str) -> (usize, String)) -> Expr {
    match e {
        Expr::Lit(v) => Expr::Lit(v.clone()),
        Expr::Col(i, n) => {
            let (ni, nn) = f(*i, n);
            Expr::Col(ni, nn)
        }
        Expr::Unary(op, x) => Expr::Unary(*op, Box::new(map_cols(x, f))),
        Expr::Binary(op, a, b) => {
            Expr::Binary(*op, Box::new(map_cols(a, f)), Box::new(map_cols(b, f)))
        }
        Expr::Call(func, args) => {
            Expr::Call(*func, args.iter().map(|a| map_cols(a, f)).collect())
        }
    }
}

/// Split a predicate into top-level AND conjuncts. In filter position only
/// truthiness matters, so `a and b` keeps a row iff both conjuncts are
/// truthy — each can be pushed independently.
pub fn conjuncts(e: &Expr) -> Vec<Expr> {
    match e {
        Expr::Binary(BinOp::And, a, b) => {
            let mut v = conjuncts(a);
            v.extend(conjuncts(b));
            v
        }
        _ => vec![e.clone()],
    }
}

/// Re-join conjuncts with AND (left-associated). Panics on empty input.
pub fn and_all(mut v: Vec<Expr>) -> Expr {
    assert!(!v.is_empty(), "and_all needs at least one conjunct");
    let mut acc = v.remove(0);
    for e in v {
        acc = Expr::Binary(BinOp::And, Box::new(acc), Box::new(e));
    }
    acc
}

/// Constant folding: bottom-up, any operator node whose children are all
/// literals is replaced by its value. The replacement value comes from
/// [`eval`] on an empty row (literal-only subtrees never read the row), so
/// folding is exactly runtime semantics — division by zero, NaN equality,
/// type-mismatch comparisons and all. Returns the folded expression and
/// the number of nodes folded; idempotent (a second pass folds nothing).
pub fn fold(e: &Expr) -> (Expr, u64) {
    let empty = Row::new(Vec::new());
    fold_inner(e, &empty)
}

fn fold_inner(e: &Expr, empty: &Row) -> (Expr, u64) {
    fn is_lit(e: &Expr) -> bool {
        matches!(e, Expr::Lit(_))
    }
    match e {
        Expr::Lit(_) | Expr::Col(..) => (e.clone(), 0),
        Expr::Unary(op, x) => {
            let (fx, n) = fold_inner(x, empty);
            if is_lit(&fx) {
                let node = Expr::Unary(*op, Box::new(fx));
                (Expr::Lit(eval(&node, empty)), n + 1)
            } else {
                (Expr::Unary(*op, Box::new(fx)), n)
            }
        }
        Expr::Binary(op, a, b) => {
            let (fa, na) = fold_inner(a, empty);
            let (fb, nb) = fold_inner(b, empty);
            if is_lit(&fa) && is_lit(&fb) {
                let node = Expr::Binary(*op, Box::new(fa), Box::new(fb));
                (Expr::Lit(eval(&node, empty)), na + nb + 1)
            } else {
                (Expr::Binary(*op, Box::new(fa), Box::new(fb)), na + nb)
            }
        }
        Expr::Call(func, args) => {
            let mut n = 0;
            let folded: Vec<Expr> = args
                .iter()
                .map(|a| {
                    let (fa, na) = fold_inner(a, empty);
                    n += na;
                    fa
                })
                .collect();
            if folded.iter().all(is_lit) {
                let node = Expr::Call(*func, folded);
                (Expr::Lit(eval(&node, empty)), n + 1)
            } else {
                (Expr::Call(*func, folded), n)
            }
        }
    }
}

// ------------------------------ display -----------------------------

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Lit(Field::Str(s)) => write!(f, "'{s}'"),
            Expr::Lit(v) => write!(f, "{v}"),
            Expr::Col(_, name) => write!(f, "{name}"),
            Expr::Unary(UnOp::Not, x) => write!(f, "not {x}"),
            Expr::Unary(UnOp::Neg, x) => write!(f, "-{x}"),
            Expr::Binary(op, a, b) => write!(f, "({a} {op} {b})"),
            Expr::Call(func, args) => {
                write!(f, "{func}(")?;
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{a}")?;
                }
                write!(f, ")")
            }
        }
    }
}

impl fmt::Display for BinOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            BinOp::Or => "or",
            BinOp::And => "and",
            BinOp::Eq => "=",
            BinOp::Ne => "!=",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
        };
        write!(f, "{s}")
    }
}

impl fmt::Display for Func {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Func::Length => "length",
            Func::Lower => "lower",
            Func::Upper => "upper",
            Func::Contains => "contains",
            Func::StartsWith => "starts_with",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(i: usize, n: &str) -> Expr {
        Expr::Col(i, n.to_string())
    }

    fn lit(f: Field) -> Expr {
        Expr::Lit(f)
    }

    #[test]
    fn cols_used_walks_all_arms() {
        let e = Expr::Binary(
            BinOp::And,
            Box::new(Expr::Binary(BinOp::Gt, Box::new(col(2, "c")), Box::new(lit(Field::F64(1.0))))),
            Box::new(Expr::Call(Func::Contains, vec![col(0, "a"), lit(Field::Str("x".into()))])),
        );
        let used: Vec<usize> = cols_used(&e).into_iter().collect();
        assert_eq!(used, vec![0, 2]);
    }

    #[test]
    fn conjunct_roundtrip() {
        let e = Expr::Binary(
            BinOp::And,
            Box::new(Expr::Binary(
                BinOp::And,
                Box::new(col(0, "a")),
                Box::new(col(1, "b")),
            )),
            Box::new(col(2, "c")),
        );
        let parts = conjuncts(&e);
        assert_eq!(parts.len(), 3);
        let back = and_all(parts);
        let r = crate::row!(true, true, true);
        assert_eq!(eval(&back, &r), eval(&e, &r));
    }

    #[test]
    fn fold_matches_runtime_eval() {
        // (1 + 2) * 3 > 8  →  fully literal, folds to Bool(true)
        let e = Expr::Binary(
            BinOp::Gt,
            Box::new(Expr::Binary(
                BinOp::Mul,
                Box::new(Expr::Binary(
                    BinOp::Add,
                    Box::new(lit(Field::F64(1.0))),
                    Box::new(lit(Field::F64(2.0))),
                )),
                Box::new(lit(Field::F64(3.0))),
            )),
            Box::new(lit(Field::F64(8.0))),
        );
        let empty = Row::new(vec![]);
        let (folded, n) = fold(&e);
        assert_eq!(n, 3);
        assert_eq!(eval(&folded, &empty), eval(&e, &empty));
        assert!(matches!(folded, Expr::Lit(Field::Bool(true))));
        // idempotent
        let (_, n2) = fold(&folded);
        assert_eq!(n2, 0);
    }

    #[test]
    fn fold_preserves_division_by_zero_semantics() {
        // 1/0 → inf (truthy), 0/0 → NaN; NaN = NaN is false at runtime and
        // must stay false after folding
        let div = |a: f64, b: f64| {
            Expr::Binary(BinOp::Div, Box::new(lit(Field::F64(a))), Box::new(lit(Field::F64(b))))
        };
        let empty = Row::new(vec![]);
        let (f1, _) = fold(&div(1.0, 0.0));
        assert!(matches!(&f1, Expr::Lit(Field::F64(v)) if v.is_infinite()));
        let nan_eq = Expr::Binary(BinOp::Eq, Box::new(div(0.0, 0.0)), Box::new(div(0.0, 0.0)));
        let (folded, _) = fold(&nan_eq);
        assert_eq!(eval(&folded, &empty), Field::Bool(false));
        assert_eq!(eval(&nan_eq, &empty), Field::Bool(false));
    }

    #[test]
    fn fold_stops_at_columns() {
        let e = Expr::Binary(
            BinOp::Gt,
            Box::new(col(0, "x")),
            Box::new(Expr::Binary(
                BinOp::Add,
                Box::new(lit(Field::F64(1.0))),
                Box::new(lit(Field::F64(2.0))),
            )),
        );
        let (folded, n) = fold(&e);
        assert_eq!(n, 1);
        match folded {
            Expr::Binary(BinOp::Gt, l, r) => {
                assert!(matches!(*l, Expr::Col(0, _)));
                assert!(matches!(*r, Expr::Lit(Field::F64(v)) if v == 3.0));
            }
            other => panic!("unexpected shape {other:?}"),
        }
    }

    #[test]
    fn map_cols_remaps_index_and_name() {
        let e = Expr::Binary(BinOp::Gt, Box::new(col(1, "b")), Box::new(lit(Field::F64(0.0))));
        let m = map_cols(&e, &|i, _| (i + 10, format!("c{}", i + 10)));
        assert_eq!(cols_used(&m).into_iter().collect::<Vec<_>>(), vec![11]);
        assert_eq!(m.to_string(), "(c11 > 0)");
    }

    #[test]
    fn display_shapes() {
        let e = Expr::Unary(
            UnOp::Not,
            Box::new(Expr::Binary(
                BinOp::Eq,
                Box::new(col(0, "id")),
                Box::new(lit(Field::F64(1.0))),
            )),
        );
        assert_eq!(e.to_string(), "not (id = 1)");
        let c = Expr::Call(Func::Contains, vec![col(1, "name"), lit(Field::Str("x".into()))]);
        assert_eq!(c.to_string(), "contains(name, 'x')");
    }
}
