//! "Sparklet" — the from-scratch distributed dataflow engine the DDP
//! coordinator runs on (the repo's Apache Spark substitute).
//!
//! * [`row`] — rows, fields, schemas.
//! * [`dataset`] — lazy, lineage-tracked datasets (RDD analogue).
//! * [`expr`] — SQL expression AST + evaluator (structured predicates).
//! * [`optimizer`] — rule-based logical plan rewriter (pushdown, pruning,
//!   folding; ablation switch `EngineConfig::optimize`).
//! * [`executor`] — fused narrow stages, shuffling wide stages, task
//!   retry, trace recording.
//! * [`cache`] — explicit persist/unpersist with a byte budget.
//! * [`memory`] — process-wide memory governor (shared byte budget for
//!   shuffle state, streaming buffers and the cache).
//! * [`spill`] — out-of-core disk spill: hash buckets and blocking-op
//!   buffers move to disk when a governor reservation fails.
//! * [`fault`] — failure injection for recovery tests.
//! * [`cluster`] — virtual-time cluster simulator for scale-out studies.
//! * [`net`] — driver ↔ worker wire protocol (frames over TCP; row
//!   payloads are colbin v2 blobs shared with the spill path — see
//!   `docs/colbin-format.md`).
//! * [`distributed`] — real multi-process execution: worker serve loop,
//!   driver-side worker pool with failover, shipping eligibility.
//! * [`stats`] — execution counters.
//! * [`stream`] — micro-batch streaming runtime over the same Plan DAG
//!   (stateful operators, watermarks, backpressure).
//! * [`trace`] — structured span tracing (run → pipe → stage → task /
//!   micro-batch) with per-span counter attribution, Chrome-trace
//!   export and a text profile report.

pub mod row;
pub mod dataset;
pub mod expr;
pub mod analyze;
pub mod optimizer;
pub mod executor;
pub mod cache;
pub mod memory;
pub mod spill;
pub mod fault;
pub mod cluster;
pub mod net;
pub mod distributed;
pub mod stats;
pub mod stream;
pub mod trace;

pub use analyze::{Analysis, ColInfo, ColType, Diagnostic, Severity};
pub use dataset::{Dataset, JoinKind, Partitioned};
pub use distributed::{WorkerOptions, WorkerPool};
pub use executor::{EngineConfig, EngineCtx, TaskRecord, TaskTrace};
pub use memory::MemoryGovernor;
pub use optimizer::RewriteCounts;
pub use row::{Column, ColumnBatch, ColumnData, Field, FieldType, Row, Schema, SchemaRef};
pub use stats::{Stat, StatsSnapshot};
pub use trace::{SpanKind, SpanRecord, Tracer};
