//! "Sparklet" — the from-scratch distributed dataflow engine the DDP
//! coordinator runs on (the repo's Apache Spark substitute).
//!
//! * [`row`] — rows, fields, schemas.
//! * [`dataset`] — lazy, lineage-tracked datasets (RDD analogue).
//! * [`executor`] — fused narrow stages, shuffling wide stages, task
//!   retry, trace recording.
//! * [`cache`] — explicit persist/unpersist with a byte budget.
//! * [`fault`] — failure injection for recovery tests.
//! * [`cluster`] — virtual-time cluster simulator for scale-out studies.
//! * [`stats`] — execution counters.

pub mod row;
pub mod dataset;
pub mod executor;
pub mod cache;
pub mod fault;
pub mod cluster;
pub mod stats;

pub use dataset::{Dataset, JoinKind, Partitioned};
pub use executor::{EngineConfig, EngineCtx, TaskRecord, TaskTrace};
pub use row::{Field, FieldType, Row, Schema, SchemaRef};
