//! Failure injection for task-level fault-tolerance tests. The executor
//! consults the injector before running each task attempt; injected
//! failures exercise the retry / lineage-recompute path the way Spark's
//! speculative re-execution would.

use crate::util::rng::Rng64;
use std::sync::Mutex;

/// Injects probabilistic task failures, bounded per task attempt.
pub struct FaultInjector {
    rng: Mutex<Rng64>,
    /// probability a given task attempt fails
    pub fail_prob: f64,
    /// never fail an attempt at or beyond this index (so tests terminate)
    pub max_failed_attempts: u32,
    injected: Mutex<u64>,
}

impl FaultInjector {
    pub fn new(seed: u64, fail_prob: f64, max_failed_attempts: u32) -> Self {
        FaultInjector {
            rng: Mutex::new(Rng64::new(seed)),
            fail_prob,
            max_failed_attempts,
            injected: Mutex::new(0),
        }
    }

    /// Should this attempt fail?
    pub fn should_fail(&self, attempt: u32) -> bool {
        if attempt >= self.max_failed_attempts {
            return false;
        }
        let fail = self.rng.lock().unwrap().gen_bool(self.fail_prob);
        if fail {
            *self.injected.lock().unwrap() += 1;
        }
        fail
    }

    pub fn injected_count(&self) -> u64 {
        *self.injected.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_fails_at_cap() {
        let f = FaultInjector::new(1, 1.0, 2);
        assert!(f.should_fail(0));
        assert!(f.should_fail(1));
        assert!(!f.should_fail(2));
        assert_eq!(f.injected_count(), 2);
    }

    #[test]
    fn zero_prob_never_fails() {
        let f = FaultInjector::new(1, 0.0, 10);
        for a in 0..10 {
            assert!(!f.should_fail(a));
        }
    }
}
