//! Process-wide memory governor for out-of-core execution.
//!
//! One [`MemoryGovernor`] is shared by everything in an engine context
//! that holds bulky intermediate state: shuffle buckets on the map side
//! of wide operators, the streaming runtime's blocking-op buffers, and
//! the [`super::cache::CacheManager`] (one budget — cached datasets and
//! in-flight shuffle state compete for the same bytes, exactly like
//! Spark's unified memory manager).
//!
//! The protocol is reserve-or-spill: a holder asks for a reservation
//! sized by `Row::approx_size` accounting; on success the bytes stay
//! resident and the RAII [`MemoryReservation`] releases them when the
//! rows are dropped; on failure the holder writes its rows to disk via
//! [`super::spill`] instead of keeping them. Nothing blocks and nothing
//! is evicted behind the holder's back, so the governor can never
//! deadlock — the worst case is "everything spills", which is the
//! correct degradation for a corpus larger than RAM.
//!
//! An unbounded governor (no budget) always grants reservations, which
//! keeps the default in-memory fast path byte-for-byte identical to the
//! pre-governor engine.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Observer of governor admission decisions. The tracer implements this
/// to attribute reservations/refusals to the span running on the
/// deciding thread; when no observer is installed (tracing off) the
/// hook is one `OnceLock::get` — an atomic load — per decision.
pub trait GovernorObserver: Send + Sync {
    /// A reservation of `bytes` was granted.
    fn reservation_granted(&self, bytes: u64);
    /// A reservation of `bytes` was refused (the holder will spill).
    fn reservation_refused(&self, bytes: u64);
}

/// Byte-budget arbiter. Cheap (two atomics), shared via `Arc`.
pub struct MemoryGovernor {
    /// `None` = unbounded (every reservation succeeds).
    budget: Option<u64>,
    reserved: AtomicU64,
    /// lifetime count of refused reservations (spill decisions)
    refused: AtomicU64,
    /// admission-decision observer (set once, by the tracing layer)
    observer: OnceLock<Arc<dyn GovernorObserver>>,
}

impl std::fmt::Debug for MemoryGovernor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MemoryGovernor")
            .field("budget", &self.budget)
            .field("reserved", &self.reserved)
            .field("refused", &self.refused)
            .finish()
    }
}

impl MemoryGovernor {
    pub fn new(budget_bytes: Option<usize>) -> MemoryGovernor {
        MemoryGovernor {
            budget: budget_bytes.map(|b| b as u64),
            reserved: AtomicU64::new(0),
            refused: AtomicU64::new(0),
            observer: OnceLock::new(),
        }
    }

    /// Install the admission observer. First caller wins; later calls
    /// are ignored (the tracer installs itself once at context build).
    pub fn set_observer(&self, obs: Arc<dyn GovernorObserver>) {
        let _ = self.observer.set(obs);
    }

    pub fn unbounded() -> MemoryGovernor {
        MemoryGovernor::new(None)
    }

    /// Configured budget, if any.
    pub fn budget_bytes(&self) -> Option<usize> {
        self.budget.map(|b| b as usize)
    }

    /// Bytes currently reserved across all holders.
    pub fn reserved_bytes(&self) -> usize {
        self.reserved.load(Ordering::Relaxed) as usize
    }

    /// Lifetime count of refused reservations.
    pub fn refusals(&self) -> u64 {
        self.refused.load(Ordering::Relaxed)
    }

    /// Try to reserve `bytes` against `gov`; on success the returned
    /// RAII guard keeps the shared handle and holds the reservation
    /// until dropped (or grown / shrunk explicitly).
    pub fn try_reserve(gov: &Arc<MemoryGovernor>, bytes: usize) -> Option<MemoryReservation> {
        if gov.admit(bytes as u64) {
            Some(MemoryReservation { gov: gov.clone(), bytes: bytes as u64 })
        } else {
            None
        }
    }

    /// An empty reservation that always succeeds — a growable account
    /// for incrementally filled buffers.
    pub fn open(gov: &Arc<MemoryGovernor>) -> MemoryReservation {
        MemoryReservation { gov: gov.clone(), bytes: 0 }
    }

    fn admit(&self, bytes: u64) -> bool {
        let admitted = self.admit_inner(bytes);
        if let Some(obs) = self.observer.get() {
            if admitted {
                obs.reservation_granted(bytes);
            } else {
                obs.reservation_refused(bytes);
            }
        }
        admitted
    }

    fn admit_inner(&self, bytes: u64) -> bool {
        match self.budget {
            None => {
                self.reserved.fetch_add(bytes, Ordering::Relaxed);
                true
            }
            Some(budget) => {
                let mut cur = self.reserved.load(Ordering::Relaxed);
                loop {
                    if cur.saturating_add(bytes) > budget {
                        self.refused.fetch_add(1, Ordering::Relaxed);
                        return false;
                    }
                    match self.reserved.compare_exchange_weak(
                        cur,
                        cur + bytes,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => return true,
                        Err(seen) => cur = seen,
                    }
                }
            }
        }
    }

    fn release(&self, bytes: u64) {
        // saturating: a release can never underflow the account
        let mut cur = self.reserved.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes);
            match self.reserved.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// RAII reservation: releases its bytes back to the governor on drop.
#[derive(Debug)]
pub struct MemoryReservation {
    gov: Arc<MemoryGovernor>,
    bytes: u64,
}

impl MemoryReservation {
    /// Bytes currently held by this reservation.
    pub fn bytes(&self) -> usize {
        self.bytes as usize
    }

    /// Try to grow the reservation by `more` bytes (incremental buffers).
    pub fn try_grow(&mut self, more: usize) -> bool {
        if self.gov.admit(more as u64) {
            self.bytes += more as u64;
            true
        } else {
            false
        }
    }

    /// Release everything now (e.g. after spilling the buffer the
    /// reservation covered) while keeping the account open for regrowth.
    pub fn release_all(&mut self) {
        self.gov.release(self.bytes);
        self.bytes = 0;
    }
}

impl Drop for MemoryReservation {
    fn drop(&mut self) {
        self.gov.release(self.bytes);
    }
}

/// Parse a human byte size: plain bytes, or a `k`/`m`/`g` suffix
/// (case-insensitive, powers of 1024, optional trailing `b` as in
/// `512mb`). `Ok(None)` — no budget — for `0`, empty, and `unbounded`.
/// Malformed or overflowing values are an **error**, never silently
/// unbounded: a typo in `DDP_MEMORY_BUDGET` must not disable the OOM
/// protection the knob exists for.
pub fn parse_bytes(s: &str) -> std::result::Result<Option<usize>, String> {
    let t = s.trim();
    if t.is_empty() || t == "0" || t.eq_ignore_ascii_case("unbounded") {
        return Ok(None);
    }
    // optional trailing 'b' ("64mb" == "64m"; bare "b" is not a size)
    let t = match t.strip_suffix(['b', 'B']) {
        Some(rest) if !rest.is_empty() && !rest.ends_with(['b', 'B']) => rest,
        _ => t,
    };
    let (num, mult) = match t.chars().last() {
        Some(c) if c.eq_ignore_ascii_case(&'k') => (&t[..t.len() - 1], 1usize << 10),
        Some(c) if c.eq_ignore_ascii_case(&'m') => (&t[..t.len() - 1], 1usize << 20),
        Some(c) if c.eq_ignore_ascii_case(&'g') => (&t[..t.len() - 1], 1usize << 30),
        _ => (t, 1usize),
    };
    num.trim()
        .parse::<usize>()
        .ok()
        .and_then(|n| n.checked_mul(mult))
        // zero is "unbounded" in every spelling ("0", "0k", "0mb", ...),
        // never a spill-everything budget
        .map(|n| if n == 0 { None } else { Some(n) })
        .ok_or_else(|| format!("invalid byte size '{s}' (expected e.g. 1048576, 64m, 2g, 512mb)"))
}

/// `DDP_MEMORY_BUDGET` env reader for [`EngineConfig` defaults]; panics
/// with a clear message on malformed values (loud beats silently
/// unbounded).
///
/// [`EngineConfig` defaults]: super::executor::EngineConfig
pub(crate) fn budget_from_env(var: &str) -> Option<usize> {
    match std::env::var(var) {
        Err(_) => None,
        Ok(v) => match parse_bytes(&v) {
            Ok(b) => b,
            Err(e) => panic!("{var}: {e}"),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_always_admits() {
        let g = Arc::new(MemoryGovernor::unbounded());
        let r = MemoryGovernor::try_reserve(&g, usize::MAX / 2).unwrap();
        assert_eq!(g.reserved_bytes(), usize::MAX / 2);
        drop(r);
        assert_eq!(g.reserved_bytes(), 0);
        assert_eq!(g.refusals(), 0);
    }

    #[test]
    fn budget_enforced_and_released() {
        let g = Arc::new(MemoryGovernor::new(Some(100)));
        let a = MemoryGovernor::try_reserve(&g, 60).unwrap();
        assert!(MemoryGovernor::try_reserve(&g, 50).is_none(), "over budget must refuse");
        assert_eq!(g.refusals(), 1);
        let b = MemoryGovernor::try_reserve(&g, 40).unwrap();
        assert_eq!(g.reserved_bytes(), 100);
        drop(a);
        assert_eq!(g.reserved_bytes(), 40);
        let c = MemoryGovernor::try_reserve(&g, 60).unwrap();
        drop(b);
        drop(c);
        assert_eq!(g.reserved_bytes(), 0);
    }

    #[test]
    fn open_reservation_grows_and_releases() {
        let g = Arc::new(MemoryGovernor::new(Some(64)));
        let mut r = MemoryGovernor::open(&g);
        assert!(r.try_grow(40));
        assert!(r.try_grow(24));
        assert!(!r.try_grow(1), "budget exhausted");
        assert_eq!(r.bytes(), 64);
        r.release_all();
        assert_eq!(g.reserved_bytes(), 0);
        assert!(r.try_grow(10), "account stays usable after release_all");
        drop(r);
        assert_eq!(g.reserved_bytes(), 0);
    }

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("1234"), Ok(Some(1234)));
        assert_eq!(parse_bytes("4k"), Ok(Some(4096)));
        assert_eq!(parse_bytes("8M"), Ok(Some(8 << 20)));
        assert_eq!(parse_bytes("2g"), Ok(Some(2 << 30)));
        assert_eq!(parse_bytes("512mb"), Ok(Some(512 << 20)));
        assert_eq!(parse_bytes("64KB"), Ok(Some(64 << 10)));
        assert_eq!(parse_bytes("0"), Ok(None));
        assert_eq!(parse_bytes("0k"), Ok(None), "zero is unbounded in every spelling");
        assert_eq!(parse_bytes("0mb"), Ok(None));
        assert_eq!(parse_bytes(""), Ok(None));
        assert_eq!(parse_bytes("unbounded"), Ok(None));
        // malformed or overflowing values are errors, never silently
        // unbounded — the knob's whole point is OOM protection
        assert!(parse_bytes("nonsense").is_err());
        assert!(parse_bytes("1.5g").is_err());
        assert!(parse_bytes("b").is_err());
        assert!(parse_bytes("99999999999999999999g").is_err());
    }

    #[test]
    fn concurrent_reserve_release_balances() {
        let g = Arc::new(MemoryGovernor::new(Some(1 << 20)));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    if let Some(r) = MemoryGovernor::try_reserve(&g, 512) {
                        drop(r);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(g.reserved_bytes(), 0);
    }
}
