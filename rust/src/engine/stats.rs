//! Engine-internal execution statistics (atomics; cheap enough for the hot
//! path). The metrics module exports these to the async publisher; the
//! cluster simulator reads them to charge network/scheduling costs.

use std::sync::atomic::{AtomicU64, Ordering};

/// Counters for one engine context (one "application").
#[derive(Debug, Default)]
pub struct EngineStats {
    pub tasks_launched: AtomicU64,
    pub tasks_retried: AtomicU64,
    pub rows_read: AtomicU64,
    pub rows_written: AtomicU64,
    pub shuffle_bytes: AtomicU64,
    pub shuffle_records: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub cache_evictions: AtomicU64,
    /// nanoseconds of task compute time, summed across tasks
    pub task_nanos: AtomicU64,
    pub stages_run: AtomicU64,
    /// logical plan rewrites applied by the optimizer
    pub plan_rewrites: AtomicU64,
    /// bytes written to disk by the out-of-core spill path
    pub spill_bytes: AtomicU64,
    /// spill files created (shuffle bucket sets + streaming chunks)
    pub spill_files: AtomicU64,
    /// sorted runs produced by the external merge sort's map side (one
    /// per input partition, or per streaming micro-batch delta)
    pub sort_runs: AtomicU64,
    /// bytes written to disk by spilled sort runs (also counted in
    /// `spill_bytes`; split out so sort pressure is attributable)
    pub sort_spill_bytes: AtomicU64,
    /// column batches executed by the vectorized narrow-stage path (one
    /// per contiguous run of expression-backed steps per partition)
    pub vectorized_batches: AtomicU64,
    /// vectorizable segments that fell back to row-at-a-time execution
    /// (ragged input arity or a mixed-type column)
    pub vectorized_fallbacks: AtomicU64,
    /// shuffle map partitions transported batch-native through a
    /// column-keyed wide operator (no row materialization at the
    /// shuffle boundary)
    pub vectorized_shuffle_batches: AtomicU64,
    /// column-keyed shuffle map partitions that fell back to row
    /// transport (ragged input arity, a mixed-type column, or a key
    /// column index past the batch width)
    pub vectorized_shuffle_fallbacks: AtomicU64,
}

impl EngineStats {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            tasks_launched: self.tasks_launched.load(Ordering::Relaxed),
            tasks_retried: self.tasks_retried.load(Ordering::Relaxed),
            rows_read: self.rows_read.load(Ordering::Relaxed),
            rows_written: self.rows_written.load(Ordering::Relaxed),
            shuffle_bytes: self.shuffle_bytes.load(Ordering::Relaxed),
            shuffle_records: self.shuffle_records.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            task_nanos: self.task_nanos.load(Ordering::Relaxed),
            stages_run: self.stages_run.load(Ordering::Relaxed),
            plan_rewrites: self.plan_rewrites.load(Ordering::Relaxed),
            spill_bytes: self.spill_bytes.load(Ordering::Relaxed),
            spill_files: self.spill_files.load(Ordering::Relaxed),
            sort_runs: self.sort_runs.load(Ordering::Relaxed),
            sort_spill_bytes: self.sort_spill_bytes.load(Ordering::Relaxed),
            vectorized_batches: self.vectorized_batches.load(Ordering::Relaxed),
            vectorized_fallbacks: self.vectorized_fallbacks.load(Ordering::Relaxed),
            vectorized_shuffle_batches: self.vectorized_shuffle_batches.load(Ordering::Relaxed),
            vectorized_shuffle_fallbacks: self
                .vectorized_shuffle_fallbacks
                .load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`EngineStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub tasks_launched: u64,
    pub tasks_retried: u64,
    pub rows_read: u64,
    pub rows_written: u64,
    pub shuffle_bytes: u64,
    pub shuffle_records: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub task_nanos: u64,
    pub stages_run: u64,
    pub plan_rewrites: u64,
    pub spill_bytes: u64,
    pub spill_files: u64,
    pub sort_runs: u64,
    pub sort_spill_bytes: u64,
    pub vectorized_batches: u64,
    pub vectorized_fallbacks: u64,
    pub vectorized_shuffle_batches: u64,
    pub vectorized_shuffle_fallbacks: u64,
}

impl StatsSnapshot {
    /// Difference since an earlier snapshot.
    pub fn delta(&self, earlier: &StatsSnapshot) -> StatsSnapshot {
        StatsSnapshot {
            tasks_launched: self.tasks_launched - earlier.tasks_launched,
            tasks_retried: self.tasks_retried - earlier.tasks_retried,
            rows_read: self.rows_read - earlier.rows_read,
            rows_written: self.rows_written - earlier.rows_written,
            shuffle_bytes: self.shuffle_bytes - earlier.shuffle_bytes,
            shuffle_records: self.shuffle_records - earlier.shuffle_records,
            cache_hits: self.cache_hits - earlier.cache_hits,
            cache_misses: self.cache_misses - earlier.cache_misses,
            cache_evictions: self.cache_evictions - earlier.cache_evictions,
            task_nanos: self.task_nanos - earlier.task_nanos,
            stages_run: self.stages_run - earlier.stages_run,
            plan_rewrites: self.plan_rewrites - earlier.plan_rewrites,
            spill_bytes: self.spill_bytes - earlier.spill_bytes,
            spill_files: self.spill_files - earlier.spill_files,
            sort_runs: self.sort_runs - earlier.sort_runs,
            sort_spill_bytes: self.sort_spill_bytes - earlier.sort_spill_bytes,
            vectorized_batches: self.vectorized_batches - earlier.vectorized_batches,
            vectorized_fallbacks: self.vectorized_fallbacks - earlier.vectorized_fallbacks,
            vectorized_shuffle_batches: self.vectorized_shuffle_batches
                - earlier.vectorized_shuffle_batches,
            vectorized_shuffle_fallbacks: self.vectorized_shuffle_fallbacks
                - earlier.vectorized_shuffle_fallbacks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_delta() {
        let s = EngineStats::new();
        s.add(&s.tasks_launched, 3);
        s.add(&s.rows_read, 100);
        let a = s.snapshot();
        s.add(&s.rows_read, 50);
        let b = s.snapshot();
        let d = b.delta(&a);
        assert_eq!(d.rows_read, 50);
        assert_eq!(d.tasks_launched, 0);
        assert_eq!(b.rows_read, 150);
    }
}
