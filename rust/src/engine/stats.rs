//! Engine-internal execution statistics (atomics; cheap enough for the hot
//! path). The metrics module exports these to the async publisher; the
//! cluster simulator reads them to charge network/scheduling costs.

use std::sync::atomic::{AtomicU64, Ordering};

/// Names one [`EngineStats`] counter. A single charge site can address
/// both the global atomics and a per-span counter set in the tracer
/// ([`super::trace`]) through the same key, which is what keeps the
/// "global = sum of spans" invariant checkable: every charge goes
/// through one `Stat`, to exactly one span.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stat {
    TasksLaunched,
    TasksRetried,
    RowsRead,
    RowsWritten,
    ShuffleBytes,
    ShuffleRecords,
    CacheHits,
    CacheMisses,
    CacheEvictions,
    TaskNanos,
    StagesRun,
    PlanRewrites,
    SpillBytes,
    SpillFiles,
    SortRuns,
    SortSpillBytes,
    VectorizedBatches,
    VectorizedFallbacks,
    VectorizedShuffleBatches,
    VectorizedShuffleFallbacks,
    AnalyzerErrors,
    AnalyzerWarnings,
    AnalyzerNotes,
    DistTasksRemote,
    DistFallbacks,
    DistBytesTx,
    DistBytesRx,
    DistWorkersLost,
}

impl Stat {
    /// Every counter, in [`StatsSnapshot`] field order.
    pub const ALL: [Stat; 28] = [
        Stat::TasksLaunched,
        Stat::TasksRetried,
        Stat::RowsRead,
        Stat::RowsWritten,
        Stat::ShuffleBytes,
        Stat::ShuffleRecords,
        Stat::CacheHits,
        Stat::CacheMisses,
        Stat::CacheEvictions,
        Stat::TaskNanos,
        Stat::StagesRun,
        Stat::PlanRewrites,
        Stat::SpillBytes,
        Stat::SpillFiles,
        Stat::SortRuns,
        Stat::SortSpillBytes,
        Stat::VectorizedBatches,
        Stat::VectorizedFallbacks,
        Stat::VectorizedShuffleBatches,
        Stat::VectorizedShuffleFallbacks,
        Stat::AnalyzerErrors,
        Stat::AnalyzerWarnings,
        Stat::AnalyzerNotes,
        Stat::DistTasksRemote,
        Stat::DistFallbacks,
        Stat::DistBytesTx,
        Stat::DistBytesRx,
        Stat::DistWorkersLost,
    ];

    /// Snake-case counter name (matches the exporter's metric suffixes).
    pub fn name(self) -> &'static str {
        match self {
            Stat::TasksLaunched => "tasks_launched",
            Stat::TasksRetried => "tasks_retried",
            Stat::RowsRead => "rows_read",
            Stat::RowsWritten => "rows_written",
            Stat::ShuffleBytes => "shuffle_bytes",
            Stat::ShuffleRecords => "shuffle_records",
            Stat::CacheHits => "cache_hits",
            Stat::CacheMisses => "cache_misses",
            Stat::CacheEvictions => "cache_evictions",
            Stat::TaskNanos => "task_nanos",
            Stat::StagesRun => "stages_run",
            Stat::PlanRewrites => "plan_rewrites",
            Stat::SpillBytes => "spill_bytes",
            Stat::SpillFiles => "spill_files",
            Stat::SortRuns => "sort_runs",
            Stat::SortSpillBytes => "sort_spill_bytes",
            Stat::VectorizedBatches => "vectorized_batches",
            Stat::VectorizedFallbacks => "vectorized_fallbacks",
            Stat::VectorizedShuffleBatches => "vectorized_shuffle_batches",
            Stat::VectorizedShuffleFallbacks => "vectorized_shuffle_fallbacks",
            Stat::AnalyzerErrors => "analyzer_errors",
            Stat::AnalyzerWarnings => "analyzer_warnings",
            Stat::AnalyzerNotes => "analyzer_notes",
            Stat::DistTasksRemote => "dist_tasks_remote",
            Stat::DistFallbacks => "dist_fallbacks",
            Stat::DistBytesTx => "dist_bytes_tx",
            Stat::DistBytesRx => "dist_bytes_rx",
            Stat::DistWorkersLost => "dist_workers_lost",
        }
    }
}

/// Counters for one engine context (one "application").
#[derive(Debug, Default)]
pub struct EngineStats {
    pub tasks_launched: AtomicU64,
    pub tasks_retried: AtomicU64,
    pub rows_read: AtomicU64,
    pub rows_written: AtomicU64,
    pub shuffle_bytes: AtomicU64,
    pub shuffle_records: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub cache_evictions: AtomicU64,
    /// nanoseconds of task compute time, summed across tasks
    pub task_nanos: AtomicU64,
    pub stages_run: AtomicU64,
    /// logical plan rewrites applied by the optimizer
    pub plan_rewrites: AtomicU64,
    /// bytes written to disk by the out-of-core spill path
    pub spill_bytes: AtomicU64,
    /// spill files created (shuffle bucket sets + streaming chunks)
    pub spill_files: AtomicU64,
    /// sorted runs produced by the external merge sort's map side (one
    /// per input partition, or per streaming micro-batch delta)
    pub sort_runs: AtomicU64,
    /// bytes written to disk by spilled sort runs (also counted in
    /// `spill_bytes`; split out so sort pressure is attributable)
    pub sort_spill_bytes: AtomicU64,
    /// column batches executed by the vectorized narrow-stage path (one
    /// per contiguous run of expression-backed steps per partition)
    pub vectorized_batches: AtomicU64,
    /// vectorizable segments that fell back to row-at-a-time execution
    /// (ragged input arity or a mixed-type column)
    pub vectorized_fallbacks: AtomicU64,
    /// shuffle map partitions transported batch-native through a
    /// column-keyed wide operator (no row materialization at the
    /// shuffle boundary)
    pub vectorized_shuffle_batches: AtomicU64,
    /// column-keyed shuffle map partitions that fell back to row
    /// transport (ragged input arity, a mixed-type column, or a key
    /// column index past the batch width)
    pub vectorized_shuffle_fallbacks: AtomicU64,
    /// error-severity diagnostics from the static plan analyzer
    /// ([`super::analyze`]; each one aborted a pipe before any task ran)
    pub analyzer_errors: AtomicU64,
    /// warning-severity analyzer diagnostics (executed anyway)
    pub analyzer_warnings: AtomicU64,
    /// note-severity analyzer diagnostics (advisory only)
    pub analyzer_notes: AtomicU64,
    /// tasks whose work executed on a remote worker process
    /// ([`super::distributed`])
    pub dist_tasks_remote: AtomicU64,
    /// stages that could not ship to workers (opaque closures) and ran
    /// local while a worker pool was attached
    pub dist_fallbacks: AtomicU64,
    /// bytes shipped to workers (request frames + payloads)
    pub dist_bytes_tx: AtomicU64,
    /// bytes received from workers (response frames + payloads)
    pub dist_bytes_rx: AtomicU64,
    /// workers declared dead after a connection failure (their tasks
    /// failed over via lineage retry)
    pub dist_workers_lost: AtomicU64,
}

impl EngineStats {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Charge a counter addressed by [`Stat`] key (the form the tracer's
    /// span-attribution path shares with the global atomics).
    #[inline]
    pub fn add_stat(&self, s: Stat, v: u64) {
        self.cell(s).fetch_add(v, Ordering::Relaxed);
    }

    fn cell(&self, s: Stat) -> &AtomicU64 {
        match s {
            Stat::TasksLaunched => &self.tasks_launched,
            Stat::TasksRetried => &self.tasks_retried,
            Stat::RowsRead => &self.rows_read,
            Stat::RowsWritten => &self.rows_written,
            Stat::ShuffleBytes => &self.shuffle_bytes,
            Stat::ShuffleRecords => &self.shuffle_records,
            Stat::CacheHits => &self.cache_hits,
            Stat::CacheMisses => &self.cache_misses,
            Stat::CacheEvictions => &self.cache_evictions,
            Stat::TaskNanos => &self.task_nanos,
            Stat::StagesRun => &self.stages_run,
            Stat::PlanRewrites => &self.plan_rewrites,
            Stat::SpillBytes => &self.spill_bytes,
            Stat::SpillFiles => &self.spill_files,
            Stat::SortRuns => &self.sort_runs,
            Stat::SortSpillBytes => &self.sort_spill_bytes,
            Stat::VectorizedBatches => &self.vectorized_batches,
            Stat::VectorizedFallbacks => &self.vectorized_fallbacks,
            Stat::VectorizedShuffleBatches => &self.vectorized_shuffle_batches,
            Stat::VectorizedShuffleFallbacks => &self.vectorized_shuffle_fallbacks,
            Stat::AnalyzerErrors => &self.analyzer_errors,
            Stat::AnalyzerWarnings => &self.analyzer_warnings,
            Stat::AnalyzerNotes => &self.analyzer_notes,
            Stat::DistTasksRemote => &self.dist_tasks_remote,
            Stat::DistFallbacks => &self.dist_fallbacks,
            Stat::DistBytesTx => &self.dist_bytes_tx,
            Stat::DistBytesRx => &self.dist_bytes_rx,
            Stat::DistWorkersLost => &self.dist_workers_lost,
        }
    }

    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            tasks_launched: self.tasks_launched.load(Ordering::Relaxed),
            tasks_retried: self.tasks_retried.load(Ordering::Relaxed),
            rows_read: self.rows_read.load(Ordering::Relaxed),
            rows_written: self.rows_written.load(Ordering::Relaxed),
            shuffle_bytes: self.shuffle_bytes.load(Ordering::Relaxed),
            shuffle_records: self.shuffle_records.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            task_nanos: self.task_nanos.load(Ordering::Relaxed),
            stages_run: self.stages_run.load(Ordering::Relaxed),
            plan_rewrites: self.plan_rewrites.load(Ordering::Relaxed),
            spill_bytes: self.spill_bytes.load(Ordering::Relaxed),
            spill_files: self.spill_files.load(Ordering::Relaxed),
            sort_runs: self.sort_runs.load(Ordering::Relaxed),
            sort_spill_bytes: self.sort_spill_bytes.load(Ordering::Relaxed),
            vectorized_batches: self.vectorized_batches.load(Ordering::Relaxed),
            vectorized_fallbacks: self.vectorized_fallbacks.load(Ordering::Relaxed),
            vectorized_shuffle_batches: self.vectorized_shuffle_batches.load(Ordering::Relaxed),
            vectorized_shuffle_fallbacks: self
                .vectorized_shuffle_fallbacks
                .load(Ordering::Relaxed),
            analyzer_errors: self.analyzer_errors.load(Ordering::Relaxed),
            analyzer_warnings: self.analyzer_warnings.load(Ordering::Relaxed),
            analyzer_notes: self.analyzer_notes.load(Ordering::Relaxed),
            dist_tasks_remote: self.dist_tasks_remote.load(Ordering::Relaxed),
            dist_fallbacks: self.dist_fallbacks.load(Ordering::Relaxed),
            dist_bytes_tx: self.dist_bytes_tx.load(Ordering::Relaxed),
            dist_bytes_rx: self.dist_bytes_rx.load(Ordering::Relaxed),
            dist_workers_lost: self.dist_workers_lost.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`EngineStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub tasks_launched: u64,
    pub tasks_retried: u64,
    pub rows_read: u64,
    pub rows_written: u64,
    pub shuffle_bytes: u64,
    pub shuffle_records: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub task_nanos: u64,
    pub stages_run: u64,
    pub plan_rewrites: u64,
    pub spill_bytes: u64,
    pub spill_files: u64,
    pub sort_runs: u64,
    pub sort_spill_bytes: u64,
    pub vectorized_batches: u64,
    pub vectorized_fallbacks: u64,
    pub vectorized_shuffle_batches: u64,
    pub vectorized_shuffle_fallbacks: u64,
    pub analyzer_errors: u64,
    pub analyzer_warnings: u64,
    pub analyzer_notes: u64,
    pub dist_tasks_remote: u64,
    pub dist_fallbacks: u64,
    pub dist_bytes_tx: u64,
    pub dist_bytes_rx: u64,
    pub dist_workers_lost: u64,
}

impl StatsSnapshot {
    /// Difference since an earlier snapshot. Saturating on every field:
    /// `earlier` may come from a context that was since replaced by a
    /// fresh one (counters restart at zero), and a publisher thread
    /// computing a delta across that boundary must clamp to zero, not
    /// panic on u64 underflow.
    pub fn delta(&self, earlier: &StatsSnapshot) -> StatsSnapshot {
        let mut out = StatsSnapshot::default();
        for s in Stat::ALL {
            *out.cell_mut(s) = self.get(s).saturating_sub(earlier.get(s));
        }
        out
    }

    /// Read one counter by [`Stat`] key.
    pub fn get(&self, s: Stat) -> u64 {
        match s {
            Stat::TasksLaunched => self.tasks_launched,
            Stat::TasksRetried => self.tasks_retried,
            Stat::RowsRead => self.rows_read,
            Stat::RowsWritten => self.rows_written,
            Stat::ShuffleBytes => self.shuffle_bytes,
            Stat::ShuffleRecords => self.shuffle_records,
            Stat::CacheHits => self.cache_hits,
            Stat::CacheMisses => self.cache_misses,
            Stat::CacheEvictions => self.cache_evictions,
            Stat::TaskNanos => self.task_nanos,
            Stat::StagesRun => self.stages_run,
            Stat::PlanRewrites => self.plan_rewrites,
            Stat::SpillBytes => self.spill_bytes,
            Stat::SpillFiles => self.spill_files,
            Stat::SortRuns => self.sort_runs,
            Stat::SortSpillBytes => self.sort_spill_bytes,
            Stat::VectorizedBatches => self.vectorized_batches,
            Stat::VectorizedFallbacks => self.vectorized_fallbacks,
            Stat::VectorizedShuffleBatches => self.vectorized_shuffle_batches,
            Stat::VectorizedShuffleFallbacks => self.vectorized_shuffle_fallbacks,
            Stat::AnalyzerErrors => self.analyzer_errors,
            Stat::AnalyzerWarnings => self.analyzer_warnings,
            Stat::AnalyzerNotes => self.analyzer_notes,
            Stat::DistTasksRemote => self.dist_tasks_remote,
            Stat::DistFallbacks => self.dist_fallbacks,
            Stat::DistBytesTx => self.dist_bytes_tx,
            Stat::DistBytesRx => self.dist_bytes_rx,
            Stat::DistWorkersLost => self.dist_workers_lost,
        }
    }

    fn cell_mut(&mut self, s: Stat) -> &mut u64 {
        match s {
            Stat::TasksLaunched => &mut self.tasks_launched,
            Stat::TasksRetried => &mut self.tasks_retried,
            Stat::RowsRead => &mut self.rows_read,
            Stat::RowsWritten => &mut self.rows_written,
            Stat::ShuffleBytes => &mut self.shuffle_bytes,
            Stat::ShuffleRecords => &mut self.shuffle_records,
            Stat::CacheHits => &mut self.cache_hits,
            Stat::CacheMisses => &mut self.cache_misses,
            Stat::CacheEvictions => &mut self.cache_evictions,
            Stat::TaskNanos => &mut self.task_nanos,
            Stat::StagesRun => &mut self.stages_run,
            Stat::PlanRewrites => &mut self.plan_rewrites,
            Stat::SpillBytes => &mut self.spill_bytes,
            Stat::SpillFiles => &mut self.spill_files,
            Stat::SortRuns => &mut self.sort_runs,
            Stat::SortSpillBytes => &mut self.sort_spill_bytes,
            Stat::VectorizedBatches => &mut self.vectorized_batches,
            Stat::VectorizedFallbacks => &mut self.vectorized_fallbacks,
            Stat::VectorizedShuffleBatches => &mut self.vectorized_shuffle_batches,
            Stat::VectorizedShuffleFallbacks => &mut self.vectorized_shuffle_fallbacks,
            Stat::AnalyzerErrors => &mut self.analyzer_errors,
            Stat::AnalyzerWarnings => &mut self.analyzer_warnings,
            Stat::AnalyzerNotes => &mut self.analyzer_notes,
            Stat::DistTasksRemote => &mut self.dist_tasks_remote,
            Stat::DistFallbacks => &mut self.dist_fallbacks,
            Stat::DistBytesTx => &mut self.dist_bytes_tx,
            Stat::DistBytesRx => &mut self.dist_bytes_rx,
            Stat::DistWorkersLost => &mut self.dist_workers_lost,
        }
    }

    /// Add `v` to one counter (span-local accumulation in the tracer).
    pub fn bump(&mut self, s: Stat, v: u64) {
        *self.cell_mut(s) += v;
    }

    /// Field-wise `self += other` (summing span-local counters back up
    /// to a total the trace tests compare against the global snapshot).
    pub fn accumulate(&mut self, other: &StatsSnapshot) {
        for s in Stat::ALL {
            *self.cell_mut(s) += other.get(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_delta() {
        let s = EngineStats::new();
        s.add(&s.tasks_launched, 3);
        s.add(&s.rows_read, 100);
        let a = s.snapshot();
        s.add(&s.rows_read, 50);
        let b = s.snapshot();
        let d = b.delta(&a);
        assert_eq!(d.rows_read, 50);
        assert_eq!(d.tasks_launched, 0);
        assert_eq!(b.rows_read, 150);
    }

    #[test]
    fn delta_saturates_across_a_counter_reset() {
        // "earlier" came from a context that was torn down and replaced;
        // the fresh context's counters restart below it on every field
        let old = EngineStats::new();
        old.add(&old.rows_read, 1000);
        old.add(&old.spill_bytes, 1 << 20);
        old.add(&old.tasks_launched, 64);
        let earlier = old.snapshot();

        let fresh = EngineStats::new();
        fresh.add(&fresh.rows_read, 10);
        let d = fresh.snapshot().delta(&earlier);
        for s in Stat::ALL {
            assert_eq!(d.get(s), 0, "field {} must clamp, not underflow", s.name());
        }
    }

    #[test]
    fn add_stat_reaches_every_field_and_accumulate_sums() {
        let s = EngineStats::new();
        for (i, st) in Stat::ALL.into_iter().enumerate() {
            s.add_stat(st, (i + 1) as u64);
        }
        let snap = s.snapshot();
        for (i, st) in Stat::ALL.into_iter().enumerate() {
            assert_eq!(snap.get(st), (i + 1) as u64, "field {}", st.name());
        }
        let mut total = StatsSnapshot::default();
        total.accumulate(&snap);
        total.accumulate(&snap);
        for st in Stat::ALL {
            assert_eq!(total.get(st), 2 * snap.get(st));
        }
        let mut bumped = StatsSnapshot::default();
        bumped.bump(Stat::SortRuns, 7);
        assert_eq!(bumped.sort_runs, 7);
    }
}
