//! Virtual-time cluster simulator.
//!
//! The paper's scale-out numbers (Fig 5's 4→48-vCPU sweep, Table 3's
//! 500 M-record scalability, §4.4's 100-node EMR fleet) were measured on
//! clusters this container cannot host (1 physical core). The simulator
//! replays *measured* single-core task costs (a [`TaskTrace`] recorded by
//! the real executor, or an analytic [`StageSpec`] for beyond-memory
//! scales) through a list-scheduling makespan model with per-framework
//! overhead knobs:
//!
//! * per-task scheduler dispatch overhead (Spark ≈ ms, Ray ≈ ms + object
//!   store, single-thread Python = 0 but `worker_speed` ≪ 1);
//! * shuffle bytes across a shared network bandwidth;
//! * serialization tax per shuffled/collected byte (the PySpark / Ray
//!   object-store penalty the paper's §1 calls out);
//! * driver / worker memory limits — exceeding the driver limit is the
//!   "Scalability Limit" failure mode in Table 3 (monolithic collect),
//!   exceeding aggregate worker memory fails DDP too, far later.
//!
//! Stages are barriers (as in Spark); tasks within a stage are scheduled
//! LPT onto the earliest-free worker.

use super::executor::TaskTrace;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Cluster + framework cost model.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub name: String,
    /// number of parallel worker slots (vCPUs)
    pub workers: usize,
    /// relative speed of one worker vs. the measurement machine (1.0 = same)
    pub worker_speed: f64,
    /// fixed dispatch overhead charged per task
    pub sched_overhead_secs: f64,
    /// shared network bandwidth for shuffles (bytes/sec)
    pub net_bandwidth_bps: f64,
    /// serialization tax per byte moved (shuffle or driver collect)
    pub ser_secs_per_byte: f64,
    /// driver memory — collects beyond this OOM (monolithic failure mode)
    pub driver_mem_bytes: u64,
    /// aggregate worker memory — working set beyond this OOMs
    pub worker_mem_bytes: u64,
}

impl ClusterConfig {
    /// AWS Glue G.1X-like worker fleet (the paper's Table 4 setup): 4 vCPU
    /// per worker; JVM/Scala task dispatch ~2 ms; 10 Gbps network.
    pub fn glue_like(vcpus: usize) -> ClusterConfig {
        ClusterConfig {
            name: format!("ddp-glue-{vcpus}vcpu"),
            workers: vcpus,
            worker_speed: 1.0,
            sched_overhead_secs: 0.002,
            net_bandwidth_bps: 1.25e9,
            ser_secs_per_byte: 0.0, // embedded in-process: no ser/de tax
            driver_mem_bytes: 8 << 30,
            worker_mem_bytes: (vcpus as u64 / 4).max(1) * (16 << 30),
        }
    }

    /// Ray-like execution (paper Table 4 comparator): per-task overhead is
    /// higher (scheduler RPC + object-store put/get) and every task's
    /// output pays a serialization tax into the object store.
    pub fn ray_like(vcpus: usize) -> ClusterConfig {
        ClusterConfig {
            name: format!("ray-{vcpus}vcpu"),
            workers: vcpus,
            worker_speed: 1.0,
            sched_overhead_secs: 0.010,
            net_bandwidth_bps: 1.25e9,
            ser_secs_per_byte: 4.0e-9, // ~250 MB/s pickle-ish
            driver_mem_bytes: 8 << 30,
            worker_mem_bytes: (vcpus as u64 / 4).max(1) * (16 << 30),
        }
    }

    /// Single-threaded Python process: one slot, CPython-speed handicap
    /// (calibrated against the real python baseline; see EXPERIMENTS.md).
    pub fn python_single(speed_vs_rust: f64) -> ClusterConfig {
        ClusterConfig {
            name: "python-1thread".into(),
            workers: 1,
            worker_speed: speed_vs_rust,
            sched_overhead_secs: 0.0,
            net_bandwidth_bps: f64::INFINITY,
            ser_secs_per_byte: 0.0,
            driver_mem_bytes: 16 << 30,
            worker_mem_bytes: 16 << 30,
        }
    }
}

/// One barrier stage of work for the simulator.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    /// per-task compute seconds as measured on the reference machine
    pub task_secs: Vec<f64>,
    /// bytes exchanged over the network after this stage
    pub shuffle_bytes: u64,
    /// bytes gathered onto the driver after this stage (monolithic collect)
    pub collect_bytes: u64,
    /// peak distributed working set during this stage
    pub working_set_bytes: u64,
}

impl StageSpec {
    pub fn uniform(name: &str, n_tasks: usize, secs_per_task: f64) -> StageSpec {
        StageSpec {
            name: name.into(),
            task_secs: vec![secs_per_task; n_tasks],
            shuffle_bytes: 0,
            collect_bytes: 0,
            working_set_bytes: 0,
        }
    }

    pub fn with_shuffle(mut self, bytes: u64) -> StageSpec {
        self.shuffle_bytes = bytes;
        self
    }

    pub fn with_collect(mut self, bytes: u64) -> StageSpec {
        self.collect_bytes = bytes;
        self
    }

    pub fn with_working_set(mut self, bytes: u64) -> StageSpec {
        self.working_set_bytes = bytes;
        self
    }
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan_secs: f64,
    /// busy-time / (makespan × workers)
    pub cpu_utilization: f64,
    pub stage_secs: Vec<(String, f64)>,
    /// OOM description if the job died
    pub failure: Option<String>,
    pub total_compute_secs: f64,
}

impl SimResult {
    pub fn ok(&self) -> bool {
        self.failure.is_none()
    }
}

/// Simulate the stages on the cluster; returns makespan + utilization, or
/// a failure if a memory limit is exceeded.
pub fn simulate(stages: &[StageSpec], cfg: &ClusterConfig) -> SimResult {
    let mut total = 0.0f64;
    let mut busy = 0.0f64;
    let mut per_stage = Vec::with_capacity(stages.len());
    for stage in stages {
        // memory gates first: a dead job has no runtime
        if stage.collect_bytes > cfg.driver_mem_bytes {
            return SimResult {
                makespan_secs: total,
                cpu_utilization: 0.0,
                stage_secs: per_stage,
                failure: Some(format!(
                    "driver OOM in stage '{}': collect of {} exceeds driver memory {}",
                    stage.name,
                    crate::util::fmt_bytes(stage.collect_bytes),
                    crate::util::fmt_bytes(cfg.driver_mem_bytes)
                )),
                total_compute_secs: busy,
            };
        }
        if stage.working_set_bytes > cfg.worker_mem_bytes {
            return SimResult {
                makespan_secs: total,
                cpu_utilization: 0.0,
                stage_secs: per_stage,
                failure: Some(format!(
                    "executor OOM in stage '{}': working set {} exceeds cluster memory {}",
                    stage.name,
                    crate::util::fmt_bytes(stage.working_set_bytes),
                    crate::util::fmt_bytes(cfg.worker_mem_bytes)
                )),
                total_compute_secs: busy,
            };
        }

        let compute = schedule_lpt(&stage.task_secs, cfg);
        busy += stage
            .task_secs
            .iter()
            .map(|t| t / cfg.worker_speed + cfg.sched_overhead_secs)
            .sum::<f64>();
        let shuffle = stage.shuffle_bytes as f64 / cfg.net_bandwidth_bps
            + stage.shuffle_bytes as f64 * cfg.ser_secs_per_byte;
        let collect = stage.collect_bytes as f64 / cfg.net_bandwidth_bps
            + stage.collect_bytes as f64 * cfg.ser_secs_per_byte;
        let stage_time = compute + shuffle + collect;
        per_stage.push((stage.name.clone(), stage_time));
        total += stage_time;
    }
    SimResult {
        makespan_secs: total,
        cpu_utilization: if total > 0.0 {
            (busy / (total * cfg.workers as f64)).min(1.0)
        } else {
            1.0
        },
        stage_secs: per_stage,
        failure: None,
        total_compute_secs: busy,
    }
}

/// Longest-processing-time list scheduling onto `workers` slots; returns
/// the stage makespan.
fn schedule_lpt(task_secs: &[f64], cfg: &ClusterConfig) -> f64 {
    if task_secs.is_empty() {
        return 0.0;
    }
    let mut tasks: Vec<f64> = task_secs
        .iter()
        .map(|t| t / cfg.worker_speed + cfg.sched_overhead_secs)
        .collect();
    tasks.sort_by(|a, b| b.partial_cmp(a).unwrap());
    // min-heap of worker-free times (f64 via ordered bits — all non-negative)
    let mut heap: BinaryHeap<Reverse<u64>> = (0..cfg.workers.max(1))
        .map(|_| Reverse(0u64))
        .collect();
    let mut makespan = 0.0f64;
    for t in tasks {
        let Reverse(free_bits) = heap.pop().unwrap();
        let free = f64::from_bits(free_bits);
        let end = free + t;
        makespan = makespan.max(end);
        heap.push(Reverse(end.to_bits()));
    }
    makespan
}

/// Group a recorded [`TaskTrace`] into `StageSpec`s (stage order = first
/// appearance order). Shuffle bytes come from the per-task records when
/// the trace carries them (the executor charges real measured bytes, so
/// partition skew is visible per stage); traces without byte accounting
/// fall back to spreading `shuffle_bytes_total` evenly.
pub fn trace_to_stages(trace: &TaskTrace, shuffle_bytes_total: u64) -> Vec<StageSpec> {
    let mut order: Vec<u64> = Vec::new();
    let mut by_stage: std::collections::HashMap<u64, Vec<f64>> = std::collections::HashMap::new();
    let mut shuffle_by_stage: std::collections::HashMap<u64, u64> =
        std::collections::HashMap::new();
    let mut measured_total = 0u64;
    for rec in trace {
        if !by_stage.contains_key(&rec.stage_id) {
            order.push(rec.stage_id);
        }
        by_stage.entry(rec.stage_id).or_default().push(rec.duration_secs);
        *shuffle_by_stage.entry(rec.stage_id).or_insert(0) += rec.shuffle_bytes;
        measured_total += rec.shuffle_bytes;
    }
    let n = order.len().max(1) as u64;
    order
        .into_iter()
        .map(|sid| StageSpec {
            name: format!("stage-{sid}"),
            task_secs: by_stage.remove(&sid).unwrap_or_default(),
            shuffle_bytes: if measured_total > 0 {
                shuffle_by_stage.get(&sid).copied().unwrap_or(0)
            } else {
                shuffle_bytes_total / n
            },
            collect_bytes: 0,
            working_set_bytes: 0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_scaling_for_uniform_tasks() {
        let stages = vec![StageSpec::uniform("s", 48, 1.0)];
        let one = simulate(&stages, &ClusterConfig::glue_like(1));
        let many = simulate(&stages, &ClusterConfig::glue_like(48));
        assert!(one.makespan_secs > 47.0);
        assert!(many.makespan_secs < 1.2);
        assert!(many.cpu_utilization > 0.9);
    }

    #[test]
    fn lpt_handles_skew() {
        // one long task dominates regardless of workers
        let mut tasks = vec![0.1; 50];
        tasks.push(10.0);
        let stages = vec![StageSpec {
            name: "skew".into(),
            task_secs: tasks,
            shuffle_bytes: 0,
            collect_bytes: 0,
            working_set_bytes: 0,
        }];
        let r = simulate(&stages, &ClusterConfig::glue_like(48));
        assert!(r.makespan_secs >= 10.0 && r.makespan_secs < 11.0);
        assert!(r.cpu_utilization < 0.2, "skew should tank utilization");
    }

    #[test]
    fn driver_oom_is_reported() {
        let stages = vec![StageSpec::uniform("collect", 4, 0.1)
            .with_collect(100 << 30)];
        let r = simulate(&stages, &ClusterConfig::glue_like(8));
        assert!(!r.ok());
        assert!(r.failure.unwrap().contains("driver OOM"));
    }

    #[test]
    fn worker_oom_is_reported() {
        let stages =
            vec![StageSpec::uniform("big", 4, 0.1).with_working_set(10_000 << 30)];
        let r = simulate(&stages, &ClusterConfig::glue_like(8));
        assert!(!r.ok());
        assert!(r.failure.unwrap().contains("executor OOM"));
    }

    #[test]
    fn ray_overhead_slower_than_ddp() {
        // many small tasks with shuffled bytes: ray pays per-task + ser tax
        let stages = vec![
            StageSpec::uniform("a", 500, 0.01).with_shuffle(200 << 20),
            StageSpec::uniform("b", 500, 0.01).with_shuffle(200 << 20),
        ];
        let ddp = simulate(&stages, &ClusterConfig::glue_like(48));
        let ray = simulate(&stages, &ClusterConfig::ray_like(48));
        assert!(ray.makespan_secs > ddp.makespan_secs * 1.5,
            "ray {} vs ddp {}", ray.makespan_secs, ddp.makespan_secs);
    }

    #[test]
    fn stage_barriers_sum() {
        let stages = vec![
            StageSpec::uniform("a", 10, 1.0),
            StageSpec::uniform("b", 10, 1.0),
        ];
        let r = simulate(&stages, &ClusterConfig::glue_like(10));
        assert_eq!(r.stage_secs.len(), 2);
        let sum: f64 = r.stage_secs.iter().map(|(_, t)| t).sum();
        assert!((sum - r.makespan_secs).abs() < 1e-9);
    }

    #[test]
    fn trace_grouping() {
        use crate::engine::executor::TaskRecord;
        let trace = vec![
            TaskRecord { stage_id: 3, duration_secs: 0.1, input_rows: 1, output_bytes: 0, shuffle_bytes: 0 },
            TaskRecord { stage_id: 3, duration_secs: 0.2, input_rows: 1, output_bytes: 0, shuffle_bytes: 0 },
            TaskRecord { stage_id: 9, duration_secs: 0.3, input_rows: 1, output_bytes: 0, shuffle_bytes: 0 },
        ];
        let stages = trace_to_stages(&trace, 100);
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].task_secs.len(), 2);
        assert_eq!(stages[1].task_secs.len(), 1);
        // no measured bytes: fallback spreads the provided total evenly
        assert_eq!(stages[0].shuffle_bytes, 50);
        assert_eq!(stages[1].shuffle_bytes, 50);
    }

    #[test]
    fn trace_with_measured_bytes_keeps_per_stage_skew() {
        use crate::engine::executor::TaskRecord;
        let trace = vec![
            TaskRecord { stage_id: 1, duration_secs: 0.1, input_rows: 5, output_bytes: 900, shuffle_bytes: 900 },
            TaskRecord { stage_id: 1, duration_secs: 0.1, input_rows: 5, output_bytes: 100, shuffle_bytes: 100 },
            TaskRecord { stage_id: 2, duration_secs: 0.1, input_rows: 5, output_bytes: 40, shuffle_bytes: 0 },
        ];
        // the provided total is ignored when the trace carries real bytes
        let stages = trace_to_stages(&trace, 999_999);
        assert_eq!(stages[0].shuffle_bytes, 1000, "measured map-side bytes per stage");
        assert_eq!(stages[1].shuffle_bytes, 0, "result stage moved nothing");
    }

    #[test]
    fn real_trace_replays_with_measured_bytes() {
        use crate::engine::{Dataset, EngineConfig, EngineCtx};
        use crate::row;
        let c = EngineCtx::new(EngineConfig { workers: 2, record_trace: true, ..Default::default() });
        let schema = crate::engine::Schema::of_names(&["x"]);
        let ds = Dataset::from_rows("n", schema, (0..200i64).map(|i| row!(i % 13)).collect(), 4);
        c.count(&ds.distinct(3)).unwrap();
        let trace = c.take_trace();
        let stages = trace_to_stages(&trace, 0);
        let total: u64 = stages.iter().map(|s| s.shuffle_bytes).sum();
        assert!(total > 0, "executor-recorded traces carry real shuffle bytes");
        let sim = simulate(&stages, &ClusterConfig::glue_like(8));
        assert!(sim.ok());
    }
}
