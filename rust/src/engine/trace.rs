//! Structured span tracing with per-span counter attribution.
//!
//! The engine's [`EngineStats`] counters are process-global: they say the
//! pipeline spilled 40 MiB, not *which stage* spilled it. This module adds
//! the missing dimension — a tree of spans (pipeline run → pipe → plan
//! stage → task / streaming micro-batch) with deterministic ids,
//! start/duration read from [`crate::util::clock`], and a span-local
//! [`StatsSnapshot`] that every charge site fills *in addition to* the
//! global atomics. Each charge is attributed to exactly one span (no
//! parent roll-up at charge time), so the global counters are provably
//! the sum of the span-local ones plus an explicit orphan bucket for
//! charges made outside any span — the invariant `rust/tests/trace.rs`
//! asserts.
//!
//! Two consumers sit on top of the span tree:
//! - [`Tracer::chrome_trace_json`] / [`Tracer::write_chrome_trace`]: a
//!   Chrome trace-event (Perfetto-compatible) JSON export with one lane
//!   per executing thread and cumulative counter tracks;
//! - [`Tracer::profile_report`]: a deterministic text report — top
//!   stages by time, spill / vectorization-fallback hotspots, and the
//!   critical-path length through the span tree.
//!
//! Cost model: a disabled tracer ([`EngineConfig::trace`] false /
//! `DDP_TRACE` unset) takes a single branch per call — span names are
//! passed as closures so no formatting happens, and no lock is touched.
//! Enabled, spans append to one preallocated vector under a mutex and
//! charges are index addressing into it.
//!
//! [`EngineConfig::trace`]: super::executor::EngineConfig
//! [`EngineStats`]: super::stats::EngineStats

use super::memory::GovernorObserver;
use super::stats::{Stat, StatsSnapshot};
use crate::json::Value;
use crate::util::clock::{self, ClockRef};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Sentinel span id: "no span" (disabled tracer, or no scope entered).
pub const NO_SPAN: u64 = 0;

/// Level of a span in the run → pipe → stage → task hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// one `PipelineDriver::run`
    Run,
    /// one pipe execution inside a run
    Pipe,
    /// one executor plan stage (narrow chain or one side of a wide op)
    Stage,
    /// one task within a stage, on a pool worker thread
    Task,
    /// one streaming micro-batch push (or the final drain)
    MicroBatch,
}

impl SpanKind {
    /// Lowercase category name (Chrome trace `cat`, report labels).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Run => "run",
            SpanKind::Pipe => "pipe",
            SpanKind::Stage => "stage",
            SpanKind::Task => "task",
            SpanKind::MicroBatch => "micro_batch",
        }
    }
}

/// Counters attributed to one span: the engine-stat set plus the
/// memory-governor admission outcomes observed while the span was the
/// thread's current scope (governor decisions are not [`Stat`]s — they
/// live on the governor, not [`super::stats::EngineStats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct SpanCounters {
    /// span-local share of the global engine counters
    pub stats: StatsSnapshot,
    /// governor reservations granted while this span was current
    pub mem_reservations: u64,
    /// bytes those granted reservations admitted
    pub mem_reserved_bytes: u64,
    /// governor refusals (spill decisions) while this span was current
    pub mem_refusals: u64,
}

impl SpanCounters {
    fn accumulate(&mut self, other: &SpanCounters) {
        self.stats.accumulate(&other.stats);
        self.mem_reservations += other.mem_reservations;
        self.mem_reserved_bytes += other.mem_reserved_bytes;
        self.mem_refusals += other.mem_refusals;
    }
}

/// One recorded span.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// deterministic id: 1-based creation order within the tracer
    pub id: u64,
    /// parent span id, [`NO_SPAN`] for roots
    pub parent: u64,
    pub kind: SpanKind,
    pub name: String,
    /// start time (seconds on the tracer's clock)
    pub start_secs: f64,
    /// end time; meaningful once `open` is false
    pub end_secs: f64,
    /// still running (export treats open spans as ending "now")
    pub open: bool,
    /// display lane (one per executing thread, first-use order)
    pub lane: u64,
    pub counters: SpanCounters,
}

impl SpanRecord {
    pub fn duration_secs(&self) -> f64 {
        (self.end_secs - self.start_secs).max(0.0)
    }
}

// Tracer instances get a process-unique token; the thread-local current
// scope stores (token, span) so a scope entered for one engine context
// can never soak up charges from another context sharing the thread.
static NEXT_TOKEN: AtomicU64 = AtomicU64::new(1);
// Display lanes are per-thread, assigned on first traced use.
static NEXT_LANE: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static CURRENT: Cell<(u64, u64)> = const { Cell::new((0, NO_SPAN)) };
    static LANE: Cell<u64> = const { Cell::new(u64::MAX) };
}

fn this_lane() -> u64 {
    LANE.with(|l| {
        let v = l.get();
        if v != u64::MAX {
            return v;
        }
        let v = NEXT_LANE.fetch_add(1, Ordering::Relaxed);
        l.set(v);
        v
    })
}

/// Span recorder for one engine context. Shared via `Arc`; all methods
/// take `&self`.
pub struct Tracer {
    enabled: bool,
    token: u64,
    clock: ClockRef,
    spans: Mutex<Vec<SpanRecord>>,
    /// charges made while no span of this tracer was current
    orphan: Mutex<SpanCounters>,
}

/// RAII scope: makes a span the thread's current charge target and ends
/// the span when dropped (restoring the previous scope).
pub struct SpanScope {
    tracer: Option<Arc<Tracer>>,
    span: u64,
    prev: (u64, u64),
}

impl Drop for SpanScope {
    fn drop(&mut self) {
        if let Some(t) = self.tracer.take() {
            CURRENT.with(|c| c.set(self.prev));
            t.end(self.span);
        }
    }
}

impl Tracer {
    /// A tracer on the shared wall clock.
    pub fn new(enabled: bool) -> Arc<Tracer> {
        Tracer::with_clock(enabled, clock::wall())
    }

    /// A tracer on an explicit clock (tests inject a
    /// [`crate::util::clock::VirtualClock`] for deterministic times).
    pub fn with_clock(enabled: bool, clock: ClockRef) -> Arc<Tracer> {
        Arc::new(Tracer {
            enabled,
            token: NEXT_TOKEN.fetch_add(1, Ordering::Relaxed),
            clock,
            spans: Mutex::new(Vec::with_capacity(if enabled { 256 } else { 0 })),
            orphan: Mutex::new(SpanCounters::default()),
        })
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Open a span. `name` is only invoked when tracing is enabled (no
    /// formatting cost on the disabled path). `parent: None` inherits
    /// the thread's current span of this tracer. Returns [`NO_SPAN`]
    /// when disabled.
    pub fn begin(&self, kind: SpanKind, name: impl FnOnce() -> String, parent: Option<u64>) -> u64 {
        if !self.enabled {
            return NO_SPAN;
        }
        let parent = parent.unwrap_or_else(|| self.current());
        let now = self.clock.now();
        let lane = this_lane();
        let mut spans = self.spans.lock().unwrap();
        let id = spans.len() as u64 + 1;
        spans.push(SpanRecord {
            id,
            parent,
            kind,
            name: name(),
            start_secs: now,
            end_secs: now,
            open: true,
            lane,
            counters: SpanCounters::default(),
        });
        id
    }

    /// Close a span (idempotent; the first close wins the end time).
    pub fn end(&self, span: u64) {
        if !self.enabled || span == NO_SPAN {
            return;
        }
        let now = self.clock.now();
        let mut spans = self.spans.lock().unwrap();
        if let Some(s) = spans.get_mut(span as usize - 1) {
            if s.open {
                s.end_secs = now;
                s.open = false;
            }
        }
    }

    /// Make `span` the thread's current charge target until the guard
    /// drops; the drop also ends the span. Call on the thread that
    /// executes the span's work.
    pub fn scope(self: &Arc<Self>, span: u64) -> SpanScope {
        if !self.enabled || span == NO_SPAN {
            return SpanScope { tracer: None, span: NO_SPAN, prev: (0, NO_SPAN) };
        }
        let prev = CURRENT.with(|c| c.replace((self.token, span)));
        SpanScope { tracer: Some(self.clone()), span, prev }
    }

    /// The thread's current span of *this* tracer ([`NO_SPAN`] if the
    /// thread is inside no scope, or inside another tracer's scope).
    pub fn current(&self) -> u64 {
        if !self.enabled {
            return NO_SPAN;
        }
        CURRENT.with(|c| {
            let (token, span) = c.get();
            if token == self.token {
                span
            } else {
                NO_SPAN
            }
        })
    }

    /// Attribute `v` of counter `s` to `span` ([`NO_SPAN`] → the orphan
    /// bucket, so the span-sum invariant still holds for charges made
    /// outside any scope).
    pub fn charge(&self, span: u64, s: Stat, v: u64) {
        if !self.enabled || v == 0 {
            return;
        }
        if span == NO_SPAN {
            self.orphan.lock().unwrap().stats.bump(s, v);
            return;
        }
        let mut spans = self.spans.lock().unwrap();
        if let Some(rec) = spans.get_mut(span as usize - 1) {
            rec.counters.stats.bump(s, v);
        }
    }

    /// Attribute to the thread's current span (or the orphan bucket).
    pub fn charge_current(&self, s: Stat, v: u64) {
        if !self.enabled {
            return;
        }
        self.charge(self.current(), s, v);
    }

    fn charge_mem(&self, granted: bool, bytes: u64) {
        if !self.enabled {
            return;
        }
        let span = self.current();
        let apply = |c: &mut SpanCounters| {
            if granted {
                c.mem_reservations += 1;
                c.mem_reserved_bytes += bytes;
            } else {
                c.mem_refusals += 1;
            }
        };
        if span == NO_SPAN {
            apply(&mut self.orphan.lock().unwrap());
            return;
        }
        let mut spans = self.spans.lock().unwrap();
        if let Some(rec) = spans.get_mut(span as usize - 1) {
            apply(&mut rec.counters);
        }
    }

    /// Snapshot of every recorded span (ids are 1..=len, in order).
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().clone()
    }

    /// Charges that landed outside any span.
    pub fn orphan_counters(&self) -> SpanCounters {
        *self.orphan.lock().unwrap()
    }

    /// Sum of all span-local counters plus the orphan bucket. With
    /// tracing on this equals the global [`EngineStats`] snapshot delta
    /// over the same window — the invariant the trace suite asserts.
    ///
    /// [`EngineStats`]: super::stats::EngineStats
    pub fn totals(&self) -> SpanCounters {
        let mut total = self.orphan_counters();
        for s in self.spans.lock().unwrap().iter() {
            total.accumulate(&s.counters);
        }
        total
    }

    // ------------------------------------------------------------------
    // consumer 1: Chrome trace-event / Perfetto JSON
    // ------------------------------------------------------------------

    /// Chrome trace-event JSON (open in `chrome://tracing` or
    /// <https://ui.perfetto.dev>): one complete (`"X"`) event per span on
    /// its thread's lane, plus cumulative counter (`"C"`) tracks for
    /// shuffle, spill and governed memory at each stage end.
    pub fn chrome_trace_json(&self) -> Value {
        let spans = self.spans();
        // an open span (export mid-run) renders up to "now"
        let now = if self.enabled { self.clock.now() } else { 0.0 };
        let mut events: Vec<Value> = Vec::with_capacity(spans.len() + 16);
        let mut lanes: Vec<u64> = spans.iter().map(|s| s.lane).collect();
        lanes.sort_unstable();
        lanes.dedup();
        events.push(Value::obj(vec![
            ("ph", Value::from("M")),
            ("name", Value::from("process_name")),
            ("pid", Value::Num(1.0)),
            ("args", Value::obj(vec![("name", Value::from("sparklet"))])),
        ]));
        for lane in &lanes {
            events.push(Value::obj(vec![
                ("ph", Value::from("M")),
                ("name", Value::from("thread_name")),
                ("pid", Value::Num(1.0)),
                ("tid", Value::Num(*lane as f64)),
                ("args", Value::obj(vec![("name", Value::from(format!("lane-{lane}")))])),
            ]));
        }
        for s in &spans {
            let end = if s.open { now.max(s.start_secs) } else { s.end_secs };
            let mut args: Vec<(&str, Value)> = vec![
                ("span_id", Value::Num(s.id as f64)),
                ("parent", Value::Num(s.parent as f64)),
            ];
            for stat in Stat::ALL {
                let v = s.counters.stats.get(stat);
                if v > 0 {
                    args.push((stat.name(), Value::Num(v as f64)));
                }
            }
            if s.counters.mem_reservations > 0 {
                args.push(("mem_reservations", Value::Num(s.counters.mem_reservations as f64)));
                args.push((
                    "mem_reserved_bytes",
                    Value::Num(s.counters.mem_reserved_bytes as f64),
                ));
            }
            if s.counters.mem_refusals > 0 {
                args.push(("mem_refusals", Value::Num(s.counters.mem_refusals as f64)));
            }
            events.push(Value::obj(vec![
                ("ph", Value::from("X")),
                ("name", Value::from(s.name.as_str())),
                ("cat", Value::from(s.kind.name())),
                ("ts", Value::Num(s.start_secs * 1e6)),
                ("dur", Value::Num((end - s.start_secs).max(0.0) * 1e6)),
                ("pid", Value::Num(1.0)),
                ("tid", Value::Num(s.lane as f64)),
                ("args", Value::obj(args)),
            ]));
        }
        // cumulative counter tracks, sampled at each stage-span end
        let mut stages: Vec<&SpanRecord> =
            spans.iter().filter(|s| s.kind == SpanKind::Stage && !s.open).collect();
        stages.sort_by(|a, b| {
            a.end_secs.total_cmp(&b.end_secs).then_with(|| a.id.cmp(&b.id))
        });
        let (mut shuffle, mut spill, mut reserved) = (0u64, 0u64, 0u64);
        for s in stages {
            shuffle += s.counters.stats.shuffle_bytes;
            spill += s.counters.stats.spill_bytes;
            reserved += s.counters.mem_reserved_bytes;
            events.push(Value::obj(vec![
                ("ph", Value::from("C")),
                ("name", Value::from("engine bytes")),
                ("pid", Value::Num(1.0)),
                ("ts", Value::Num(s.end_secs * 1e6)),
                (
                    "args",
                    Value::obj(vec![
                        ("shuffle_bytes", Value::Num(shuffle as f64)),
                        ("spill_bytes", Value::Num(spill as f64)),
                        ("mem_reserved_bytes", Value::Num(reserved as f64)),
                    ]),
                ),
            ]));
        }
        Value::obj(vec![
            ("traceEvents", Value::Arr(events)),
            ("displayTimeUnit", Value::from("ms")),
        ])
    }

    /// Write [`Self::chrome_trace_json`] to `path`.
    pub fn write_chrome_trace(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, crate::json::to_string_pretty(&self.chrome_trace_json()))
    }

    // ------------------------------------------------------------------
    // consumer 2: deterministic text profile report
    // ------------------------------------------------------------------

    /// Aggregate stage spans by name (deterministic: name-sorted). The
    /// metrics exporter publishes these as per-stage gauges.
    pub fn stage_rollup(&self) -> Vec<StageAgg> {
        let mut by_name: BTreeMap<String, StageAgg> = BTreeMap::new();
        for s in self.spans.lock().unwrap().iter() {
            if s.kind != SpanKind::Stage {
                continue;
            }
            let agg = by_name.entry(s.name.clone()).or_insert_with(|| StageAgg {
                name: s.name.clone(),
                ..StageAgg::default()
            });
            agg.spans += 1;
            agg.wall_secs += s.duration_secs();
            agg.counters.accumulate(&s.counters);
        }
        by_name.into_values().collect()
    }

    /// Deterministic text profile: top-`top_n` stages by total time
    /// (ties broken by name), spill and vectorization-fallback hotspots,
    /// governor pressure, and the critical-path length through the span
    /// tree (longest chain of non-overlapping spans, descending through
    /// children).
    pub fn profile_report(&self, top_n: usize) -> String {
        use std::fmt::Write as _;
        let spans = self.spans();
        let mut out = String::new();
        let _ = writeln!(out, "== sparklet trace profile ==");
        let mut kind_counts: BTreeMap<&'static str, usize> = BTreeMap::new();
        for s in &spans {
            *kind_counts.entry(s.kind.name()).or_default() += 1;
        }
        let kinds: Vec<String> =
            kind_counts.iter().map(|(k, n)| format!("{n} {k}")).collect();
        let _ = writeln!(out, "spans: {} ({})", spans.len(), kinds.join(", "));
        let (cp_secs, cp_spans) = critical_path(&spans);
        let _ = writeln!(out, "critical path: {cp_secs:.6}s across {cp_spans} span(s)");

        let mut stages = self.stage_rollup();
        stages.sort_by(|a, b| {
            b.wall_secs.total_cmp(&a.wall_secs).then_with(|| a.name.cmp(&b.name))
        });
        if !stages.is_empty() {
            let _ = writeln!(out, "top stages by total time:");
            for (i, a) in stages.iter().take(top_n).enumerate() {
                let _ = writeln!(
                    out,
                    "  {:>2}. {:<24} {:.6}s  spans={} tasks={} rows_in={} shuffle={}",
                    i + 1,
                    a.name,
                    a.wall_secs,
                    a.spans,
                    a.counters.stats.tasks_launched,
                    a.counters.stats.rows_read,
                    fmt_bytes(a.counters.stats.shuffle_bytes),
                );
            }
        }
        let spillers: Vec<&StageAgg> =
            stages.iter().filter(|a| a.counters.stats.spill_bytes > 0).collect();
        if !spillers.is_empty() {
            let _ = writeln!(out, "spill hotspots:");
            for a in spillers {
                let _ = writeln!(
                    out,
                    "  {:<24} spill={} files={} sort_runs={}",
                    a.name,
                    fmt_bytes(a.counters.stats.spill_bytes),
                    a.counters.stats.spill_files,
                    a.counters.stats.sort_runs,
                );
            }
        }
        let fallers: Vec<&StageAgg> = stages
            .iter()
            .filter(|a| {
                a.counters.stats.vectorized_fallbacks
                    + a.counters.stats.vectorized_shuffle_fallbacks
                    > 0
            })
            .collect();
        if !fallers.is_empty() {
            let _ = writeln!(out, "vectorization fallbacks:");
            for a in fallers {
                let _ = writeln!(
                    out,
                    "  {:<24} batches={} fallbacks={} shuffle_batches={} shuffle_fallbacks={}",
                    a.name,
                    a.counters.stats.vectorized_batches,
                    a.counters.stats.vectorized_fallbacks,
                    a.counters.stats.vectorized_shuffle_batches,
                    a.counters.stats.vectorized_shuffle_fallbacks,
                );
            }
        }
        let t = self.totals();
        let _ = writeln!(
            out,
            "memory governor: {} reservation(s) granted ({}), {} refused",
            t.mem_reservations,
            fmt_bytes(t.mem_reserved_bytes),
            t.mem_refusals,
        );
        let orphan = self.orphan_counters();
        let named: Vec<String> = Stat::ALL
            .into_iter()
            .filter(|s| orphan.stats.get(*s) > 0)
            .map(|s| format!("{}={}", s.name(), orphan.stats.get(s)))
            .collect();
        if !named.is_empty() {
            let _ = writeln!(out, "unattributed charges: {}", named.join(" "));
        }
        out
    }
}

// The tracer observes governor admission decisions so reservations and
// refusals land on the span whose work triggered them (task spans are
// scope-entered on the worker thread running the reserving code).
impl GovernorObserver for Tracer {
    fn reservation_granted(&self, bytes: u64) {
        self.charge_mem(true, bytes);
    }

    fn reservation_refused(&self, bytes: u64) {
        self.charge_mem(false, bytes);
    }
}

/// Per-stage aggregate (one per distinct stage-span name).
#[derive(Debug, Clone, Default)]
pub struct StageAgg {
    pub name: String,
    /// number of stage spans aggregated under this name
    pub spans: usize,
    /// summed wall-clock duration of those spans
    pub wall_secs: f64,
    pub counters: SpanCounters,
}

/// Longest chain of non-overlapping spans through the tree, descending
/// into children: `cp(span) = max(duration, best sequential chain of
/// children cps)`, and the overall path chains root spans the same way.
/// Returns `(seconds, spans on the path)`.
pub fn critical_path(spans: &[SpanRecord]) -> (f64, usize) {
    if spans.is_empty() {
        return (0.0, 0);
    }
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); spans.len()];
    let mut roots: Vec<usize> = Vec::new();
    for (i, s) in spans.iter().enumerate() {
        let p = s.parent as usize;
        if p >= 1 && p <= spans.len() && s.parent != s.id {
            children[p - 1].push(i);
        } else {
            roots.push(i);
        }
    }
    let mut memo: Vec<Option<(f64, usize)>> = vec![None; spans.len()];
    // post-order without recursion (span trees can be deep in theory)
    let mut stack: Vec<(usize, bool)> = roots.iter().map(|&r| (r, false)).collect();
    while let Some((i, expanded)) = stack.pop() {
        if memo[i].is_some() {
            continue;
        }
        if !expanded {
            stack.push((i, true));
            for &c in &children[i] {
                stack.push((c, false));
            }
            continue;
        }
        let kids: Vec<(f64, f64, f64, usize)> = children[i]
            .iter()
            .map(|&c| {
                let (w, n) = memo[c].expect("children resolved before parent");
                (spans[c].start_secs, spans[c].end_secs, w, n)
            })
            .collect();
        let (chain_w, chain_n) = best_chain(kids);
        let own = spans[i].duration_secs();
        memo[i] = Some(if chain_w > own { (chain_w, chain_n) } else { (own, 1) });
    }
    let root_items: Vec<(f64, f64, f64, usize)> = roots
        .iter()
        .map(|&r| {
            let (w, n) = memo[r].expect("roots resolved");
            (spans[r].start_secs, spans[r].end_secs, w, n)
        })
        .collect();
    best_chain(root_items)
}

/// Best-weight chain of non-overlapping `(start, end, weight, count)`
/// intervals (weighted interval scheduling, O(n log n)).
fn best_chain(mut items: Vec<(f64, f64, f64, usize)>) -> (f64, usize) {
    if items.is_empty() {
        return (0.0, 0);
    }
    const EPS: f64 = 1e-9;
    items.sort_by(|a, b| a.1.total_cmp(&b.1).then_with(|| a.0.total_cmp(&b.0)));
    let ends: Vec<f64> = items.iter().map(|it| it.1).collect();
    // best[i] = best chain among items[0..=i]
    let mut best: Vec<(f64, usize)> = Vec::with_capacity(items.len());
    for (i, it) in items.iter().enumerate() {
        let cut = ends.partition_point(|&e| e <= it.0 + EPS).min(i);
        let prev = if cut > 0 { best[cut - 1] } else { (0.0, 0) };
        let mine = (prev.0 + it.2, prev.1 + it.3);
        let carried = if i > 0 { best[i - 1] } else { (0.0, 0) };
        best.push(if mine.0 > carried.0 { mine } else { carried });
    }
    *best.last().unwrap()
}

/// Deterministic human byte formatting (fixed two decimals above KiB).
fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2}GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2}MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2}KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::{virt, Clock};

    fn traced() -> (Arc<Tracer>, Arc<crate::util::clock::VirtualClock>) {
        let clock = virt();
        let tracer = Tracer::with_clock(true, clock.clone());
        (tracer, clock)
    }

    #[test]
    fn spans_nest_and_time_from_the_clock() {
        let (t, clock) = traced();
        clock.set(10.0);
        let run = t.begin(SpanKind::Run, || "run".into(), None);
        let _rs = t.scope(run);
        clock.advance(1.0);
        let stage = t.begin(SpanKind::Stage, || "narrow#1".into(), None);
        {
            let _ss = t.scope(stage);
            assert_eq!(t.current(), stage);
            clock.advance(2.0);
        }
        assert_eq!(t.current(), run);
        let spans = t.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].id, 1);
        assert_eq!(spans[1].parent, run, "stage inherits the scoped parent");
        assert_eq!(spans[1].start_secs, 11.0);
        assert!(!spans[1].open);
        assert_eq!(spans[1].duration_secs(), 2.0);
        assert!(spans[0].open, "run scope still held");
    }

    #[test]
    fn charges_attribute_to_current_span_or_orphan() {
        let (t, _clock) = traced();
        t.charge_current(Stat::PlanRewrites, 3);
        let span = t.begin(SpanKind::Stage, || "s".into(), None);
        {
            let _s = t.scope(span);
            t.charge_current(Stat::RowsRead, 10);
            t.charge(span, Stat::ShuffleBytes, 100);
        }
        t.charge_current(Stat::RowsRead, 5);
        let spans = t.spans();
        assert_eq!(spans[0].counters.stats.rows_read, 10);
        assert_eq!(spans[0].counters.stats.shuffle_bytes, 100);
        let orphan = t.orphan_counters();
        assert_eq!(orphan.stats.plan_rewrites, 3);
        assert_eq!(orphan.stats.rows_read, 5);
        let total = t.totals();
        assert_eq!(total.stats.rows_read, 15);
        assert_eq!(total.stats.shuffle_bytes, 100);
    }

    #[test]
    fn scopes_are_tracer_scoped_not_thread_global() {
        let (a, _ca) = traced();
        let (b, _cb) = traced();
        let sa = a.begin(SpanKind::Stage, || "a".into(), None);
        let _ga = a.scope(sa);
        // b's charge on this thread must not land in a's span
        b.charge_current(Stat::RowsRead, 7);
        assert_eq!(a.spans()[0].counters.stats.rows_read, 0);
        assert_eq!(b.orphan_counters().stats.rows_read, 7);
        assert_eq!(b.current(), NO_SPAN);
    }

    #[test]
    fn disabled_tracer_is_inert_and_lazy() {
        let t = Tracer::new(false);
        let mut named = false;
        let span = t.begin(
            SpanKind::Run,
            || {
                named = true;
                "x".into()
            },
            None,
        );
        assert_eq!(span, NO_SPAN);
        assert!(!named, "name closure must not run when disabled");
        let _s = t.scope(span);
        t.charge_current(Stat::RowsRead, 9);
        t.charge(span, Stat::RowsRead, 9);
        assert!(t.spans().is_empty());
        assert_eq!(t.totals().stats.rows_read, 0);
    }

    #[test]
    fn governor_observer_attributes_to_current_span() {
        let (t, _clock) = traced();
        let span = t.begin(SpanKind::Task, || "task".into(), None);
        {
            let _s = t.scope(span);
            t.reservation_granted(4096);
            t.reservation_refused(1 << 20);
        }
        t.reservation_granted(16);
        let c = t.spans()[0].counters;
        assert_eq!(c.mem_reservations, 1);
        assert_eq!(c.mem_reserved_bytes, 4096);
        assert_eq!(c.mem_refusals, 1);
        assert_eq!(t.orphan_counters().mem_reservations, 1);
        let total = t.totals();
        assert_eq!(total.mem_reservations, 2);
        assert_eq!(total.mem_reserved_bytes, 4112);
    }

    #[test]
    fn chrome_trace_round_trips_and_scales_to_micros() {
        let (t, clock) = traced();
        clock.set(1.0);
        let run = t.begin(SpanKind::Run, || "run".into(), None);
        {
            let _rs = t.scope(run);
            let stage = t.begin(SpanKind::Stage, || "sort#3".into(), None);
            let _ss = t.scope(stage);
            t.charge(stage, Stat::ShuffleBytes, 2048);
            clock.advance(0.5);
        }
        let text = crate::json::to_string_pretty(&t.chrome_trace_json());
        let parsed = crate::json::parse(&text).expect("export must be valid JSON");
        let events = parsed.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let stage_ev = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("sort#3"))
            .expect("stage event present");
        assert_eq!(stage_ev.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(stage_ev.get("ts").unwrap().as_f64(), Some(1e6));
        assert_eq!(stage_ev.get("dur").unwrap().as_f64(), Some(0.5e6));
        let args = stage_ev.get("args").unwrap();
        assert_eq!(args.get("shuffle_bytes").unwrap().as_u64(), Some(2048));
        // cumulative counter track sampled at the stage end
        assert!(events.iter().any(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("C")
                && e.get("args")
                    .and_then(|a| a.get("shuffle_bytes"))
                    .and_then(|v| v.as_u64())
                    == Some(2048)
        }));
    }

    #[test]
    fn critical_path_chains_non_overlapping_children() {
        let (t, clock) = traced();
        clock.set(0.0);
        let run = t.begin(SpanKind::Run, || "run".into(), None);
        // two sequential stages (1s + 2s) and one overlapping both (2.5s):
        // the chain 1s+2s = 3s beats the single 2.5s span
        let a = t.begin(SpanKind::Stage, || "a".into(), Some(run));
        clock.advance(1.0);
        t.end(a);
        let b = t.begin(SpanKind::Stage, || "b".into(), Some(run));
        clock.advance(2.0);
        t.end(b);
        let c = t.begin(SpanKind::Stage, || "c".into(), Some(run));
        clock.set(0.25); // overlaps a and b
        t.end(run); // ends at 0.25 on the rewound clock — irrelevant, run duration < chain
        let spans = {
            let mut s = t.spans();
            // give c a real interval overlapping a and b
            s[3].start_secs = 0.5;
            s[3].end_secs = 3.0;
            s[3].open = false;
            let _ = c;
            s
        };
        let (secs, count) = critical_path(&spans);
        assert!((secs - 3.0).abs() < 1e-9, "got {secs}");
        assert_eq!(count, 2);
    }

    #[test]
    fn profile_report_is_deterministic_and_names_hotspots() {
        let (t, clock) = traced();
        let stage = t.begin(SpanKind::Stage, || "reduce#9".into(), None);
        {
            let _s = t.scope(stage);
            t.charge(stage, Stat::SpillBytes, 9000);
            t.charge(stage, Stat::SpillFiles, 2);
            t.charge(stage, Stat::VectorizedFallbacks, 1);
            clock.advance(0.125);
        }
        let r1 = t.profile_report(5);
        let r2 = t.profile_report(5);
        assert_eq!(r1, r2, "report must be deterministic");
        assert!(r1.contains("reduce#9"));
        assert!(r1.contains("spill hotspots:"));
        assert!(r1.contains("vectorization fallbacks:"));
        assert!(r1.contains("critical path: 0.125000s"));
    }

    #[test]
    fn stage_rollup_groups_by_name() {
        let (t, clock) = traced();
        for _ in 0..2 {
            let s = t.begin(SpanKind::Stage, || "narrow#4".into(), None);
            let _g = t.scope(s);
            t.charge(s, Stat::RowsRead, 50);
            clock.advance(0.25);
        }
        let other = t.begin(SpanKind::Task, || "task".into(), None);
        t.end(other);
        let rollup = t.stage_rollup();
        assert_eq!(rollup.len(), 1, "task spans excluded");
        assert_eq!(rollup[0].name, "narrow#4");
        assert_eq!(rollup[0].spans, 2);
        assert_eq!(rollup[0].counters.stats.rows_read, 100);
        assert!((rollup[0].wall_secs - 0.5).abs() < 1e-9);
    }
}
