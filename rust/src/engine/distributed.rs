//! Real driver/worker distributed execution.
//!
//! Promotes the trace-fed [`super::cluster`] *simulator* into an actual
//! multi-process mode: `ddp worker --listen <addr>` processes execute
//! data-plane tasks the driver ships over TCP ([`super::net`] frames,
//! colbin v2 row payloads — the spill wire format), and the driver
//! partitions each eligible stage's tasks across the worker fleet.
//!
//! ## What ships, what stays local
//!
//! Plan nodes carry opaque Rust closures (`map`/`filter`/`flat_map`/
//! `map_partitions`, reduce and comparator functions), which cannot
//! cross a process boundary. The split is therefore *declarative data
//! plane remote, control plane and closures local*:
//!
//! * **narrow stages** whose fused chain is entirely structured
//!   ([`FilterExpr`](super::dataset::Plan::FilterExpr) /
//!   [`Project`](super::dataset::Plan::Project)) ship as SQL text — the
//!   pinned `Expr` display ↔ [`crate::pipes::sql::compile`] round-trip
//!   is the serialization format, verified per stage before dispatch;
//! * **shuffle map sides** keyed by whole-row hash (`distinct` /
//!   `repartition`) or by a declared key column (`join_on`) ship rows
//!   and receive hash buckets back — [`super::executor`]'s
//!   deterministic `DefaultHasher`-based bucketing produces identical
//!   bucket layouts in any process running this code;
//! * everything else (reduce map-side combine, sort, opaque chains)
//!   runs local and counts a `dist_fallbacks`.
//!
//! Output is **byte-identical** to single-process execution at any
//! worker count because workers execute the same kernels over the same
//! partitions and the driver preserves partition order end-to-end
//! (proven differentially by `rust/tests/distributed.rs`).
//!
//! ## Worker loss
//!
//! The driver holds every shipped input partition, so a dead worker
//! (connection error mid-call) costs nothing but a retry: the worker is
//! marked dead, the task fails over to the next live worker — or to
//! local execution when none remain — and the retry is charged to
//! `tasks_retried` / `dist_workers_lost`. A *compute* error reported by
//! a worker (an `ERR` frame) is deterministic and is NOT failed over:
//! the task re-runs locally so the error surfaces exactly as a
//! single-process run would surface it.

use super::executor::{ColBound, Step};
use super::expr::Expr;
use super::net::{self, op};
use super::row::{Row, Schema};
use super::trace::{SpanKind, Tracer};
use crate::json::Value;
use crate::util::error::{DdpError, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ---------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------

/// Worker behavior knobs (CLI-facing).
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerOptions {
    /// exit the process (simulating a crash) after serving this many
    /// data-plane requests — the worker-loss test hook
    pub fail_after: Option<u64>,
}

/// Serve data-plane requests on `listener` until the process exits.
/// Each connection is handled on its own thread; a connection ends at
/// EOF or an explicit [`op::SHUTDOWN`].
pub fn serve(listener: TcpListener, opts: WorkerOptions) -> Result<()> {
    let served = Arc::new(AtomicU64::new(0));
    for conn in listener.incoming() {
        let conn = conn?;
        let served = served.clone();
        std::thread::spawn(move || {
            let _ = serve_conn(conn, &served, opts.fail_after);
        });
    }
    Ok(())
}

fn serve_conn(mut conn: TcpStream, served: &AtomicU64, fail_after: Option<u64>) -> Result<()> {
    conn.set_nodelay(true).ok();
    loop {
        let frame = match net::read_frame(&mut conn) {
            Ok(f) => f,
            Err(_) => return Ok(()), // peer hung up
        };
        match frame.op {
            op::PING => net::write_frame(&mut conn, op::OK, &Value::obj(vec![]), &[])?,
            op::SHUTDOWN => return Ok(()),
            op::NARROW | op::BUCKET => {
                if let Some(n) = fail_after {
                    if served.fetch_add(1, Ordering::SeqCst) >= n {
                        // simulate a worker crash mid-request: die without
                        // responding, so the driver sees a dead connection
                        eprintln!("ddp worker: injected failure (fail-after reached)");
                        std::process::exit(3);
                    }
                }
                let out = if frame.op == op::NARROW {
                    handle_narrow(&frame.header, &frame.payload)
                } else {
                    handle_bucket(&frame.header, &frame.payload)
                };
                match out {
                    Ok((header, payload)) => {
                        net::write_frame(&mut conn, op::OK, &header, &payload)?
                    }
                    Err(e) => net::write_frame(
                        &mut conn,
                        op::ERR,
                        &Value::obj(vec![("msg", Value::str(e.to_string()))]),
                        &[],
                    )?,
                }
            }
            other => net::write_frame(
                &mut conn,
                op::ERR,
                &Value::obj(vec![("msg", Value::str(format!("unknown opcode {other}")))]),
                &[],
            )?,
        }
    }
}

/// Execute a shipped structured narrow chain over the payload rows.
fn handle_narrow(header: &Value, payload: &[u8]) -> Result<(Value, Vec<u8>)> {
    let data = header
        .get("data")
        .ok_or_else(|| DdpError::format("net", "narrow request missing 'data'"))?;
    let rows = net::blob_to_rows(data, payload)?;
    let steps = parse_steps(header)?;
    let out = if header.bool_or("vectorize", true) {
        super::executor::apply_chain_vectorized(&rows, &steps)?
    } else {
        super::executor::ChainOut::rows_only(super::executor::apply_chain_fused(&rows, &steps)?)
    };
    let blob = net::rows_to_blob(&out.rows)?;
    let header = Value::obj(vec![
        ("data", blob.meta),
        ("vec_batches", Value::num(out.vec_batches as f64)),
        ("vec_fallbacks", Value::num(out.vec_fallbacks as f64)),
    ]);
    Ok((header, blob.bytes))
}

/// Hash-bucket the payload rows: whole-row key when `key_col` is null,
/// the declared key column otherwise. Bucket layout is identical to the
/// driver's local map side — both run [`super::executor::bucket_of`]
/// over the same deterministic hash.
fn handle_bucket(header: &Value, payload: &[u8]) -> Result<(Value, Vec<u8>)> {
    let data = header
        .get("data")
        .ok_or_else(|| DdpError::format("net", "bucket request missing 'data'"))?;
    let rows = net::blob_to_rows(data, payload)?;
    let num_parts = header.u64_or("num_parts", 0) as usize;
    if num_parts == 0 {
        return Err(DdpError::format("net", "bucket request with num_parts=0"));
    }
    let key_col = header.get("key_col").and_then(|v| v.as_u64()).map(|v| v as usize);
    let mut buckets: Vec<Vec<Row>> = (0..num_parts).map(|_| Vec::new()).collect();
    for row in rows {
        let b = match key_col {
            Some(kc) => {
                if kc >= row.len() {
                    // the local row path would panic on this access; fail
                    // structured so the driver reproduces the error locally
                    return Err(DdpError::format(
                        "net",
                        format!("key column {kc} out of range for row of width {}", row.len()),
                    ));
                }
                super::executor::bucket_of(row.get(kc), num_parts)
            }
            None => super::executor::bucket_of(&super::executor::whole_row_key(&row), num_parts),
        };
        buckets[b].push(row);
    }
    let (metas, payload) = net::buckets_to_payload(&buckets)?;
    Ok((Value::obj(vec![("buckets", Value::Arr(metas))]), payload))
}

/// Rebuild the executor's step list from a shipped description. The
/// per-step [`ColBound`] travels with the step so an out-of-range
/// column reference raises the *same* structured error text on a
/// worker as it would locally.
fn parse_steps(header: &Value) -> Result<Vec<Step>> {
    let steps = header
        .get("steps")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| DdpError::format("net", "narrow request missing 'steps'"))?;
    let mut out = Vec::with_capacity(steps.len());
    for s in steps {
        let bound = parse_bound(s);
        match s.str_or("t", "").as_str() {
            "filter" => {
                let src = s
                    .get("expr")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| DdpError::format("net", "filter step missing 'expr'"))?
                    .to_string();
                let names = s.get_string_list("names");
                let refs: Vec<&str> = names.iter().map(|n| n.as_str()).collect();
                let schema = Schema::of_names(&refs);
                let expr = crate::pipes::sql::compile(&src, &schema)?;
                out.push(Step::FilterExpr(Arc::new(expr), bound));
            }
            "project" => {
                let cols: Vec<usize> = s
                    .get("cols")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|v| v.as_u64()).map(|v| v as usize).collect())
                    .unwrap_or_default();
                out.push(Step::Project(cols, bound));
            }
            other => {
                return Err(DdpError::format("net", format!("unknown step type '{other}'")))
            }
        }
    }
    Ok(out)
}

fn parse_bound(s: &Value) -> Option<ColBound> {
    let b = s.get("bound")?;
    Some(ColBound {
        idx: b.u64_or("idx", 0) as usize,
        name: b.str_or("name", "?"),
        // `op` is a &'static str in the bound error message — map the
        // wire string back onto the two statics the driver can send
        op: if b.str_or("op", "") == "projection" { "projection" } else { "filter predicate" },
    })
}

fn bound_to_json(bound: &ColBound) -> Value {
    Value::obj(vec![
        ("idx", Value::num(bound.idx as f64)),
        ("name", Value::str(bound.name.clone())),
        ("op", Value::str(bound.op)),
    ])
}

// ---------------------------------------------------------------------
// shipping eligibility (driver side)
// ---------------------------------------------------------------------

/// A narrow stage's wire description — built once per stage, reused by
/// every task. `try_build` returns `None` when the chain cannot ship
/// (opaque closures, or an expression whose SQL round-trip is not
/// verified exact), in which case the stage runs local.
pub(crate) struct NarrowDesc {
    steps: Vec<Value>,
    vectorize: bool,
}

impl NarrowDesc {
    pub(crate) fn try_build(steps: &[Step], vectorize: bool) -> Option<NarrowDesc> {
        if steps.is_empty() {
            return None;
        }
        let mut shipped = Vec::with_capacity(steps.len());
        for step in steps {
            match step {
                Step::FilterExpr(e, bound) => {
                    let names = reference_schema(e)?;
                    // the shipping format IS the pinned display ↔ compile
                    // round-trip; verify it reproduces this exact AST
                    // before trusting it with the stage
                    let printed = e.to_string();
                    let schema =
                        Schema::of_names(&names.iter().map(|n| n.as_str()).collect::<Vec<_>>());
                    match crate::pipes::sql::compile(&printed, &schema) {
                        Ok(back) if back == **e => {}
                        _ => return None,
                    }
                    let mut pairs = vec![
                        ("t", Value::str("filter")),
                        ("expr", Value::str(printed)),
                        ("names", Value::Arr(names.into_iter().map(Value::str).collect())),
                    ];
                    if let Some(b) = bound {
                        pairs.push(("bound", bound_to_json(b)));
                    }
                    shipped.push(Value::obj(pairs));
                }
                Step::Project(cols, bound) => {
                    let mut pairs = vec![
                        ("t", Value::str("project")),
                        (
                            "cols",
                            Value::Arr(cols.iter().map(|&c| Value::num(c as f64)).collect()),
                        ),
                    ];
                    if let Some(b) = bound {
                        pairs.push(("bound", bound_to_json(b)));
                    }
                    shipped.push(Value::obj(pairs));
                }
                _ => return None, // opaque closure — cannot ship
            }
        }
        Some(NarrowDesc { steps: shipped, vectorize })
    }

    fn request_header(&self, data_meta: Value) -> Value {
        Value::obj(vec![
            ("data", data_meta),
            ("steps", Value::Arr(self.steps.clone())),
            ("vectorize", Value::Bool(self.vectorize)),
        ])
    }
}

/// Build a synthetic schema under which `compile(e.to_string())`
/// resolves every column reference back to its original index: each
/// referenced name is placed at its index, gaps are padded with names
/// that cannot collide. `None` when the expression's references are
/// ambiguous (one name at two indices, or two names at one index —
/// possible under duplicate-column schemas, W101).
fn reference_schema(e: &Expr) -> Option<Vec<String>> {
    let mut refs: Vec<(usize, String)> = Vec::new();
    collect_cols(e, &mut refs);
    let width = refs.iter().map(|(i, _)| i + 1).max().unwrap_or(0);
    let mut names: Vec<Option<String>> = vec![None; width];
    for (i, n) in refs {
        match &names[i] {
            None => names[i] = Some(n),
            Some(existing) if *existing == n => {}
            Some(_) => return None, // two names claim one index
        }
    }
    let used: std::collections::BTreeSet<&String> =
        names.iter().flatten().collect::<std::collections::BTreeSet<_>>();
    if used.len() != names.iter().flatten().count() {
        return None; // one name claims two indices
    }
    let mut out = Vec::with_capacity(width);
    for (i, slot) in names.iter().enumerate() {
        match slot {
            Some(n) => out.push(n.clone()),
            None => {
                let mut pad = format!("__ddp_pad_{i}");
                while used.contains(&pad) {
                    pad.push('_');
                }
                out.push(pad);
            }
        }
    }
    Some(out)
}

fn collect_cols(e: &Expr, out: &mut Vec<(usize, String)>) {
    match e {
        Expr::Lit(_) => {}
        Expr::Col(i, n) => out.push((*i, n.clone())),
        Expr::Unary(_, x) => collect_cols(x, out),
        Expr::Binary(_, a, b) => {
            collect_cols(a, out);
            collect_cols(b, out);
        }
        Expr::Call(_, args) => {
            for a in args {
                collect_cols(a, out);
            }
        }
    }
}

// ---------------------------------------------------------------------
// driver side
// ---------------------------------------------------------------------

/// Per-task distribution counters, merged driver-side into
/// [`super::stats::EngineStats`] after task collection (the same
/// aggregate-then-charge pattern the vectorization counters use).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct DistCounters {
    /// 1 when the task's work executed on a remote worker
    pub remote: u64,
    /// request bytes shipped to workers (frames included)
    pub tx: u64,
    /// response bytes received from workers
    pub rx: u64,
    /// failovers after a worker connection died mid-task
    pub retried: u64,
    /// workers newly declared dead by this task
    pub lost: u64,
}

impl DistCounters {
    pub(crate) fn merge(&mut self, other: &DistCounters) {
        self.remote += other.remote;
        self.tx += other.tx;
        self.rx += other.rx;
        self.retried += other.retried;
        self.lost += other.lost;
    }
}

struct WorkerConn {
    addr: String,
    stream: Mutex<Option<TcpStream>>,
    dead: AtomicBool,
}

/// A fleet of connected worker processes. Tasks are assigned round-robin
/// by task index; a worker whose connection dies is marked dead and its
/// tasks fail over to survivors (or to local execution). Spawned-local
/// children are killed when the pool drops; they also watch their stdin
/// and exit on EOF, so an abnormal driver exit cannot leak workers.
pub struct WorkerPool {
    workers: Vec<WorkerConn>,
    children: Mutex<Vec<Child>>,
}

impl WorkerPool {
    /// Connect to already-running workers at `addrs`.
    pub fn connect(addrs: &[String]) -> Result<WorkerPool> {
        let mut workers = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let stream = TcpStream::connect(addr)
                .map_err(|e| DdpError::format("net", format!("connect {addr}: {e}")))?;
            stream.set_nodelay(true).ok();
            workers.push(WorkerConn {
                addr: addr.clone(),
                stream: Mutex::new(Some(stream)),
                dead: AtomicBool::new(false),
            });
        }
        Ok(WorkerPool { workers, children: Mutex::new(Vec::new()) })
    }

    /// Spawn `n` local worker processes from the `ddp` binary at `bin`
    /// and connect to them. `fail_first_after`: pass `--fail-after N` to
    /// worker 0 only (the worker-loss test hook).
    pub fn spawn_local(bin: &Path, n: usize, fail_first_after: Option<u64>) -> Result<WorkerPool> {
        let mut children = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        for i in 0..n {
            let mut cmd = Command::new(bin);
            cmd.arg("worker").arg("--listen").arg("127.0.0.1:0");
            if i == 0 {
                if let Some(k) = fail_first_after {
                    cmd.arg("--fail-after").arg(k.to_string());
                }
            }
            cmd.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::inherit());
            let mut child = cmd.spawn().map_err(|e| {
                DdpError::format("net", format!("spawn worker {}: {e}", bin.display()))
            })?;
            let stdout = child.stdout.take().expect("stdout piped");
            let mut line = String::new();
            BufReader::new(stdout).read_line(&mut line)?;
            let addr = line
                .trim()
                .strip_prefix("LISTENING ")
                .ok_or_else(|| {
                    DdpError::format("net", format!("worker did not announce address: {line:?}"))
                })?
                .to_string();
            children.push(child);
            addrs.push(addr);
        }
        let mut pool = WorkerPool::connect(&addrs)?;
        pool.children = Mutex::new(children);
        Ok(pool)
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn live_workers(&self) -> usize {
        self.workers.iter().filter(|w| !w.dead.load(Ordering::SeqCst)).count()
    }

    pub fn addrs(&self) -> Vec<String> {
        self.workers.iter().map(|w| w.addr.clone()).collect()
    }

    /// One request/response on worker `w`'s connection. Any IO failure
    /// poisons the connection (a half-written frame cannot be resumed).
    fn call_once(
        &self,
        w: usize,
        opcode: u8,
        header: &Value,
        payload: &[u8],
    ) -> Result<net::Frame> {
        let mut guard = self.workers[w].stream.lock().unwrap();
        let stream = guard
            .as_mut()
            .ok_or_else(|| DdpError::format("net", "connection previously failed"))?;
        let out = net::write_frame(stream, opcode, header, payload)
            .and_then(|()| net::read_frame(stream));
        if out.is_err() {
            *guard = None;
        }
        out
    }

    /// Dispatch with failover: try the task's round-robin worker, then
    /// every other live worker. `Ok(None)` = no live workers (caller
    /// computes locally). `Err` = a worker *reported* a compute error —
    /// deterministic, so the caller re-runs locally to surface it
    /// exactly as a single-process run would.
    fn call_failover(
        &self,
        tracer: &Arc<Tracer>,
        task_idx: usize,
        opcode: u8,
        header: &Value,
        payload: &[u8],
        d: &mut DistCounters,
    ) -> Result<Option<net::Frame>> {
        let n = self.workers.len();
        let req_bytes = payload.len() as u64 + 64; // frame + header overhead, approx
        for k in 0..n {
            let w = (task_idx + k) % n;
            if self.workers[w].dead.load(Ordering::SeqCst) {
                continue;
            }
            // one span per attempt, named by worker — `stage_rollup()`
            // then attributes wall-clock to real workers, not simulated
            // lanes
            let span = tracer.begin(SpanKind::Stage, || format!("worker#{w}"), None);
            let _scope = tracer.scope(span);
            match self.call_once(w, opcode, header, payload) {
                Ok(frame) if frame.op == op::OK => {
                    d.remote += 1;
                    d.tx += req_bytes;
                    d.rx += frame.payload.len() as u64 + 64;
                    return Ok(Some(frame));
                }
                Ok(frame) => {
                    let msg = frame.header.str_or("msg", "unknown worker error");
                    return Err(DdpError::format("net", format!("worker {w}: {msg}")));
                }
                Err(_) => {
                    // connection died — declare the worker lost and fail
                    // the task over (lineage: the driver still holds the
                    // input partition)
                    if !self.workers[w].dead.swap(true, Ordering::SeqCst) {
                        d.lost += 1;
                        log::warn!("worker {} ({}) lost; failing over", w, self.workers[w].addr);
                    }
                    d.retried += 1;
                }
            }
        }
        Ok(None)
    }

    /// Remote narrow-chain execution. `Ok(None)` = run locally.
    pub(crate) fn narrow(
        &self,
        tracer: &Arc<Tracer>,
        task_idx: usize,
        rows: &[Row],
        desc: &NarrowDesc,
        d: &mut DistCounters,
    ) -> Result<Option<(Vec<Row>, u64, u64)>> {
        let blob = net::rows_to_blob(rows)?;
        let header = desc.request_header(blob.meta);
        match self.call_failover(tracer, task_idx, op::NARROW, &header, &blob.bytes, d)? {
            None => Ok(None),
            Some(frame) => {
                let data = frame
                    .header
                    .get("data")
                    .ok_or_else(|| DdpError::format("net", "narrow response missing 'data'"))?;
                let rows = net::blob_to_rows(data, &frame.payload)?;
                Ok(Some((
                    rows,
                    frame.header.u64_or("vec_batches", 0),
                    frame.header.u64_or("vec_fallbacks", 0),
                )))
            }
        }
    }

    /// Remote shuffle map side: hash-bucket `rows` into `num_parts`
    /// buckets by whole-row hash (`key_col: None`) or by a declared key
    /// column. `Ok(None)` = run locally.
    pub(crate) fn bucket(
        &self,
        tracer: &Arc<Tracer>,
        task_idx: usize,
        rows: &[Row],
        num_parts: usize,
        key_col: Option<usize>,
        d: &mut DistCounters,
    ) -> Result<Option<Vec<Vec<Row>>>> {
        let blob = net::rows_to_blob(rows)?;
        let mut pairs = vec![
            ("data", blob.meta),
            ("num_parts", Value::num(num_parts as f64)),
        ];
        if let Some(kc) = key_col {
            pairs.push(("key_col", Value::num(kc as f64)));
        }
        let header = Value::obj(pairs);
        match self.call_failover(tracer, task_idx, op::BUCKET, &header, &blob.bytes, d)? {
            None => Ok(None),
            Some(frame) => {
                let metas = frame
                    .header
                    .get("buckets")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| DdpError::format("net", "bucket response missing 'buckets'"))?;
                let buckets = net::payload_to_buckets(metas, &frame.payload)?;
                if buckets.len() != num_parts {
                    return Err(DdpError::format(
                        "net",
                        format!("worker returned {} buckets, expected {num_parts}", buckets.len()),
                    ));
                }
                Ok(Some(buckets))
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in &self.workers {
            // best-effort orderly goodbye before the kill
            if let Some(mut s) = w.stream.lock().unwrap().take() {
                let _ = net::write_frame(&mut s, op::SHUTDOWN, &Value::obj(vec![]), &[]);
                let _ = s.flush();
            }
        }
        for child in self.children.lock().unwrap().iter_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

// ---------------------------------------------------------------------
// configuration plumbing
// ---------------------------------------------------------------------

/// Locate the `ddp` binary for spawn-local workers: explicit config,
/// `DDP_WORKER_BIN`, the current executable when it *is* `ddp`, or a
/// `ddp` sibling of the current executable (covers `target/<profile>/
/// examples/<name>` via the parent directory).
pub fn resolve_worker_binary(explicit: Option<&Path>) -> Option<PathBuf> {
    if let Some(p) = explicit {
        return Some(p.to_path_buf());
    }
    if let Ok(p) = std::env::var("DDP_WORKER_BIN") {
        return Some(PathBuf::from(p));
    }
    let exe = std::env::current_exe().ok()?;
    if exe.file_stem().is_some_and(|s| s == "ddp") {
        return Some(exe);
    }
    let candidates = [
        exe.parent()?.join("ddp"),
        exe.parent()?.parent()?.join("ddp"),
    ];
    candidates.into_iter().find(|c| c.is_file())
}

/// Build (or fetch) the worker pool a config asks for. Spawned-from-env
/// pools are shared process-wide — the env is constant for the process,
/// and workers are stateless per-request, so every context in a test
/// run reuses one fleet instead of forking per context.
pub(crate) fn pool_from_config(cfg: &super::executor::EngineConfig) -> Option<Arc<WorkerPool>> {
    if !cfg.remote_workers.is_empty() {
        match WorkerPool::connect(&cfg.remote_workers) {
            Ok(p) => return Some(Arc::new(p)),
            Err(e) => {
                log::warn!("remote workers unavailable ({e}); running single-process");
                return None;
            }
        }
    }
    if cfg.spawn_workers > 0 {
        static SHARED: OnceLock<Option<Arc<WorkerPool>>> = OnceLock::new();
        return SHARED
            .get_or_init(|| {
                let bin = resolve_worker_binary(cfg.worker_binary.as_deref())?;
                match WorkerPool::spawn_local(&bin, cfg.spawn_workers, None) {
                    Ok(p) => Some(Arc::new(p)),
                    Err(e) => {
                        log::warn!("could not spawn workers ({e}); running single-process");
                        None
                    }
                }
            })
            .clone();
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::executor::Step;
    use crate::engine::expr::{BinOp, Expr};
    use crate::engine::row::Field;
    use crate::row;
    use std::sync::Arc;

    fn col(i: usize, n: &str) -> Expr {
        Expr::Col(i, n.to_string())
    }

    #[test]
    fn narrow_desc_ships_structured_chains_only() {
        let e = Expr::Binary(
            BinOp::Gt,
            Box::new(col(1, "score")),
            Box::new(Expr::Lit(Field::F64(0.5))),
        );
        let steps =
            vec![Step::FilterExpr(Arc::new(e), None), Step::Project(vec![1, 0], None)];
        assert!(NarrowDesc::try_build(&steps, true).is_some());

        let opaque = vec![Step::Map(Arc::new(|r: &crate::engine::row::Row| r.clone()))];
        assert!(NarrowDesc::try_build(&opaque, true).is_none());
        assert!(NarrowDesc::try_build(&[], true).is_none());
    }

    #[test]
    fn reference_schema_rejects_ambiguous_names() {
        // same name at two indices: compile() could not tell them apart
        let e = Expr::Binary(BinOp::And, Box::new(col(0, "x")), Box::new(col(2, "x")));
        assert!(reference_schema(&e).is_none());
        // distinct names at distinct indices: fine, gaps padded
        let e = Expr::Binary(BinOp::And, Box::new(col(0, "a")), Box::new(col(2, "b")));
        let names = reference_schema(&e).unwrap();
        assert_eq!(names.len(), 3);
        assert_eq!(names[0], "a");
        assert_eq!(names[2], "b");
    }

    #[test]
    fn in_process_worker_round_trip() {
        // a real TCP worker on a thread: narrow + bucket round trips
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let _ = serve(listener, WorkerOptions::default());
        });
        let pool = WorkerPool::connect(&[addr]).unwrap();
        let tracer = Tracer::new(false);
        let mut d = DistCounters::default();

        let e = Expr::Binary(
            BinOp::Gt,
            Box::new(col(0, "x")),
            Box::new(Expr::Lit(Field::I64(2))),
        );
        let steps = vec![Step::FilterExpr(Arc::new(e), None)];
        let desc = NarrowDesc::try_build(&steps, true).unwrap();
        let rows = vec![row!(1i64), row!(3i64), row!(5i64)];
        let (out, _, _) =
            pool.narrow(&tracer, 0, &rows, &desc, &mut d).unwrap().expect("worker alive");
        assert_eq!(out, vec![row!(3i64), row!(5i64)]);
        assert_eq!(d.remote, 1);

        let buckets = pool
            .bucket(&tracer, 1, &rows, 4, Some(0), &mut d)
            .unwrap()
            .expect("worker alive");
        assert_eq!(buckets.len(), 4);
        let mut local: Vec<Vec<crate::engine::row::Row>> = vec![Vec::new(); 4];
        for r in &rows {
            local[super::super::executor::bucket_of(r.get(0), 4)].push(r.clone());
        }
        assert_eq!(buckets, local);
    }
}
