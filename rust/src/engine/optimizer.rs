//! Rule-based logical plan optimizer.
//!
//! Runs over the [`Plan`] DAG before execution (gated by
//! [`super::executor::EngineConfig::optimize`]) and rewrites the
//! *structured* nodes — [`Plan::FilterExpr`], [`Plan::Project`], and wide
//! ops carrying key-column metadata. Closure-based nodes (`Map`, `Filter`,
//! `FlatMap`, opaque keys) are opaque and act as rewrite fences.
//!
//! Every rule preserves **byte-identical collected output** — same rows,
//! same order, same partition layout — which the differential test suite
//! (`tests/optimizer.rs`) asserts over randomly generated DAGs. That
//! constraint is why some textbook rewrites are deliberately absent:
//!
//! * projection pushdown below `Repartition`/`Distinct` would change the
//!   row-content hash that assigns bucket layout;
//! * projection pushdown below `ReduceByKey` would break the opaque
//!   reduce closure's column indices;
//! * predicate pushdown below `ReduceByKey` is only legal when the
//!   predicate touches nothing but the structured key column (the
//!   [`Dataset::reduce_by_key_col`] contract guarantees the reducer
//!   preserves it);
//! * predicate pushdown into the *right* side of a **left** join would
//!   also filter the null-extended rows, so it is restricted to inner
//!   joins (left-side predicates push into either kind).
//!
//! Rules implemented: constant folding, trivially-true filter removal,
//! adjacent filter conjunction, adjacent projection collapsing, identity
//! projection removal, predicate pushdown (below `Union`, `Repartition`,
//! `Distinct`, `Sort`, `Project` with column remapping, into `Join`
//! sides per conjunct, below column-keyed `ReduceByKey`), projection
//! pushdown (below `Union`, into both sides of a column-keyed `Join`),
//! and adjacent equal-width repartition collapsing.
//!
//! `Filter` commutes with `SortBy` because the engine's sort is *stable*
//! (the external merge sort's run-index tie-breaking reproduces a stable
//! gather-sort exactly): stably sorting a filtered subsequence yields
//! exactly the subsequence of the stably sorted whole, so filtering
//! first shrinks the sort without changing a byte of output.
//!
//! Cache-registered (persisted) datasets are rewrite barriers: rewriting
//! one would mint a new node id and detach its cache registration, so the
//! optimizer leaves those subtrees untouched.

use super::dataset::{Dataset, JoinKind, KeyFn, Plan};
use super::expr::{self, Expr};
use super::row::{Row, Schema};
use std::collections::{BTreeSet, HashMap};
use std::fmt;
use std::sync::Arc;

/// Per-rule application counts for one `optimize` call (mergeable across
/// calls; surfaced through `EngineCtx::rewrite_counts` and, in total, the
/// `plan_rewrites` engine stat).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RewriteCounts {
    pub constant_folds: u64,
    pub trivial_filters_dropped: u64,
    pub trivial_projects_dropped: u64,
    pub filters_merged: u64,
    pub projects_collapsed: u64,
    pub filter_pushdown_union: u64,
    pub filter_pushdown_repartition: u64,
    pub filter_pushdown_distinct: u64,
    pub filter_pushdown_project: u64,
    pub filter_pushdown_join: u64,
    pub filter_pushdown_reduce: u64,
    pub filter_pushdown_sort: u64,
    pub project_pushdown_union: u64,
    pub project_pushdown_join: u64,
    pub repartitions_collapsed: u64,
}

impl RewriteCounts {
    pub fn total(&self) -> u64 {
        self.constant_folds
            + self.trivial_filters_dropped
            + self.trivial_projects_dropped
            + self.filters_merged
            + self.projects_collapsed
            + self.filter_pushdown_union
            + self.filter_pushdown_repartition
            + self.filter_pushdown_distinct
            + self.filter_pushdown_project
            + self.filter_pushdown_join
            + self.filter_pushdown_reduce
            + self.filter_pushdown_sort
            + self.project_pushdown_union
            + self.project_pushdown_join
            + self.repartitions_collapsed
    }

    pub fn merge(&mut self, o: &RewriteCounts) {
        self.constant_folds += o.constant_folds;
        self.trivial_filters_dropped += o.trivial_filters_dropped;
        self.trivial_projects_dropped += o.trivial_projects_dropped;
        self.filters_merged += o.filters_merged;
        self.projects_collapsed += o.projects_collapsed;
        self.filter_pushdown_union += o.filter_pushdown_union;
        self.filter_pushdown_repartition += o.filter_pushdown_repartition;
        self.filter_pushdown_distinct += o.filter_pushdown_distinct;
        self.filter_pushdown_project += o.filter_pushdown_project;
        self.filter_pushdown_join += o.filter_pushdown_join;
        self.filter_pushdown_reduce += o.filter_pushdown_reduce;
        self.filter_pushdown_sort += o.filter_pushdown_sort;
        self.project_pushdown_union += o.project_pushdown_union;
        self.project_pushdown_join += o.project_pushdown_join;
        self.repartitions_collapsed += o.repartitions_collapsed;
    }
}

impl fmt::Display for RewriteCounts {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rewrites: {} (fold {}, drop-filter {}, drop-project {}, merge-filter {}, \
             collapse-project {}, push-filter u/r/d/p/j/k/s {}/{}/{}/{}/{}/{}/{}, \
             push-project u/j {}/{}, collapse-repartition {})",
            self.total(),
            self.constant_folds,
            self.trivial_filters_dropped,
            self.trivial_projects_dropped,
            self.filters_merged,
            self.projects_collapsed,
            self.filter_pushdown_union,
            self.filter_pushdown_repartition,
            self.filter_pushdown_distinct,
            self.filter_pushdown_project,
            self.filter_pushdown_join,
            self.filter_pushdown_reduce,
            self.filter_pushdown_sort,
            self.project_pushdown_union,
            self.project_pushdown_join,
            self.repartitions_collapsed,
        )
    }
}

/// Result of one optimizer pass.
pub struct Optimized {
    pub plan: Dataset,
    pub counts: RewriteCounts,
}

/// Optimize a plan. `is_barrier` marks node ids that must not be rewritten
/// or bypassed (the executor passes cache registration: a persisted node's
/// id is its cache key).
pub fn optimize(ds: &Dataset, is_barrier: &dyn Fn(u64) -> bool) -> Optimized {
    let mut counts = RewriteCounts::default();
    let mut memo: HashMap<u64, Dataset> = HashMap::new();
    let plan = rewrite(ds, is_barrier, &mut counts, &mut memo);
    Optimized { plan, counts }
}

/// Bottom-up rewrite with memoization over the (possibly shared) DAG.
/// Returns the ORIGINAL dataset handle when nothing changed, so unchanged
/// plans keep their node ids (and with them their cache registrations).
fn rewrite(
    ds: &Dataset,
    barrier: &dyn Fn(u64) -> bool,
    counts: &mut RewriteCounts,
    memo: &mut HashMap<u64, Dataset>,
) -> Dataset {
    if let Some(done) = memo.get(&ds.id) {
        return done.clone();
    }
    let out = if barrier(ds.id) {
        ds.clone()
    } else {
        let rebuilt = rebuild(ds, barrier, counts, memo);
        fixpoint(rebuilt, barrier, counts)
    };
    memo.insert(ds.id, out.clone());
    out
}

/// Clone the node with optimized children; keeps the original handle (and
/// id) when no child changed.
fn rebuild(
    ds: &Dataset,
    barrier: &dyn Fn(u64) -> bool,
    counts: &mut RewriteCounts,
    memo: &mut HashMap<u64, Dataset>,
) -> Dataset {
    let node = match &*ds.node {
        Plan::Source { .. } => return ds.clone(),
        Plan::Map { input, f, schema } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Map { input: ni, f: f.clone(), schema: schema.clone() }
        }
        Plan::Filter { input, f } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Filter { input: ni, f: f.clone() }
        }
        Plan::FilterExpr { input, expr } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::FilterExpr { input: ni, expr: expr.clone() }
        }
        Plan::Project { input, cols, schema } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Project { input: ni, cols: cols.clone(), schema: schema.clone() }
        }
        Plan::FlatMap { input, f, schema } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::FlatMap { input: ni, f: f.clone(), schema: schema.clone() }
        }
        Plan::MapPartitions { input, f, schema } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::MapPartitions { input: ni, f: f.clone(), schema: schema.clone() }
        }
        Plan::ReduceByKey { input, key, reduce, num_parts, key_col } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::ReduceByKey {
                input: ni,
                key: key.clone(),
                reduce: reduce.clone(),
                num_parts: *num_parts,
                key_col: *key_col,
            }
        }
        Plan::Distinct { input, num_parts } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Distinct { input: ni, num_parts: *num_parts }
        }
        Plan::Sort { input, cmp } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Sort { input: ni, cmp: cmp.clone() }
        }
        Plan::Repartition { input, num_parts } => {
            let ni = rewrite(input, barrier, counts, memo);
            if ni.id == input.id {
                return ds.clone();
            }
            Plan::Repartition { input: ni, num_parts: *num_parts }
        }
        Plan::Join { left, right, lkey, rkey, kind, num_parts, schema, lkey_col, rkey_col } => {
            let nl = rewrite(left, barrier, counts, memo);
            let nr = rewrite(right, barrier, counts, memo);
            if nl.id == left.id && nr.id == right.id {
                return ds.clone();
            }
            Plan::Join {
                left: nl,
                right: nr,
                lkey: lkey.clone(),
                rkey: rkey.clone(),
                kind: *kind,
                num_parts: *num_parts,
                schema: schema.clone(),
                lkey_col: *lkey_col,
                rkey_col: *rkey_col,
            }
        }
        Plan::Union { inputs } => {
            let nis: Vec<Dataset> = inputs
                .iter()
                .map(|i| rewrite(i, barrier, counts, memo))
                .collect();
            if nis.iter().zip(inputs.iter()).all(|(a, b)| a.id == b.id) {
                return ds.clone();
            }
            Plan::Union { inputs: nis }
        }
    };
    Dataset::with_node(node, ds.schema.clone())
}

/// Apply node-local rules until none fire (bounded — every rule strictly
/// shrinks the plan or moves a filter/projection downward, so the bound is
/// a safety net, not a correctness requirement).
///
/// When the analyzer guard is live ([`super::analyze::guard_enabled`]:
/// debug builds and `DDP_ANALYZE=1`), every rule firing is followed by a
/// schema-equivalence re-inference of the pre/post plan — a rewrite that
/// changes the inferred output schema is an engine bug and panics, so
/// every differential suite doubles as a machine-checked proof that
/// rewrites are schema-preserving.
fn fixpoint(mut cur: Dataset, barrier: &dyn Fn(u64) -> bool, counts: &mut RewriteCounts) -> Dataset {
    let guard = super::analyze::guard_enabled();
    for _ in 0..64 {
        match apply_once(&cur, barrier, counts) {
            Some(next) => {
                if guard {
                    super::analyze::assert_rewrite_preserves_schema(&cur, &next);
                }
                cur = next;
            }
            None => break,
        }
    }
    cur
}

fn filter_over(input: &Dataset, expr: Arc<Expr>) -> Dataset {
    Dataset::with_node(
        Plan::FilterExpr { input: input.clone(), expr },
        input.schema.clone(),
    )
}

/// Try each rule at this node; `Some(new)` if one fired.
fn apply_once(
    ds: &Dataset,
    barrier: &dyn Fn(u64) -> bool,
    counts: &mut RewriteCounts,
) -> Option<Dataset> {
    match &*ds.node {
        Plan::FilterExpr { input, expr } => {
            // constant folding inside the predicate
            let (folded, nfolds) = expr::fold(expr);
            if nfolds > 0 {
                counts.constant_folds += nfolds;
                return Some(filter_over(input, Arc::new(folded)));
            }
            // drop always-true filters (always-false filters are kept:
            // replacing them with an empty source would change the
            // partition layout, breaking byte-identity)
            if let Expr::Lit(v) = &**expr {
                if expr::truthy(v) {
                    counts.trivial_filters_dropped += 1;
                    return Some(input.clone());
                }
                return None;
            }
            // every rule below replaces or bypasses `input`; a persisted
            // input must keep its node id, so stop here
            if barrier(input.id) {
                return None;
            }
            match &*input.node {
                Plan::FilterExpr { input: gin, expr: ge } => {
                    counts.filters_merged += 1;
                    let merged = Expr::Binary(
                        expr::BinOp::And,
                        Box::new((**ge).clone()),
                        Box::new((**expr).clone()),
                    );
                    Some(filter_over(gin, Arc::new(merged)))
                }
                Plan::Union { inputs } => {
                    counts.filter_pushdown_union += 1;
                    let filtered: Vec<Dataset> = inputs
                        .iter()
                        .map(|i| fixpoint(filter_over(i, expr.clone()), barrier, counts))
                        .collect();
                    Some(Dataset::with_node(
                        Plan::Union { inputs: filtered },
                        ds.schema.clone(),
                    ))
                }
                Plan::Repartition { input: gin, num_parts } => {
                    counts.filter_pushdown_repartition += 1;
                    let pushed = fixpoint(filter_over(gin, expr.clone()), barrier, counts);
                    Some(Dataset::with_node(
                        Plan::Repartition { input: pushed, num_parts: *num_parts },
                        ds.schema.clone(),
                    ))
                }
                Plan::Distinct { input: gin, num_parts } => {
                    counts.filter_pushdown_distinct += 1;
                    let pushed = fixpoint(filter_over(gin, expr.clone()), barrier, counts);
                    Some(Dataset::with_node(
                        Plan::Distinct { input: pushed, num_parts: *num_parts },
                        ds.schema.clone(),
                    ))
                }
                Plan::Sort { input: gin, cmp } => {
                    // the sort is stable (external merge sort with
                    // input-order tie-breaking): sorting the filtered
                    // subsequence equals filtering the sorted whole, byte
                    // for byte — and the sort now handles fewer rows
                    counts.filter_pushdown_sort += 1;
                    let pushed = fixpoint(filter_over(gin, expr.clone()), barrier, counts);
                    Some(Dataset::with_node(
                        Plan::Sort { input: pushed, cmp: cmp.clone() },
                        ds.schema.clone(),
                    ))
                }
                Plan::Project { input: gin, cols, schema } => {
                    counts.filter_pushdown_project += 1;
                    let cols2 = cols.clone();
                    let gschema = gin.schema.clone();
                    let remapped = expr::map_cols(expr, &|i, _| {
                        let src = cols2[i];
                        (src, gschema.field(src).0.to_string())
                    });
                    let pushed = fixpoint(filter_over(gin, Arc::new(remapped)), barrier, counts);
                    Some(Dataset::with_node(
                        Plan::Project {
                            input: pushed,
                            cols: cols.clone(),
                            schema: schema.clone(),
                        },
                        ds.schema.clone(),
                    ))
                }
                Plan::ReduceByKey { input: gin, key, reduce, num_parts, key_col } => {
                    let kc = (*key_col)?;
                    let used = expr::cols_used(expr);
                    if used.is_empty() || !used.iter().all(|&i| i == kc) {
                        return None;
                    }
                    // predicate touches only the key column: groups whose
                    // key fails would be dropped whole either way, and the
                    // reduce_by_key_col contract keeps the key column
                    // intact through the fold
                    counts.filter_pushdown_reduce += 1;
                    let pushed = fixpoint(filter_over(gin, expr.clone()), barrier, counts);
                    Some(Dataset::with_node(
                        Plan::ReduceByKey {
                            input: pushed,
                            key: key.clone(),
                            reduce: reduce.clone(),
                            num_parts: *num_parts,
                            key_col: Some(kc),
                        },
                        ds.schema.clone(),
                    ))
                }
                Plan::Join {
                    left,
                    right,
                    lkey,
                    rkey,
                    kind,
                    num_parts,
                    schema,
                    lkey_col,
                    rkey_col,
                } => {
                    let lw = left.schema.len();
                    let mut lpush: Vec<Expr> = Vec::new();
                    let mut rpush: Vec<Expr> = Vec::new();
                    let mut keep: Vec<Expr> = Vec::new();
                    for c in expr::conjuncts(expr) {
                        let used = expr::cols_used(&c);
                        if used.is_empty() {
                            keep.push(c);
                        } else if used.iter().all(|&i| i < lw) {
                            // left-side predicate: legal for inner AND left
                            // joins (null-extension never changes left cols)
                            lpush.push(c);
                        } else if *kind == JoinKind::Inner && used.iter().all(|&i| i >= lw) {
                            // right-side predicate: inner joins only — in a
                            // left join it would also have to filter the
                            // null-extended rows above the join
                            let rschema = right.schema.clone();
                            rpush.push(expr::map_cols(&c, &|i, _| {
                                (i - lw, rschema.field(i - lw).0.to_string())
                            }));
                        } else {
                            keep.push(c);
                        }
                    }
                    if lpush.is_empty() && rpush.is_empty() {
                        return None;
                    }
                    counts.filter_pushdown_join += (lpush.len() + rpush.len()) as u64;
                    let nleft = if lpush.is_empty() {
                        left.clone()
                    } else {
                        fixpoint(
                            filter_over(left, Arc::new(expr::and_all(lpush))),
                            barrier,
                            counts,
                        )
                    };
                    let nright = if rpush.is_empty() {
                        right.clone()
                    } else {
                        fixpoint(
                            filter_over(right, Arc::new(expr::and_all(rpush))),
                            barrier,
                            counts,
                        )
                    };
                    let njoin = Dataset::with_node(
                        Plan::Join {
                            left: nleft,
                            right: nright,
                            lkey: lkey.clone(),
                            rkey: rkey.clone(),
                            kind: *kind,
                            num_parts: *num_parts,
                            schema: schema.clone(),
                            lkey_col: *lkey_col,
                            rkey_col: *rkey_col,
                        },
                        ds.schema.clone(),
                    );
                    Some(if keep.is_empty() {
                        njoin
                    } else {
                        filter_over(&njoin, Arc::new(expr::and_all(keep)))
                    })
                }
                _ => None,
            }
        }

        Plan::Project { input, cols, schema } => {
            // identity projection: selecting every column in order
            if cols.len() == input.schema.len()
                && cols.iter().enumerate().all(|(i, &c)| i == c)
                && schema.as_ref() == input.schema.as_ref()
            {
                counts.trivial_projects_dropped += 1;
                return Some(input.clone());
            }
            if barrier(input.id) {
                return None;
            }
            match &*input.node {
                Plan::Project { input: gin, cols: icols, .. } => {
                    counts.projects_collapsed += 1;
                    let ncols: Vec<usize> = cols.iter().map(|&j| icols[j]).collect();
                    Some(Dataset::with_node(
                        Plan::Project { input: gin.clone(), cols: ncols, schema: schema.clone() },
                        ds.schema.clone(),
                    ))
                }
                Plan::Union { inputs } => {
                    counts.project_pushdown_union += 1;
                    let projected: Vec<Dataset> = inputs
                        .iter()
                        .map(|i| {
                            let p = Dataset::with_node(
                                Plan::Project {
                                    input: i.clone(),
                                    cols: cols.clone(),
                                    schema: schema.clone(),
                                },
                                schema.clone(),
                            );
                            fixpoint(p, barrier, counts)
                        })
                        .collect();
                    Some(Dataset::with_node(
                        Plan::Union { inputs: projected },
                        ds.schema.clone(),
                    ))
                }
                Plan::Join {
                    left,
                    right,
                    lkey: _,
                    rkey: _,
                    kind,
                    num_parts,
                    schema: jschema,
                    lkey_col: Some(lk),
                    rkey_col: Some(rk),
                } => {
                    // prune join inputs to the columns the projection (plus
                    // the join keys) actually references, so the shuffle
                    // moves only referenced columns
                    let lw = left.schema.len();
                    let rw = right.schema.len();
                    let mut need: BTreeSet<usize> = cols.iter().copied().collect();
                    need.insert(*lk);
                    need.insert(lw + *rk);
                    let lkeep: Vec<usize> = (0..lw).filter(|i| need.contains(i)).collect();
                    let rkeep: Vec<usize> =
                        (0..rw).filter(|i| need.contains(&(lw + i))).collect();
                    if lkeep.len() == lw && rkeep.len() == rw {
                        return None;
                    }
                    counts.project_pushdown_join += 1;
                    let nleft = if lkeep.len() == lw {
                        left.clone()
                    } else {
                        fixpoint(left.project(lkeep.clone()), barrier, counts)
                    };
                    let nright = if rkeep.len() == rw {
                        right.clone()
                    } else {
                        fixpoint(right.project(rkeep.clone()), barrier, counts)
                    };
                    let nlk = lkeep.iter().position(|&c| c == *lk).unwrap();
                    let nrk = rkeep.iter().position(|&c| c == *rk).unwrap();
                    // pruned join keeps the caller-declared names of the
                    // surviving columns
                    let mut kept: Vec<usize> = lkeep.clone();
                    kept.extend(rkeep.iter().map(|&c| lw + c));
                    let njschema = Schema::new(
                        kept.iter().map(|&i| jschema.field(i)).collect::<Vec<_>>(),
                    );
                    let lkey2: KeyFn = Arc::new(move |r: &Row| r.get(nlk).clone());
                    let rkey2: KeyFn = Arc::new(move |r: &Row| r.get(nrk).clone());
                    let njoin = Dataset::with_node(
                        Plan::Join {
                            left: nleft,
                            right: nright,
                            lkey: lkey2,
                            rkey: rkey2,
                            kind: *kind,
                            num_parts: *num_parts,
                            schema: njschema.clone(),
                            lkey_col: Some(nlk),
                            rkey_col: Some(nrk),
                        },
                        njschema,
                    );
                    let ncols: Vec<usize> = cols
                        .iter()
                        .map(|&c| kept.iter().position(|&k| k == c).unwrap())
                        .collect();
                    Some(Dataset::with_node(
                        Plan::Project { input: njoin, cols: ncols, schema: schema.clone() },
                        ds.schema.clone(),
                    ))
                }
                _ => None,
            }
        }

        Plan::Repartition { input, num_parts } => {
            if barrier(input.id) {
                return None;
            }
            if let Plan::Repartition { input: gin, num_parts: m } = &*input.node {
                // same width twice: the second pass maps every row to the
                // bucket it is already in (content-hash partitioning), so
                // the inner shuffle is a no-op
                if *m == *num_parts {
                    counts.repartitions_collapsed += 1;
                    return Some(Dataset::with_node(
                        Plan::Repartition { input: gin.clone(), num_parts: *num_parts },
                        ds.schema.clone(),
                    ));
                }
            }
            None
        }

        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::expr::BinOp;
    use crate::engine::row::{Field, FieldType};
    use crate::row;

    fn src() -> Dataset {
        let schema = Schema::new(vec![
            ("id", FieldType::I64),
            ("grp", FieldType::I64),
            ("name", FieldType::Str),
        ]);
        let rows = (0..20)
            .map(|i| row!(i as i64, (i % 4) as i64, format!("n{i}")))
            .collect();
        Dataset::from_rows("src", schema, rows, 3)
    }

    fn gt(col: usize, name: &str, v: f64) -> Expr {
        Expr::Binary(
            BinOp::Gt,
            Box::new(Expr::Col(col, name.into())),
            Box::new(Expr::Lit(Field::F64(v))),
        )
    }

    fn no_barrier(_: u64) -> bool {
        false
    }

    #[test]
    fn unchanged_plan_keeps_ids() {
        let ds = src();
        let mapped = ds.map(ds.schema.clone(), |r| r.clone());
        let out = optimize(&mapped, &no_barrier);
        assert_eq!(out.plan.id, mapped.id);
        assert_eq!(out.counts.total(), 0);
    }

    #[test]
    fn barrier_stops_rewrites() {
        let ds = src();
        let rp = ds.repartition(2);
        let filtered = rp.filter_expr(gt(0, "id", 5.0));
        // with the repartition persisted, the filter must stay above it
        let barrier_id = rp.id;
        let out = optimize(&filtered, &|id| id == barrier_id);
        assert_eq!(out.counts.total(), 0);
        assert_eq!(out.plan.id, filtered.id);
        // without the barrier it pushes
        let out = optimize(&filtered, &no_barrier);
        assert_eq!(out.counts.filter_pushdown_repartition, 1);
    }

    #[test]
    fn shared_subtree_rewritten_once() {
        let ds = src();
        let rp = ds.repartition(2);
        let a = rp.filter_expr(gt(0, "id", 3.0));
        let b = rp.filter_expr(gt(0, "id", 7.0));
        let u = a.union(&[b]);
        let out = optimize(&u, &no_barrier);
        assert_eq!(out.counts.filter_pushdown_repartition, 2);
        // both rewritten branches still share the same source
        let inputs = out.plan.inputs();
        let src_of = |d: &Dataset| d.inputs()[0].inputs()[0].id;
        assert_eq!(src_of(&inputs[0]), src_of(&inputs[1]));
    }

    #[test]
    fn display_counts() {
        let mut a = RewriteCounts { constant_folds: 2, ..Default::default() };
        let b = RewriteCounts { filters_merged: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.total(), 3);
        let s = a.to_string();
        assert!(s.contains("rewrites: 3"), "got: {s}");
    }
}
