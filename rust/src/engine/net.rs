//! Driver ↔ worker wire protocol for distributed execution.
//!
//! One TCP connection per worker carries length-prefixed frames; row
//! payloads travel as **colbin v2 blobs encoded by the exact spill
//! code path** ([`super::spill::encode_rows_blob`]), so ship-to-peer
//! and spill-to-disk share one encoder/decoder and the network format
//! is covered by the same conformance suite as the on-disk format
//! (`docs/colbin-format.md`).
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! magic   4 bytes  "DDPW"
//! op      1 byte   (see [`op`])
//! hlen    4 bytes  u32 — length of the JSON header
//! header  hlen bytes — UTF-8 JSON ([`crate::json::Value`] object)
//! plen    8 bytes  u64 — length of the payload
//! payload plen bytes — zero or more concatenated colbin blobs
//! ```
//!
//! The header describes how to slice the payload: row blobs carry
//! `{rows, width, widths?, len}` metadata mirroring the spill file's
//! per-segment metadata (`width` rebuilds the all-`Any` spill schema,
//! `widths` restores ragged row arities after the rectangular pad).
//! Requests and responses use the same frame shape; errors travel as
//! [`op::ERR`] frames with a `msg` header field.

use super::row::Row;
use super::spill::{decode_rows_blob, encode_rows_blob};
use crate::json::{self, Value};
use crate::util::error::{DdpError, Result};
use std::io::{Read, Write};

/// Frame magic — distinct from colbin's `DDPC` so a stray colbin blob
/// (or a v1 peer) fails loudly at the frame layer, not mid-payload.
pub const MAGIC: [u8; 4] = *b"DDPW";

/// Frame opcodes.
pub mod op {
    /// liveness probe; responds [`OK`] with an empty payload
    pub const PING: u8 = 0;
    /// execute a structured narrow chain over the payload rows
    pub const NARROW: u8 = 1;
    /// hash-bucket the payload rows (shuffle map side)
    pub const BUCKET: u8 = 2;
    /// orderly worker shutdown (no response)
    pub const SHUTDOWN: u8 = 3;
    /// successful response
    pub const OK: u8 = 4;
    /// failed response; header `msg` carries the error
    pub const ERR: u8 = 5;
}

/// Frame size guard: a corrupt length prefix must fail as a structured
/// error, not an allocation of attacker-controlled size. Generous —
/// shuffle payloads are per-partition, not per-corpus.
const MAX_FRAME_BYTES: u64 = 1 << 34; // 16 GiB

/// One wire frame: opcode, JSON header, raw payload.
#[derive(Debug)]
pub struct Frame {
    pub op: u8,
    pub header: Value,
    pub payload: Vec<u8>,
}

/// Write one frame (single `write_all` per section; the caller flushes).
pub fn write_frame(w: &mut impl Write, op: u8, header: &Value, payload: &[u8]) -> Result<()> {
    let htext = json::to_string(header);
    let hbytes = htext.as_bytes();
    w.write_all(&MAGIC)?;
    w.write_all(&[op])?;
    w.write_all(&(hbytes.len() as u32).to_le_bytes())?;
    w.write_all(hbytes)?;
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; errors on bad magic, oversized sections, or EOF.
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if magic != MAGIC {
        return Err(DdpError::format(
            "net",
            format!("bad frame magic {magic:02x?} (expected {MAGIC:02x?})"),
        ));
    }
    let mut opb = [0u8; 1];
    r.read_exact(&mut opb)?;
    let mut hlen = [0u8; 4];
    r.read_exact(&mut hlen)?;
    let hlen = u32::from_le_bytes(hlen) as u64;
    if hlen > MAX_FRAME_BYTES {
        return Err(DdpError::format("net", format!("header length {hlen} exceeds frame cap")));
    }
    let mut hbytes = vec![0u8; hlen as usize];
    r.read_exact(&mut hbytes)?;
    let htext = String::from_utf8(hbytes)
        .map_err(|e| DdpError::format("net", format!("header is not UTF-8: {e}")))?;
    let header = json::parse(&htext)?;
    let mut plen = [0u8; 8];
    r.read_exact(&mut plen)?;
    let plen = u64::from_le_bytes(plen);
    if plen > MAX_FRAME_BYTES {
        return Err(DdpError::format("net", format!("payload length {plen} exceeds frame cap")));
    }
    let mut payload = vec![0u8; plen as usize];
    r.read_exact(&mut payload)?;
    Ok(Frame { op: opb[0], header, payload })
}

/// A rows payload plus the JSON metadata needed to decode it — the
/// network twin of the spill file's `SegmentMeta`.
pub struct RowsBlob {
    pub bytes: Vec<u8>,
    pub meta: Value,
}

/// Encode rows through the spill encoder (rectangular pad + recorded
/// widths for ragged buckets — identical bytes to a spilled bucket).
pub fn rows_to_blob(rows: &[Row]) -> Result<RowsBlob> {
    let (bytes, width, widths) = encode_rows_blob(rows)?;
    let mut pairs = vec![
        ("rows", Value::num(rows.len() as f64)),
        ("width", Value::num(width as f64)),
        ("len", Value::num(bytes.len() as f64)),
    ];
    if let Some(ws) = &widths {
        pairs.push(("widths", Value::Arr(ws.iter().map(|w| Value::num(*w as f64)).collect())));
    }
    Ok(RowsBlob { bytes, meta: Value::obj(pairs) })
}

/// Decode a rows payload slice against its metadata object.
pub fn blob_to_rows(meta: &Value, bytes: &[u8]) -> Result<Vec<Row>> {
    let nrows = meta.u64_or("rows", 0);
    if nrows == 0 {
        return Ok(Vec::new());
    }
    let width = meta.u64_or("width", 0) as usize;
    let widths: Option<Vec<u32>> = meta.get("widths").and_then(|v| v.as_arr()).map(|arr| {
        arr.iter().map(|w| w.as_u64().unwrap_or(0) as u32).collect()
    });
    decode_rows_blob(bytes, width, widths.as_deref())
}

/// Slice a multi-blob payload into per-bucket row vectors using the
/// response's `buckets` metadata array (mirrors a spill file: blobs
/// concatenated back-to-back, lengths in the metadata).
pub fn payload_to_buckets(metas: &[Value], payload: &[u8]) -> Result<Vec<Vec<Row>>> {
    let mut out = Vec::with_capacity(metas.len());
    let mut off = 0usize;
    for meta in metas {
        let len = meta.u64_or("len", 0) as usize;
        let end = off.checked_add(len).filter(|&e| e <= payload.len()).ok_or_else(|| {
            DdpError::format(
                "net",
                format!("bucket extent [{off}..{off}+{len}) exceeds payload {}", payload.len()),
            )
        })?;
        out.push(blob_to_rows(meta, &payload[off..end])?);
        off = end;
    }
    Ok(out)
}

/// Encode buckets as concatenated blobs plus their metadata array.
pub fn buckets_to_payload(buckets: &[Vec<Row>]) -> Result<(Vec<Value>, Vec<u8>)> {
    let mut metas = Vec::with_capacity(buckets.len());
    let mut payload = Vec::new();
    for bucket in buckets {
        // empty buckets travel as metadata only (rows=0, len=0): colbin
        // needs a width to write a header, and nothing needs reading back
        if bucket.is_empty() {
            metas.push(Value::obj(vec![
                ("rows", Value::num(0.0)),
                ("width", Value::num(0.0)),
                ("len", Value::num(0.0)),
            ]));
            continue;
        }
        let blob = rows_to_blob(bucket)?;
        metas.push(blob.meta);
        payload.extend_from_slice(&blob.bytes);
    }
    Ok((metas, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::Field;
    use crate::row;

    #[test]
    fn frame_round_trip() {
        let header = Value::obj(vec![("k", Value::str("v")), ("n", Value::num(7.0))]);
        let mut buf = Vec::new();
        write_frame(&mut buf, op::NARROW, &header, b"payload").unwrap();
        let f = read_frame(&mut &buf[..]).unwrap();
        assert_eq!(f.op, op::NARROW);
        assert_eq!(f.header, header);
        assert_eq!(f.payload, b"payload");
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, op::PING, &Value::obj(vec![]), b"").unwrap();
        buf[0] = b'X';
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn rows_blob_round_trip_including_ragged() {
        let rows = vec![
            row!(1i64, "a"),
            row!(2i64),                      // ragged: shorter row
            row!(3i64, "c", Field::Null),    // ragged with trailing real null
        ];
        let blob = rows_to_blob(&rows).unwrap();
        let back = blob_to_rows(&blob.meta, &blob.bytes).unwrap();
        assert_eq!(back, rows);
    }

    #[test]
    fn bucket_payload_round_trip_with_empty_buckets() {
        let buckets = vec![vec![row!(1i64)], vec![], vec![row!("x", 2.5f64)]];
        let (metas, payload) = buckets_to_payload(&buckets).unwrap();
        let back = payload_to_buckets(&metas, &payload).unwrap();
        assert_eq!(back, buckets);
    }
}
