//! Cache manager — the engine half of the paper's *explicit state
//! management* (§3.2): pipes selectively `persist` intermediate datasets so
//! shared lineage (`C → D` and `C → E`) is computed once, and *register
//! cleanup* so cached state is dropped deterministically when a pipe
//! completes ("like the `delete` clause in C++").

use super::dataset::Partitioned;
use super::memory::{MemoryGovernor, MemoryReservation};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Per-entry bookkeeping.
struct Entry {
    data: Partitioned,
    bytes: usize,
    hits: u64,
    /// governor reservation backing this entry; released on drop, so
    /// eviction/unpersist/clear automatically return the bytes
    _res: Option<MemoryReservation>,
}

/// Thread-safe cache keyed by plan-node id, with a byte budget and
/// LRU-ish eviction (least-hit entry evicted first; good enough for
/// pipeline-shaped reuse). Entries also reserve from the engine's shared
/// [`MemoryGovernor`]: cached datasets and in-flight shuffle state
/// compete for one budget, and an entry that can't get a reservation
/// (even after evicting colder entries) is simply not cached — caching
/// is an optimization, never a correctness requirement.
pub struct CacheManager {
    inner: Mutex<CacheInner>,
    governor: Arc<MemoryGovernor>,
}

struct CacheInner {
    registered: HashMap<u64, bool>, // id -> currently wanted
    entries: HashMap<u64, Entry>,
    budget_bytes: usize,
    used_bytes: usize,
    evictions: u64,
    hits_total: u64,
}

impl CacheManager {
    pub fn new(budget_bytes: usize) -> Self {
        CacheManager::with_governor(budget_bytes, Arc::new(MemoryGovernor::unbounded()))
    }

    /// Cache sharing the engine's memory budget with shuffle/stream state.
    pub fn with_governor(budget_bytes: usize, governor: Arc<MemoryGovernor>) -> Self {
        CacheManager {
            inner: Mutex::new(CacheInner {
                registered: HashMap::new(),
                entries: HashMap::new(),
                budget_bytes,
                used_bytes: 0,
                evictions: 0,
                hits_total: 0,
            }),
            governor,
        }
    }

    /// Mark a dataset as cache-worthy. The executor stores its partitions
    /// after the next materialization.
    pub fn register(&self, id: u64) {
        self.inner.lock().unwrap().registered.insert(id, true);
    }

    pub fn is_registered(&self, id: u64) -> bool {
        *self
            .inner
            .lock()
            .unwrap()
            .registered
            .get(&id)
            .unwrap_or(&false)
    }

    /// Explicit cleanup: drop the cached data and the registration.
    pub fn unpersist(&self, id: u64) {
        let mut g = self.inner.lock().unwrap();
        g.registered.remove(&id);
        if let Some(e) = g.entries.remove(&id) {
            g.used_bytes -= e.bytes;
        }
    }

    /// Drop everything (end of pipeline run).
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.registered.clear();
        g.entries.clear();
        g.used_bytes = 0;
    }

    pub fn get(&self, id: u64) -> Option<Partitioned> {
        let mut g = self.inner.lock().unwrap();
        match g.entries.get_mut(&id) {
            Some(e) => {
                e.hits += 1;
                let data = e.data.clone();
                g.hits_total += 1;
                Some(data)
            }
            None => None,
        }
    }

    /// Insert a materialized dataset, evicting least-used entries if the
    /// budget would be exceeded. Entries larger than the whole budget are
    /// not cached (unbounded inputs must not pin memory — §3.2), and an
    /// entry the shared governor refuses (even with the cache emptied)
    /// is skipped rather than forced in.
    pub fn put(&self, id: u64, data: Partitioned) {
        let bytes = data.approx_bytes();
        let mut g = self.inner.lock().unwrap();
        if bytes > g.budget_bytes {
            return;
        }
        // re-caching an id must release the old entry's accounting first,
        // or the replaced bytes would be charged forever
        if let Some(old) = g.entries.remove(&id) {
            g.used_bytes -= old.bytes;
        }
        let res = loop {
            if g.used_bytes + bytes <= g.budget_bytes {
                if let Some(res) = MemoryGovernor::try_reserve(&self.governor, bytes) {
                    break res;
                }
                // governor refused: evicting own entries can free at most
                // `used_bytes` of governor budget. If even that plus the
                // governor's current headroom can't fit the entry, the
                // pressure is external (in-flight shuffle/stream state) —
                // give up now instead of pointlessly wiping the cache
                let gov_free = self
                    .governor
                    .budget_bytes()
                    .map(|b| b.saturating_sub(self.governor.reserved_bytes()))
                    .unwrap_or(usize::MAX);
                if bytes > g.used_bytes.saturating_add(gov_free) {
                    return;
                }
            }
            // evict the least-hit entry to make room (own budget or the
            // shared governor budget — either pressure frees real bytes)
            let victim = g
                .entries
                .iter()
                .min_by_key(|(_, e)| e.hits)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    if let Some(e) = g.entries.remove(&k) {
                        g.used_bytes -= e.bytes;
                        g.evictions += 1;
                    }
                }
                // nothing left to evict and still no room: don't cache
                None => return,
            }
        };
        g.used_bytes += bytes;
        g.entries.insert(id, Entry { data, bytes, hits: 0, _res: Some(res) });
    }

    pub fn used_bytes(&self) -> usize {
        self.inner.lock().unwrap().used_bytes
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }

    /// Total entry-level hits over the cache's lifetime.
    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::Schema;
    use crate::row;
    use std::sync::Arc;

    fn pd(n: usize) -> Partitioned {
        Partitioned {
            schema: Schema::of_names(&["x"]),
            parts: vec![Arc::new((0..n).map(|i| row!(i as i64)).collect())],
        }
    }

    #[test]
    fn register_put_get_unpersist() {
        let c = CacheManager::new(1 << 20);
        c.register(1);
        assert!(c.is_registered(1));
        assert!(c.get(1).is_none());
        c.put(1, pd(10));
        assert_eq!(c.get(1).unwrap().num_rows(), 10);
        c.unpersist(1);
        assert!(c.get(1).is_none());
        assert!(!c.is_registered(1));
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn eviction_respects_budget() {
        let one = pd(100).approx_bytes();
        let c = CacheManager::new(one * 2 + 10);
        c.put(1, pd(100));
        c.put(2, pd(100));
        // access 2 so 1 is the cold victim
        let _ = c.get(2);
        c.put(3, pd(100));
        assert!(c.get(1).is_none(), "cold entry should be evicted");
        assert!(c.get(2).is_some());
        assert!(c.get(3).is_some());
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn oversized_entry_not_cached() {
        let c = CacheManager::new(8);
        c.put(1, pd(1000));
        assert!(c.get(1).is_none());
    }

    #[test]
    fn clear_drops_all() {
        let c = CacheManager::new(1 << 20);
        c.register(1);
        c.put(1, pd(5));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn byte_pressure_evicts_least_hit_first() {
        let one = pd(100).approx_bytes();
        let c = CacheManager::new(one * 3 + 10);
        c.put(1, pd(100));
        c.put(2, pd(100));
        c.put(3, pd(100));
        // heat 1 twice, 3 once; 2 stays cold
        let _ = c.get(1);
        let _ = c.get(1);
        let _ = c.get(3);
        assert_eq!(c.hits(), 3);
        c.put(4, pd(100));
        assert!(c.get(2).is_none(), "coldest entry evicted first");
        assert!(c.get(1).is_some() && c.get(3).is_some() && c.get(4).is_some());
        // keep 1 the hottest and apply two more rounds of pressure: the
        // newcomers churn, the hot entry stays resident
        let _ = c.get(3);
        let _ = c.get(4);
        c.put(5, pd(100));
        c.put(6, pd(100));
        assert!(c.get(1).is_some(), "hottest entry survives repeated pressure");
    }

    #[test]
    fn recached_entry_keeps_byte_accounting_exact() {
        let c = CacheManager::new(1 << 20);
        c.register(1);
        c.put(1, pd(100));
        let after_first = c.used_bytes();
        assert!(after_first > 0);
        // re-caching the same id must not double-charge
        c.put(1, pd(100));
        assert_eq!(c.used_bytes(), after_first);
        assert_eq!(c.len(), 1);
        // replacing with a smaller payload shrinks the account
        c.put(1, pd(10));
        let after_small = c.used_bytes();
        assert!(after_small < after_first);
        assert_eq!(after_small, pd(10).approx_bytes());
        c.unpersist(1);
        assert_eq!(c.used_bytes(), 0);
        assert_eq!(c.evictions(), 0, "replacement is not an eviction");
    }

    #[test]
    fn shared_governor_budget_bounds_cache() {
        use crate::engine::memory::MemoryGovernor;
        let one = pd(100).approx_bytes();
        // cache's own budget is generous; the shared governor is the
        // binding constraint
        let gov = Arc::new(MemoryGovernor::new(Some(one * 2 + 10)));
        let c = CacheManager::with_governor(1 << 20, gov.clone());
        c.put(1, pd(100));
        c.put(2, pd(100));
        assert_eq!(gov.reserved_bytes(), one * 2);
        // governor pressure forces an eviction
        c.put(3, pd(100));
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        assert_eq!(gov.reserved_bytes(), one * 2);
        c.clear();
        assert_eq!(gov.reserved_bytes(), 0, "clear releases every reservation");
        // an outside holder (shuffle state) owns nearly the whole budget:
        // with nothing left to evict, the entry is skipped, not forced in
        let outside = MemoryGovernor::try_reserve(&gov, one * 2).unwrap();
        c.put(4, pd(100));
        assert!(c.get(4).is_none(), "refused entry is not cached");
        drop(outside);
        c.put(4, pd(100));
        assert!(c.get(4).is_some(), "cache works again once the budget frees");
        // external pressure that eviction can't possibly relieve must not
        // wipe resident entries one by one on the way to failing anyway
        let hog = MemoryGovernor::try_reserve(&gov, one + 10).unwrap();
        let evictions_before = c.evictions();
        c.put(5, pd(200)); // needs 2*one; cache holds one, governor has 0 free
        assert!(c.get(5).is_none());
        assert!(c.get(4).is_some(), "futile insert must not evict resident entries");
        assert_eq!(c.evictions(), evictions_before);
        drop(hog);
    }

    #[test]
    fn unpersist_releases_governor_bytes() {
        use crate::engine::memory::MemoryGovernor;
        let gov = Arc::new(MemoryGovernor::new(Some(1 << 20)));
        let c = CacheManager::with_governor(1 << 20, gov.clone());
        c.register(1);
        c.put(1, pd(50));
        assert!(gov.reserved_bytes() > 0);
        c.unpersist(1);
        assert_eq!(gov.reserved_bytes(), 0);
    }

    #[test]
    fn evictions_counter_is_exact() {
        let one = pd(100).approx_bytes();
        let c = CacheManager::new(one * 2 + 10);
        c.put(1, pd(100));
        c.put(2, pd(100));
        assert_eq!(c.evictions(), 0);
        c.put(3, pd(100)); // evicts exactly one
        assert_eq!(c.evictions(), 1);
        c.put(4, pd(150)); // larger entry displaces both residents
        assert_eq!(c.evictions(), 3);
        assert_eq!(c.len(), 1);
        // oversized and replacement paths never count as evictions
        c.put(5, pd(10_000));
        c.put(4, pd(150));
        assert_eq!(c.evictions(), 3);
    }
}
