//! Cache manager — the engine half of the paper's *explicit state
//! management* (§3.2): pipes selectively `persist` intermediate datasets so
//! shared lineage (`C → D` and `C → E`) is computed once, and *register
//! cleanup* so cached state is dropped deterministically when a pipe
//! completes ("like the `delete` clause in C++").

use super::dataset::Partitioned;
use std::collections::HashMap;
use std::sync::Mutex;

/// Per-entry bookkeeping.
struct Entry {
    data: Partitioned,
    bytes: usize,
    hits: u64,
}

/// Thread-safe cache keyed by plan-node id, with a byte budget and
/// LRU-ish eviction (least-hit entry evicted first; good enough for
/// pipeline-shaped reuse).
pub struct CacheManager {
    inner: Mutex<CacheInner>,
}

struct CacheInner {
    registered: HashMap<u64, bool>, // id -> currently wanted
    entries: HashMap<u64, Entry>,
    budget_bytes: usize,
    used_bytes: usize,
    evictions: u64,
}

impl CacheManager {
    pub fn new(budget_bytes: usize) -> Self {
        CacheManager {
            inner: Mutex::new(CacheInner {
                registered: HashMap::new(),
                entries: HashMap::new(),
                budget_bytes,
                used_bytes: 0,
                evictions: 0,
            }),
        }
    }

    /// Mark a dataset as cache-worthy. The executor stores its partitions
    /// after the next materialization.
    pub fn register(&self, id: u64) {
        self.inner.lock().unwrap().registered.insert(id, true);
    }

    pub fn is_registered(&self, id: u64) -> bool {
        *self
            .inner
            .lock()
            .unwrap()
            .registered
            .get(&id)
            .unwrap_or(&false)
    }

    /// Explicit cleanup: drop the cached data and the registration.
    pub fn unpersist(&self, id: u64) {
        let mut g = self.inner.lock().unwrap();
        g.registered.remove(&id);
        if let Some(e) = g.entries.remove(&id) {
            g.used_bytes -= e.bytes;
        }
    }

    /// Drop everything (end of pipeline run).
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.registered.clear();
        g.entries.clear();
        g.used_bytes = 0;
    }

    pub fn get(&self, id: u64) -> Option<Partitioned> {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.entries.get_mut(&id) {
            e.hits += 1;
            Some(e.data.clone())
        } else {
            None
        }
    }

    /// Insert a materialized dataset, evicting least-used entries if the
    /// budget would be exceeded. Entries larger than the whole budget are
    /// not cached (unbounded inputs must not pin memory — §3.2).
    pub fn put(&self, id: u64, data: Partitioned) {
        let bytes = data.approx_bytes();
        let mut g = self.inner.lock().unwrap();
        if bytes > g.budget_bytes {
            return;
        }
        while g.used_bytes + bytes > g.budget_bytes {
            // evict the least-hit entry
            let victim = g
                .entries
                .iter()
                .min_by_key(|(_, e)| e.hits)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    if let Some(e) = g.entries.remove(&k) {
                        g.used_bytes -= e.bytes;
                        g.evictions += 1;
                    }
                }
                None => break,
            }
        }
        g.used_bytes += bytes;
        g.entries.insert(id, Entry { data, bytes, hits: 0 });
    }

    pub fn used_bytes(&self) -> usize {
        self.inner.lock().unwrap().used_bytes
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::Schema;
    use crate::row;
    use std::sync::Arc;

    fn pd(n: usize) -> Partitioned {
        Partitioned {
            schema: Schema::of_names(&["x"]),
            parts: vec![Arc::new((0..n).map(|i| row!(i as i64)).collect())],
        }
    }

    #[test]
    fn register_put_get_unpersist() {
        let c = CacheManager::new(1 << 20);
        c.register(1);
        assert!(c.is_registered(1));
        assert!(c.get(1).is_none());
        c.put(1, pd(10));
        assert_eq!(c.get(1).unwrap().num_rows(), 10);
        c.unpersist(1);
        assert!(c.get(1).is_none());
        assert!(!c.is_registered(1));
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn eviction_respects_budget() {
        let one = pd(100).approx_bytes();
        let c = CacheManager::new(one * 2 + 10);
        c.put(1, pd(100));
        c.put(2, pd(100));
        // access 2 so 1 is the cold victim
        let _ = c.get(2);
        c.put(3, pd(100));
        assert!(c.get(1).is_none(), "cold entry should be evicted");
        assert!(c.get(2).is_some());
        assert!(c.get(3).is_some());
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn oversized_entry_not_cached() {
        let c = CacheManager::new(8);
        c.put(1, pd(1000));
        assert!(c.get(1).is_none());
    }

    #[test]
    fn clear_drops_all() {
        let c = CacheManager::new(1 << 20);
        c.register(1);
        c.put(1, pd(5));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
    }
}
