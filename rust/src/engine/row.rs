//! Row / field / schema model for the dataflow engine.
//!
//! A [`Row`] is a flat vector of [`Field`]s positioned by a shared
//! [`Schema`] (names → indices), mirroring Spark's `Row` + `StructType`.
//! Fields are hashable (f64 via bit pattern) so any field can be a shuffle
//! or join key.

use crate::util::error::{DdpError, Result};
use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

/// A single cell value.
#[derive(Debug, Clone, PartialEq)]
pub enum Field {
    Null,
    Bool(bool),
    I64(i64),
    F64(f64),
    Str(String),
    Bytes(Vec<u8>),
}

impl Field {
    pub fn type_name(&self) -> &'static str {
        match self {
            Field::Null => "null",
            Field::Bool(_) => "bool",
            Field::I64(_) => "i64",
            Field::F64(_) => "f64",
            Field::Str(_) => "str",
            Field::Bytes(_) => "bytes",
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Field::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Field::I64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Field::F64(v) => Some(*v),
            Field::I64(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Field::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_bytes(&self) -> Option<&[u8]> {
        match self {
            Field::Bytes(b) => Some(b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Field::Null)
    }

    /// Total order over all field values: type tag first (null < bool <
    /// i64 < f64 < str < bytes), then value; f64 by IEEE total order. Used
    /// by the executor to emit shuffle-reduce output in a canonical,
    /// hash-map-independent order.
    pub fn canonical_cmp(&self, other: &Field) -> std::cmp::Ordering {
        fn tag(f: &Field) -> u8 {
            match f {
                Field::Null => 0,
                Field::Bool(_) => 1,
                Field::I64(_) => 2,
                Field::F64(_) => 3,
                Field::Str(_) => 4,
                Field::Bytes(_) => 5,
            }
        }
        match (self, other) {
            (Field::Bool(a), Field::Bool(b)) => a.cmp(b),
            (Field::I64(a), Field::I64(b)) => a.cmp(b),
            (Field::F64(a), Field::F64(b)) => a.total_cmp(b),
            (Field::Str(a), Field::Str(b)) => a.cmp(b),
            (Field::Bytes(a), Field::Bytes(b)) => a.cmp(b),
            _ => tag(self).cmp(&tag(other)),
        }
    }

    /// Approximate in-memory size in bytes (used by cache accounting and
    /// the cluster simulator's shuffle-byte model).
    pub fn approx_size(&self) -> usize {
        match self {
            Field::Null => 1,
            Field::Bool(_) => 1,
            Field::I64(_) | Field::F64(_) => 8,
            Field::Str(s) => 24 + s.len(),
            Field::Bytes(b) => 24 + b.len(),
        }
    }
}

impl Eq for Field {}

impl Hash for Field {
    fn hash<H: Hasher>(&self, state: &mut H) {
        match self {
            Field::Null => 0u8.hash(state),
            Field::Bool(b) => {
                1u8.hash(state);
                b.hash(state);
            }
            Field::I64(v) => {
                2u8.hash(state);
                v.hash(state);
            }
            Field::F64(v) => {
                3u8.hash(state);
                v.to_bits().hash(state);
            }
            Field::Str(s) => {
                4u8.hash(state);
                s.hash(state);
            }
            Field::Bytes(b) => {
                5u8.hash(state);
                b.hash(state);
            }
        }
    }
}

impl fmt::Display for Field {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Field::Null => write!(f, "null"),
            Field::Bool(b) => write!(f, "{b}"),
            Field::I64(v) => write!(f, "{v}"),
            Field::F64(v) => write!(f, "{v}"),
            Field::Str(s) => write!(f, "{s}"),
            Field::Bytes(b) => write!(f, "<{} bytes>", b.len()),
        }
    }
}

impl From<&str> for Field {
    fn from(s: &str) -> Self {
        Field::Str(s.to_string())
    }
}
impl From<String> for Field {
    fn from(s: String) -> Self {
        Field::Str(s)
    }
}
impl From<i64> for Field {
    fn from(v: i64) -> Self {
        Field::I64(v)
    }
}
impl From<f64> for Field {
    fn from(v: f64) -> Self {
        Field::F64(v)
    }
}
impl From<bool> for Field {
    fn from(v: bool) -> Self {
        Field::Bool(v)
    }
}

/// Column types for schema validation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FieldType {
    Any,
    Bool,
    I64,
    F64,
    Str,
    Bytes,
}

impl FieldType {
    pub fn matches(&self, f: &Field) -> bool {
        matches!(
            (self, f),
            (FieldType::Any, _)
                | (_, Field::Null)
                | (FieldType::Bool, Field::Bool(_))
                | (FieldType::I64, Field::I64(_))
                | (FieldType::F64, Field::F64(_))
                | (FieldType::Str, Field::Str(_))
                | (FieldType::Bytes, Field::Bytes(_))
        )
    }

    pub fn parse(name: &str) -> Result<FieldType> {
        Ok(match name {
            "any" => FieldType::Any,
            "bool" => FieldType::Bool,
            "i64" | "int" | "long" => FieldType::I64,
            "f64" | "float" | "double" => FieldType::F64,
            "str" | "string" => FieldType::Str,
            "bytes" | "binary" => FieldType::Bytes,
            other => return Err(DdpError::schema(format!("unknown field type '{other}'"))),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FieldType::Any => "any",
            FieldType::Bool => "bool",
            FieldType::I64 => "i64",
            FieldType::F64 => "f64",
            FieldType::Str => "str",
            FieldType::Bytes => "bytes",
        }
    }
}

/// Ordered, named, typed column list. Shared via `Arc`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schema {
    fields: Vec<(String, FieldType)>,
    index: HashMap<String, usize>,
}

pub type SchemaRef = Arc<Schema>;

impl Schema {
    pub fn new(fields: Vec<(&str, FieldType)>) -> SchemaRef {
        let fields: Vec<(String, FieldType)> =
            fields.into_iter().map(|(n, t)| (n.to_string(), t)).collect();
        let index = fields
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.clone(), i))
            .collect();
        Arc::new(Schema { fields, index })
    }

    pub fn of_names(names: &[&str]) -> SchemaRef {
        Schema::new(names.iter().map(|n| (*n, FieldType::Any)).collect())
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn idx(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    pub fn names(&self) -> Vec<&str> {
        self.fields.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn field_type(&self, i: usize) -> FieldType {
        self.fields[i].1
    }

    pub fn field(&self, i: usize) -> (&str, FieldType) {
        (self.fields[i].0.as_str(), self.fields[i].1)
    }

    /// Check a row conforms (arity + types).
    pub fn validate_row(&self, row: &Row) -> Result<()> {
        if row.fields.len() != self.fields.len() {
            return Err(DdpError::schema(format!(
                "arity mismatch: row has {} fields, schema has {}",
                row.fields.len(),
                self.fields.len()
            )));
        }
        for (i, f) in row.fields.iter().enumerate() {
            if !self.fields[i].1.matches(f) {
                return Err(DdpError::schema(format!(
                    "field '{}' expected {}, got {}",
                    self.fields[i].0,
                    self.fields[i].1.name(),
                    f.type_name()
                )));
            }
        }
        Ok(())
    }
}

/// A data record: positional fields interpreted through a schema.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Row {
    pub fields: Vec<Field>,
}

impl Row {
    pub fn new(fields: Vec<Field>) -> Row {
        Row { fields }
    }

    pub fn get(&self, i: usize) -> &Field {
        &self.fields[i]
    }

    /// Field lookup by name through a schema.
    pub fn col<'a>(&'a self, schema: &Schema, name: &str) -> Option<&'a Field> {
        schema.idx(name).map(|i| &self.fields[i])
    }

    pub fn str_col(&self, schema: &Schema, name: &str) -> Option<&str> {
        self.col(schema, name).and_then(|f| f.as_str())
    }

    pub fn i64_col(&self, schema: &Schema, name: &str) -> Option<i64> {
        self.col(schema, name).and_then(|f| f.as_i64())
    }

    pub fn f64_col(&self, schema: &Schema, name: &str) -> Option<f64> {
        self.col(schema, name).and_then(|f| f.as_f64())
    }

    pub fn approx_size(&self) -> usize {
        16 + self.fields.iter().map(|f| f.approx_size()).sum::<usize>()
    }
}

#[macro_export]
macro_rules! row {
    ($($v:expr),* $(,)?) => {
        $crate::engine::row::Row::new(vec![$($crate::engine::row::Field::from($v)),*])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_lookup() {
        let s = Schema::new(vec![("id", FieldType::I64), ("text", FieldType::Str)]);
        assert_eq!(s.idx("id"), Some(0));
        assert_eq!(s.idx("text"), Some(1));
        assert_eq!(s.idx("nope"), None);
        assert_eq!(s.names(), vec!["id", "text"]);
    }

    #[test]
    fn row_macro_and_access() {
        let s = Schema::new(vec![("id", FieldType::I64), ("text", FieldType::Str)]);
        let r = row!(7i64, "hello");
        assert_eq!(r.i64_col(&s, "id"), Some(7));
        assert_eq!(r.str_col(&s, "text"), Some("hello"));
        s.validate_row(&r).unwrap();
    }

    #[test]
    fn validation_catches_type_errors() {
        let s = Schema::new(vec![("id", FieldType::I64)]);
        assert!(s.validate_row(&row!("not an int")).is_err());
        assert!(s.validate_row(&row!(1i64, 2i64)).is_err());
        // nulls always pass
        assert!(s.validate_row(&Row::new(vec![Field::Null])).is_ok());
    }

    #[test]
    fn field_hash_f64_bits() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(Field::F64(1.0));
        assert!(set.contains(&Field::F64(1.0)));
        assert!(!set.contains(&Field::F64(2.0)));
    }

    #[test]
    fn canonical_cmp_total_order() {
        use std::cmp::Ordering;
        assert_eq!(Field::Null.canonical_cmp(&Field::Bool(false)), Ordering::Less);
        assert_eq!(Field::I64(2).canonical_cmp(&Field::I64(10)), Ordering::Less);
        assert_eq!(Field::Str("a".into()).canonical_cmp(&Field::Str("b".into())), Ordering::Less);
        // mixed numeric types order by tag, not value — canonical, not SQL
        assert_eq!(Field::I64(9).canonical_cmp(&Field::F64(1.0)), Ordering::Less);
        // NaN is ordered (IEEE total order), so sorts are never ambiguous
        assert_eq!(Field::F64(f64::NAN).canonical_cmp(&Field::F64(f64::NAN)), Ordering::Equal);
    }

    #[test]
    fn approx_sizes() {
        assert_eq!(Field::I64(1).approx_size(), 8);
        assert!(Field::Str("abc".into()).approx_size() > 3);
        let r = row!(1i64, "abc");
        assert!(r.approx_size() > 16);
    }
}
