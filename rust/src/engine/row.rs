//! Row / field / schema model for the dataflow engine.
//!
//! A [`Row`] is a flat vector of [`Field`]s positioned by a shared
//! [`Schema`] (names → indices), mirroring Spark's `Row` + `StructType`.
//! Fields are hashable (f64 via bit pattern) so any field can be a shuffle
//! or join key.

use crate::util::error::{DdpError, Result};
use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

/// A single cell value.
#[derive(Debug, Clone, PartialEq)]
pub enum Field {
    Null,
    Bool(bool),
    I64(i64),
    F64(f64),
    Str(String),
    Bytes(Vec<u8>),
}

impl Field {
    pub fn type_name(&self) -> &'static str {
        match self {
            Field::Null => "null",
            Field::Bool(_) => "bool",
            Field::I64(_) => "i64",
            Field::F64(_) => "f64",
            Field::Str(_) => "str",
            Field::Bytes(_) => "bytes",
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Field::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Field::I64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Field::F64(v) => Some(*v),
            Field::I64(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Field::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_bytes(&self) -> Option<&[u8]> {
        match self {
            Field::Bytes(b) => Some(b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Field::Null)
    }

    /// Total order over all field values: type tag first (null < bool <
    /// i64 < f64 < str < bytes), then value; f64 by IEEE total order. Used
    /// by the executor to emit shuffle-reduce output in a canonical,
    /// hash-map-independent order.
    pub fn canonical_cmp(&self, other: &Field) -> std::cmp::Ordering {
        fn tag(f: &Field) -> u8 {
            match f {
                Field::Null => 0,
                Field::Bool(_) => 1,
                Field::I64(_) => 2,
                Field::F64(_) => 3,
                Field::Str(_) => 4,
                Field::Bytes(_) => 5,
            }
        }
        match (self, other) {
            (Field::Bool(a), Field::Bool(b)) => a.cmp(b),
            (Field::I64(a), Field::I64(b)) => a.cmp(b),
            (Field::F64(a), Field::F64(b)) => a.total_cmp(b),
            (Field::Str(a), Field::Str(b)) => a.cmp(b),
            (Field::Bytes(a), Field::Bytes(b)) => a.cmp(b),
            _ => tag(self).cmp(&tag(other)),
        }
    }

    /// Approximate in-memory size in bytes (used by cache accounting and
    /// the cluster simulator's shuffle-byte model).
    pub fn approx_size(&self) -> usize {
        match self {
            Field::Null => 1,
            Field::Bool(_) => 1,
            Field::I64(_) | Field::F64(_) => 8,
            Field::Str(s) => 24 + s.len(),
            Field::Bytes(b) => 24 + b.len(),
        }
    }
}

impl Eq for Field {}

impl Hash for Field {
    fn hash<H: Hasher>(&self, state: &mut H) {
        match self {
            Field::Null => 0u8.hash(state),
            Field::Bool(b) => {
                1u8.hash(state);
                b.hash(state);
            }
            Field::I64(v) => {
                2u8.hash(state);
                v.hash(state);
            }
            Field::F64(v) => {
                3u8.hash(state);
                v.to_bits().hash(state);
            }
            Field::Str(s) => {
                4u8.hash(state);
                s.hash(state);
            }
            Field::Bytes(b) => {
                5u8.hash(state);
                b.hash(state);
            }
        }
    }
}

impl fmt::Display for Field {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Field::Null => write!(f, "null"),
            Field::Bool(b) => write!(f, "{b}"),
            Field::I64(v) => write!(f, "{v}"),
            Field::F64(v) => write!(f, "{v}"),
            Field::Str(s) => write!(f, "{s}"),
            Field::Bytes(b) => write!(f, "<{} bytes>", b.len()),
        }
    }
}

impl From<&str> for Field {
    fn from(s: &str) -> Self {
        Field::Str(s.to_string())
    }
}
impl From<String> for Field {
    fn from(s: String) -> Self {
        Field::Str(s)
    }
}
impl From<i64> for Field {
    fn from(v: i64) -> Self {
        Field::I64(v)
    }
}
impl From<f64> for Field {
    fn from(v: f64) -> Self {
        Field::F64(v)
    }
}
impl From<bool> for Field {
    fn from(v: bool) -> Self {
        Field::Bool(v)
    }
}

/// Column types for schema validation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FieldType {
    Any,
    Bool,
    I64,
    F64,
    Str,
    Bytes,
}

impl FieldType {
    pub fn matches(&self, f: &Field) -> bool {
        matches!(
            (self, f),
            (FieldType::Any, _)
                | (_, Field::Null)
                | (FieldType::Bool, Field::Bool(_))
                | (FieldType::I64, Field::I64(_))
                | (FieldType::F64, Field::F64(_))
                | (FieldType::Str, Field::Str(_))
                | (FieldType::Bytes, Field::Bytes(_))
        )
    }

    pub fn parse(name: &str) -> Result<FieldType> {
        Ok(match name {
            "any" => FieldType::Any,
            "bool" => FieldType::Bool,
            "i64" | "int" | "long" => FieldType::I64,
            "f64" | "float" | "double" => FieldType::F64,
            "str" | "string" => FieldType::Str,
            "bytes" | "binary" => FieldType::Bytes,
            other => return Err(DdpError::schema(format!("unknown field type '{other}'"))),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            FieldType::Any => "any",
            FieldType::Bool => "bool",
            FieldType::I64 => "i64",
            FieldType::F64 => "f64",
            FieldType::Str => "str",
            FieldType::Bytes => "bytes",
        }
    }
}

/// Ordered, named, typed column list. Shared via `Arc`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schema {
    fields: Vec<(String, FieldType)>,
    index: HashMap<String, usize>,
}

pub type SchemaRef = Arc<Schema>;

impl Schema {
    pub fn new(fields: Vec<(&str, FieldType)>) -> SchemaRef {
        let fields: Vec<(String, FieldType)> =
            fields.into_iter().map(|(n, t)| (n.to_string(), t)).collect();
        let index = fields
            .iter()
            .enumerate()
            .map(|(i, (n, _))| (n.clone(), i))
            .collect();
        Arc::new(Schema { fields, index })
    }

    pub fn of_names(names: &[&str]) -> SchemaRef {
        Schema::new(names.iter().map(|n| (*n, FieldType::Any)).collect())
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn idx(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    pub fn names(&self) -> Vec<&str> {
        self.fields.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn field_type(&self, i: usize) -> FieldType {
        self.fields[i].1
    }

    pub fn field(&self, i: usize) -> (&str, FieldType) {
        (self.fields[i].0.as_str(), self.fields[i].1)
    }

    /// Check a row conforms (arity + types).
    pub fn validate_row(&self, row: &Row) -> Result<()> {
        if row.fields.len() != self.fields.len() {
            return Err(DdpError::schema(format!(
                "arity mismatch: row has {} fields, schema has {}",
                row.fields.len(),
                self.fields.len()
            )));
        }
        for (i, f) in row.fields.iter().enumerate() {
            if !self.fields[i].1.matches(f) {
                return Err(DdpError::schema(format!(
                    "field '{}' expected {}, got {}",
                    self.fields[i].0,
                    self.fields[i].1.name(),
                    f.type_name()
                )));
            }
        }
        Ok(())
    }
}

/// A data record: positional fields interpreted through a schema.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Row {
    pub fields: Vec<Field>,
}

impl Row {
    pub fn new(fields: Vec<Field>) -> Row {
        Row { fields }
    }

    pub fn get(&self, i: usize) -> &Field {
        &self.fields[i]
    }

    /// Field lookup by name through a schema.
    pub fn col<'a>(&'a self, schema: &Schema, name: &str) -> Option<&'a Field> {
        schema.idx(name).map(|i| &self.fields[i])
    }

    pub fn str_col(&self, schema: &Schema, name: &str) -> Option<&str> {
        self.col(schema, name).and_then(|f| f.as_str())
    }

    pub fn i64_col(&self, schema: &Schema, name: &str) -> Option<i64> {
        self.col(schema, name).and_then(|f| f.as_i64())
    }

    pub fn f64_col(&self, schema: &Schema, name: &str) -> Option<f64> {
        self.col(schema, name).and_then(|f| f.as_f64())
    }

    pub fn approx_size(&self) -> usize {
        16 + self.fields.iter().map(|f| f.approx_size()).sum::<usize>()
    }
}

#[macro_export]
macro_rules! row {
    ($($v:expr),* $(,)?) => {
        $crate::engine::row::Row::new(vec![$($crate::engine::row::Field::from($v)),*])
    };
}

// --------------------------- columnar batches ---------------------------

/// Typed backing storage for one column of a [`ColumnBatch`].
///
/// Typed variants hold a placeholder value at null slots (the validity mask
/// on [`Column`] is authoritative); the `Any` variant stores per-value
/// tagged [`Field`]s and is used for mixed-type or all-null columns.
#[derive(Debug, Clone, PartialEq)]
pub enum ColumnData {
    Bool(Vec<bool>),
    I64(Vec<i64>),
    F64(Vec<f64>),
    Str(Vec<String>),
    Bytes(Vec<Vec<u8>>),
    Any(Vec<Field>),
}

impl ColumnData {
    pub fn len(&self) -> usize {
        match self {
            ColumnData::Bool(v) => v.len(),
            ColumnData::I64(v) => v.len(),
            ColumnData::F64(v) => v.len(),
            ColumnData::Str(v) => v.len(),
            ColumnData::Bytes(v) => v.len(),
            ColumnData::Any(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One column of a [`ColumnBatch`]: typed values plus an optional null
/// mask (`nulls[i] == true` marks slot `i` null). Invariants: `Any`
/// columns never carry a mask (nullness lives in the `Field::Null`
/// values); a mask, when present, has the same length as the data.
#[derive(Debug, Clone, PartialEq)]
pub struct Column {
    pub data: ColumnData,
    pub nulls: Option<Vec<bool>>,
}

impl Column {
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn is_null(&self, i: usize) -> bool {
        match &self.data {
            ColumnData::Any(v) => v[i].is_null(),
            _ => self.nulls.as_ref().is_some_and(|m| m[i]),
        }
    }

    /// Build a column from row-major fields: typed storage when all
    /// non-null values share one concrete type, `Any` otherwise (mixed
    /// concrete types, or no non-null values at all). Total — never fails.
    pub fn from_fields(fields: Vec<Field>) -> Column {
        #[derive(Clone, Copy, PartialEq)]
        enum T {
            None,
            Bool,
            I64,
            F64,
            Str,
            Bytes,
            Mixed,
        }
        let mut t = T::None;
        for f in &fields {
            let ft = match f {
                Field::Null => continue,
                Field::Bool(_) => T::Bool,
                Field::I64(_) => T::I64,
                Field::F64(_) => T::F64,
                Field::Str(_) => T::Str,
                Field::Bytes(_) => T::Bytes,
            };
            t = match t {
                T::None => ft,
                cur if cur == ft => cur,
                _ => T::Mixed,
            };
        }
        macro_rules! build {
            ($variant:ident, $fvariant:ident, $default:expr) => {{
                let n = fields.len();
                let mut data = Vec::with_capacity(n);
                let mut nulls = vec![false; n];
                let mut any_null = false;
                for (i, f) in fields.into_iter().enumerate() {
                    match f {
                        Field::$fvariant(v) => data.push(v),
                        Field::Null => {
                            data.push($default);
                            nulls[i] = true;
                            any_null = true;
                        }
                        _ => unreachable!("column type scan found a homogeneous type"),
                    }
                }
                Column {
                    data: ColumnData::$variant(data),
                    nulls: any_null.then_some(nulls),
                }
            }};
        }
        match t {
            T::None | T::Mixed => Column { data: ColumnData::Any(fields), nulls: None },
            T::Bool => build!(Bool, Bool, false),
            T::I64 => build!(I64, I64, 0),
            T::F64 => build!(F64, F64, 0.0),
            T::Str => build!(Str, Str, String::new()),
            T::Bytes => build!(Bytes, Bytes, Vec::new()),
        }
    }

    /// True when the column holds at least two distinct concrete value
    /// types. `from_fields` only produces `Any` for mixed or all-null
    /// input, so: `Any` + any non-null value ⇒ mixed.
    pub fn is_mixed(&self) -> bool {
        match &self.data {
            ColumnData::Any(v) => v.iter().any(|f| !f.is_null()),
            _ => false,
        }
    }

    /// Clone out the field at slot `i`.
    pub fn field_at(&self, i: usize) -> Field {
        if self.is_null(i) {
            return Field::Null;
        }
        match &self.data {
            ColumnData::Bool(v) => Field::Bool(v[i]),
            ColumnData::I64(v) => Field::I64(v[i]),
            ColumnData::F64(v) => Field::F64(v[i]),
            ColumnData::Str(v) => Field::Str(v[i].clone()),
            ColumnData::Bytes(v) => Field::Bytes(v[i].clone()),
            ColumnData::Any(v) => v[i].clone(),
        }
    }

    /// Consume the column back into row-major fields.
    pub fn into_fields(self) -> Vec<Field> {
        let Column { data, nulls } = self;
        fn wrap<T>(
            data: Vec<T>,
            nulls: Option<Vec<bool>>,
            mk: impl Fn(T) -> Field,
        ) -> Vec<Field> {
            match nulls {
                None => data.into_iter().map(mk).collect(),
                Some(m) => data
                    .into_iter()
                    .zip(m)
                    .map(|(v, n)| if n { Field::Null } else { mk(v) })
                    .collect(),
            }
        }
        match data {
            ColumnData::Bool(v) => wrap(v, nulls, Field::Bool),
            ColumnData::I64(v) => wrap(v, nulls, Field::I64),
            ColumnData::F64(v) => wrap(v, nulls, Field::F64),
            ColumnData::Str(v) => wrap(v, nulls, Field::Str),
            ColumnData::Bytes(v) => wrap(v, nulls, Field::Bytes),
            ColumnData::Any(v) => v,
        }
    }

    /// Keep only slots where `keep[i]` is true (`kept` is the precomputed
    /// survivor count, for allocation).
    pub fn filtered(&self, keep: &[bool], kept: usize) -> Column {
        fn sel<T: Clone>(v: &[T], keep: &[bool], kept: usize) -> Vec<T> {
            let mut out = Vec::with_capacity(kept);
            for (x, k) in v.iter().zip(keep) {
                if *k {
                    out.push(x.clone());
                }
            }
            out
        }
        let data = match &self.data {
            ColumnData::Bool(v) => ColumnData::Bool(sel(v, keep, kept)),
            ColumnData::I64(v) => ColumnData::I64(sel(v, keep, kept)),
            ColumnData::F64(v) => ColumnData::F64(sel(v, keep, kept)),
            ColumnData::Str(v) => ColumnData::Str(sel(v, keep, kept)),
            ColumnData::Bytes(v) => ColumnData::Bytes(sel(v, keep, kept)),
            ColumnData::Any(v) => ColumnData::Any(sel(v, keep, kept)),
        };
        let nulls = self.nulls.as_ref().map(|m| sel(m, keep, kept));
        Column { data, nulls }.normalize()
    }

    /// Gather slots by index (indices may repeat or reorder; every index
    /// must be in bounds). The result is normalized so representation
    /// invariants hold even when the gather selects only null slots.
    pub fn take(&self, idxs: &[usize]) -> Column {
        fn sel<T: Clone>(v: &[T], idxs: &[usize]) -> Vec<T> {
            idxs.iter().map(|&i| v[i].clone()).collect()
        }
        let data = match &self.data {
            ColumnData::Bool(v) => ColumnData::Bool(sel(v, idxs)),
            ColumnData::I64(v) => ColumnData::I64(sel(v, idxs)),
            ColumnData::F64(v) => ColumnData::F64(sel(v, idxs)),
            ColumnData::Str(v) => ColumnData::Str(sel(v, idxs)),
            ColumnData::Bytes(v) => ColumnData::Bytes(sel(v, idxs)),
            ColumnData::Any(v) => ColumnData::Any(sel(v, idxs)),
        };
        let nulls = self.nulls.as_ref().map(|m| sel(m, idxs));
        Column { data, nulls }.normalize()
    }

    /// Restore the canonical representation after slot-level surgery
    /// (`filtered`/`take`, colbin decode): a mask with no set bits is
    /// dropped, and a typed column whose slots are all null collapses to
    /// the `Any` form `from_fields` would have produced. Keeping every
    /// producer on one canonical form makes batch equality and spill
    /// round-trips representation-stable.
    pub fn normalize(self) -> Column {
        let Column { data, nulls } = self;
        match nulls {
            None => Column { data, nulls: None },
            Some(m) => {
                if !m.iter().any(|&n| n) {
                    Column { data, nulls: None }
                } else if m.iter().all(|&n| n) {
                    Column { data: ColumnData::Any(vec![Field::Null; m.len()]), nulls: None }
                } else {
                    Column { data, nulls: Some(m) }
                }
            }
        }
    }

    /// Per-slot hashes equal to feeding `field_at(i)` through
    /// `DefaultHasher` (the executor's shuffle hash), without
    /// materializing a `Field` per slot. Null slots hash exactly as
    /// `Field::Null` (tag byte only) — the typed placeholder value at a
    /// null slot is never observed, so a null key can never hash or
    /// bucket like a real `0`/`0.0`/`""`.
    pub fn hash_values(&self) -> Vec<u64> {
        use std::collections::hash_map::DefaultHasher;
        let n = self.len();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut h = DefaultHasher::new();
            if self.is_null(i) {
                0u8.hash(&mut h);
            } else {
                match &self.data {
                    ColumnData::Bool(v) => {
                        1u8.hash(&mut h);
                        v[i].hash(&mut h);
                    }
                    ColumnData::I64(v) => {
                        2u8.hash(&mut h);
                        v[i].hash(&mut h);
                    }
                    ColumnData::F64(v) => {
                        3u8.hash(&mut h);
                        v[i].to_bits().hash(&mut h);
                    }
                    ColumnData::Str(v) => {
                        4u8.hash(&mut h);
                        v[i].hash(&mut h);
                    }
                    ColumnData::Bytes(v) => {
                        5u8.hash(&mut h);
                        v[i].hash(&mut h);
                    }
                    ColumnData::Any(v) => v[i].hash(&mut h),
                }
            }
            out.push(h.finish());
        }
        out
    }

    /// Sum of `Field::approx_size` over the column's slots. Null slots
    /// count as `Field::Null` (1 byte), not as the typed placeholder, so
    /// byte accounting is identical to the row representation.
    pub fn approx_fields_size(&self) -> usize {
        let null_count =
            |m: &Option<Vec<bool>>| m.as_ref().map_or(0, |m| m.iter().filter(|&&n| n).count());
        match &self.data {
            ColumnData::Bool(v) => v.len(),
            ColumnData::I64(v) => {
                let nulls = null_count(&self.nulls);
                8 * (v.len() - nulls) + nulls
            }
            ColumnData::F64(v) => {
                let nulls = null_count(&self.nulls);
                8 * (v.len() - nulls) + nulls
            }
            ColumnData::Str(v) => match &self.nulls {
                None => v.iter().map(|s| 24 + s.len()).sum(),
                Some(m) => {
                    v.iter().zip(m).map(|(s, &n)| if n { 1 } else { 24 + s.len() }).sum()
                }
            },
            ColumnData::Bytes(v) => match &self.nulls {
                None => v.iter().map(|b| 24 + b.len()).sum(),
                Some(m) => {
                    v.iter().zip(m).map(|(b, &n)| if n { 1 } else { 24 + b.len() }).sum()
                }
            },
            ColumnData::Any(v) => v.iter().map(|f| f.approx_size()).sum(),
        }
    }
}

/// A rectangular batch of rows in columnar layout: one [`Column`] per
/// schema position. The batch length is stored explicitly so zero-column
/// batches (and empty inputs) stay well-defined.
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnBatch {
    pub cols: Vec<Column>,
    len: usize,
}

impl ColumnBatch {
    pub fn new(cols: Vec<Column>, len: usize) -> ColumnBatch {
        debug_assert!(cols.iter().all(|c| c.len() == len));
        ColumnBatch { cols, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn num_cols(&self) -> usize {
        self.cols.len()
    }

    /// Transpose rows into columns. Returns `None` when the rows cannot be
    /// represented as a typed batch: ragged arity, or a column mixing two
    /// concrete value types (the engine falls back to row-at-a-time
    /// execution for those). Empty input yields an empty batch.
    pub fn try_from_rows(rows: &[Row]) -> Option<ColumnBatch> {
        let Some(first) = rows.first() else {
            return Some(ColumnBatch { cols: Vec::new(), len: 0 });
        };
        let width = first.fields.len();
        if rows.iter().any(|r| r.fields.len() != width) {
            return None;
        }
        let mut cols = Vec::with_capacity(width);
        for c in 0..width {
            let col = Column::from_fields(rows.iter().map(|r| r.fields[c].clone()).collect());
            if col.is_mixed() {
                return None;
            }
            cols.push(col);
        }
        Some(ColumnBatch { cols, len: rows.len() })
    }

    /// Transpose columns back into rows, consuming the batch (no clones).
    pub fn into_rows(self) -> Vec<Row> {
        let len = self.len;
        let mut its: Vec<std::vec::IntoIter<Field>> =
            self.cols.into_iter().map(|c| c.into_fields().into_iter()).collect();
        (0..len)
            .map(|_| Row::new(its.iter_mut().map(|it| it.next().unwrap()).collect()))
            .collect()
    }

    /// Clone out row `r`.
    pub fn row_at(&self, r: usize) -> Row {
        Row::new(self.cols.iter().map(|c| c.field_at(r)).collect())
    }

    /// Keep only rows where `keep[i]` is true.
    pub fn filter(&self, keep: &[bool]) -> ColumnBatch {
        assert_eq!(keep.len(), self.len);
        let kept = keep.iter().filter(|k| **k).count();
        let cols = self.cols.iter().map(|c| c.filtered(keep, kept)).collect();
        ColumnBatch { cols, len: kept }
    }

    /// Gather rows by index (indices may repeat or reorder). Used by the
    /// batch-native shuffle to split a batch into per-bucket batches
    /// without materializing rows.
    pub fn take(&self, idxs: &[usize]) -> ColumnBatch {
        let cols = self.cols.iter().map(|c| c.take(idxs)).collect();
        ColumnBatch { cols, len: idxs.len() }
    }

    /// Exactly `sum(row.approx_size())` over the batch's rows, without
    /// materializing them (null slots count as `Field::Null`, not the
    /// typed placeholder), so shuffle-byte accounting is identical in
    /// batch and row mode.
    pub fn approx_rows_size(&self) -> usize {
        16 * self.len + self.cols.iter().map(|c| c.approx_fields_size()).sum::<usize>()
    }

    /// Select (and possibly duplicate/reorder) columns by index. Columns
    /// used exactly once are moved, not cloned.
    pub fn project(self, idxs: &[usize]) -> ColumnBatch {
        let len = self.len;
        let mut used = vec![false; self.cols.len()];
        let unique = idxs.iter().all(|&i| !std::mem::replace(&mut used[i], true));
        let cols = if unique {
            let mut slots: Vec<Option<Column>> = self.cols.into_iter().map(Some).collect();
            idxs.iter().map(|&i| slots[i].take().unwrap()).collect()
        } else {
            idxs.iter().map(|&i| self.cols[i].clone()).collect()
        };
        ColumnBatch { cols, len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schema_lookup() {
        let s = Schema::new(vec![("id", FieldType::I64), ("text", FieldType::Str)]);
        assert_eq!(s.idx("id"), Some(0));
        assert_eq!(s.idx("text"), Some(1));
        assert_eq!(s.idx("nope"), None);
        assert_eq!(s.names(), vec!["id", "text"]);
    }

    #[test]
    fn row_macro_and_access() {
        let s = Schema::new(vec![("id", FieldType::I64), ("text", FieldType::Str)]);
        let r = row!(7i64, "hello");
        assert_eq!(r.i64_col(&s, "id"), Some(7));
        assert_eq!(r.str_col(&s, "text"), Some("hello"));
        s.validate_row(&r).unwrap();
    }

    #[test]
    fn validation_catches_type_errors() {
        let s = Schema::new(vec![("id", FieldType::I64)]);
        assert!(s.validate_row(&row!("not an int")).is_err());
        assert!(s.validate_row(&row!(1i64, 2i64)).is_err());
        // nulls always pass
        assert!(s.validate_row(&Row::new(vec![Field::Null])).is_ok());
    }

    #[test]
    fn field_hash_f64_bits() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(Field::F64(1.0));
        assert!(set.contains(&Field::F64(1.0)));
        assert!(!set.contains(&Field::F64(2.0)));
    }

    #[test]
    fn canonical_cmp_total_order() {
        use std::cmp::Ordering;
        assert_eq!(Field::Null.canonical_cmp(&Field::Bool(false)), Ordering::Less);
        assert_eq!(Field::I64(2).canonical_cmp(&Field::I64(10)), Ordering::Less);
        assert_eq!(Field::Str("a".into()).canonical_cmp(&Field::Str("b".into())), Ordering::Less);
        // mixed numeric types order by tag, not value — canonical, not SQL
        assert_eq!(Field::I64(9).canonical_cmp(&Field::F64(1.0)), Ordering::Less);
        // NaN is ordered (IEEE total order), so sorts are never ambiguous
        assert_eq!(Field::F64(f64::NAN).canonical_cmp(&Field::F64(f64::NAN)), Ordering::Equal);
    }

    #[test]
    fn canonical_cmp_nonfinite_and_null_total_order() {
        use std::cmp::Ordering;
        // IEEE total order over f64: -NaN < -inf < finite < +inf < +NaN
        let neg_nan = f64::from_bits(f64::NAN.to_bits() | (1u64 << 63));
        let mut keys = vec![
            Field::F64(f64::NAN),
            Field::F64(f64::INFINITY),
            Field::F64(1.0),
            Field::F64(f64::NEG_INFINITY),
            Field::F64(neg_nan),
            Field::Null,
        ];
        keys.sort_by(|a, b| a.canonical_cmp(b));
        assert_eq!(keys[0], Field::Null); // Null tag sorts before every F64
        assert!(matches!(keys[1], Field::F64(v) if v.is_nan() && v.is_sign_negative()));
        assert_eq!(keys[2], Field::F64(f64::NEG_INFINITY));
        assert_eq!(keys[3], Field::F64(1.0));
        assert_eq!(keys[4], Field::F64(f64::INFINITY));
        assert!(matches!(keys[5], Field::F64(v) if v.is_nan() && v.is_sign_positive()));
        // -0.0 and +0.0 are distinct under total order (deterministic ties)
        assert_eq!(Field::F64(-0.0).canonical_cmp(&Field::F64(0.0)), Ordering::Less);
        // antisymmetric spot-check so both paths sort identically
        for a in &keys {
            for b in &keys {
                assert_eq!(a.canonical_cmp(b), b.canonical_cmp(a).reverse());
            }
        }
    }

    #[test]
    fn column_from_fields_typed_and_mixed() {
        // homogeneous → typed, nulls carried in the mask
        let c = Column::from_fields(vec![Field::I64(1), Field::Null, Field::I64(3)]);
        assert!(matches!(&c.data, ColumnData::I64(v) if v == &vec![1, 0, 3]));
        assert!(c.is_null(1) && !c.is_null(0));
        assert!(!c.is_mixed());
        assert_eq!(c.field_at(1), Field::Null);
        assert_eq!(c.field_at(2), Field::I64(3));
        // mixed concrete types → Any, flagged
        let m = Column::from_fields(vec![Field::I64(1), Field::Str("x".into())]);
        assert!(matches!(&m.data, ColumnData::Any(_)));
        assert!(m.is_mixed());
        // all-null → Any but NOT mixed (vectorizable)
        let n = Column::from_fields(vec![Field::Null, Field::Null]);
        assert!(!n.is_mixed());
        assert!(n.is_null(0));
    }

    #[test]
    fn batch_row_roundtrip() {
        let rows = vec![
            row!(1i64, "a", 1.5),
            Row::new(vec![Field::Null, Field::Str("b".into()), Field::F64(f64::NAN)]),
            row!(3i64, "c", -0.0),
        ];
        let b = ColumnBatch::try_from_rows(&rows).unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(b.num_cols(), 3);
        assert_eq!(b.row_at(1).fields[1], Field::Str("b".into()));
        let back = b.into_rows();
        // NaN != NaN under PartialEq; compare via canonical order
        assert_eq!(back.len(), rows.len());
        for (x, y) in back.iter().zip(&rows) {
            for (fx, fy) in x.fields.iter().zip(&y.fields) {
                assert_eq!(fx.canonical_cmp(fy), std::cmp::Ordering::Equal);
            }
        }
    }

    #[test]
    fn batch_rejects_ragged_and_mixed() {
        let ragged = vec![row!(1i64), row!(1i64, 2i64)];
        assert!(ColumnBatch::try_from_rows(&ragged).is_none());
        let mixed = vec![row!(1i64), row!("s")];
        assert!(ColumnBatch::try_from_rows(&mixed).is_none());
        // empty input is fine (zero-width, zero-length batch)
        let empty = ColumnBatch::try_from_rows(&[]).unwrap();
        assert!(empty.is_empty());
        assert_eq!(empty.into_rows(), Vec::<Row>::new());
    }

    #[test]
    fn batch_filter_and_project() {
        let rows = vec![row!(1i64, "a"), row!(2i64, "b"), row!(3i64, "c")];
        let b = ColumnBatch::try_from_rows(&rows).unwrap();
        let f = b.filter(&[true, false, true]);
        assert_eq!(f.len(), 2);
        assert_eq!(f.row_at(1), row!(3i64, "c"));
        // duplicate + reorder projection
        let p = f.project(&[1, 0, 1]);
        assert_eq!(p.row_at(0), row!("a", 1i64, "a"));
        assert_eq!(p.into_rows()[1], row!("c", 3i64, "c"));
    }

    fn ref_hash(f: &Field) -> u64 {
        use std::collections::hash_map::DefaultHasher;
        let mut h = DefaultHasher::new();
        f.hash(&mut h);
        h.finish()
    }

    #[test]
    fn hash_values_matches_field_hash_and_never_reads_placeholders() {
        // Placeholder collision setups: a real 0 / 0.0 / "" sits next to a
        // null slot whose typed storage holds the very same placeholder.
        let cases = vec![
            vec![Field::I64(0), Field::Null, Field::I64(5), Field::Null],
            vec![Field::F64(0.0), Field::Null, Field::F64(-0.0)],
            vec![Field::Str(String::new()), Field::Null, Field::Str("x".into())],
            vec![Field::Bytes(Vec::new()), Field::Null, Field::Bytes(vec![1])],
            vec![Field::Bool(false), Field::Null],
            // mixed column (Any storage) and all-null column
            vec![Field::I64(1), Field::Str("s".into()), Field::Null],
            vec![Field::Null, Field::Null],
        ];
        for fields in cases {
            let col = Column::from_fields(fields.clone());
            let hashes = col.hash_values();
            assert_eq!(hashes.len(), fields.len());
            for (i, f) in fields.iter().enumerate() {
                assert_eq!(hashes[i], ref_hash(f), "slot {i} of {fields:?}");
                assert_eq!(col.field_at(i).canonical_cmp(f), std::cmp::Ordering::Equal);
            }
        }
        // The null slot must hash as Null, not as the placeholder it sits on.
        let col = Column::from_fields(vec![Field::I64(0), Field::Null]);
        let hashes = col.hash_values();
        assert_eq!(hashes[0], ref_hash(&Field::I64(0)));
        assert_eq!(hashes[1], ref_hash(&Field::Null));
        assert_ne!(hashes[0], hashes[1]);
    }

    #[test]
    fn take_gathers_and_normalizes() {
        let c = Column::from_fields(vec![Field::I64(1), Field::Null, Field::I64(3)]);
        let t = c.take(&[2, 0, 2]);
        assert_eq!(t.field_at(0), Field::I64(3));
        assert_eq!(t.field_at(1), Field::I64(1));
        assert_eq!(t.field_at(2), Field::I64(3));
        // gathering only non-null slots drops the mask entirely
        assert!(t.nulls.is_none());
        // gathering only null slots collapses to the canonical Any form,
        // exactly what from_fields produces for all-null input
        let n = c.take(&[1, 1]);
        assert_eq!(n, Column::from_fields(vec![Field::Null, Field::Null]));
        assert!(matches!(&n.data, ColumnData::Any(_)));
        assert!(n.nulls.is_none());
        // filtered() normalizes the same way
        let f = c.filtered(&[false, true, false], 1);
        assert_eq!(f, Column::from_fields(vec![Field::Null]));
    }

    #[test]
    fn batch_take_matches_row_gather() {
        let rows = vec![
            row!(1i64, "a"),
            Row::new(vec![Field::Null, Field::Str("b".into())]),
            row!(3i64, "c"),
        ];
        let b = ColumnBatch::try_from_rows(&rows).unwrap();
        let idxs = [2usize, 0, 1, 1];
        let t = b.take(&idxs);
        assert_eq!(t.len(), idxs.len());
        for (out, &i) in t.clone().into_rows().iter().zip(idxs.iter()) {
            assert_eq!(out, &rows[i]);
        }
        // empty gather keeps the width
        let e = b.take(&[]);
        assert_eq!(e.len(), 0);
        assert_eq!(e.num_cols(), 2);
    }

    #[test]
    fn approx_rows_size_is_exactly_the_row_sum() {
        let rows = vec![
            row!(1i64, "abc", 1.5, true),
            Row::new(vec![Field::Null, Field::Null, Field::Null, Field::Null]),
            Row::new(vec![
                Field::I64(0),
                Field::Str(String::new()),
                Field::F64(0.0),
                Field::Bool(false),
            ]),
        ];
        let b = ColumnBatch::try_from_rows(&rows).unwrap();
        let want: usize = rows.iter().map(|r| r.approx_size()).sum();
        assert_eq!(b.approx_rows_size(), want);
        // mixed column goes through Any storage — still exact
        let mixed = vec![row!(1i64), row!("s"), Row::new(vec![Field::Null])];
        let cols = vec![Column::from_fields(
            mixed.iter().map(|r| r.fields[0].clone()).collect(),
        )];
        let mb = ColumnBatch::new(cols, 3);
        assert_eq!(mb.approx_rows_size(), mixed.iter().map(|r| r.approx_size()).sum::<usize>());
    }

    #[test]
    fn approx_sizes() {
        assert_eq!(Field::I64(1).approx_size(), 8);
        assert!(Field::Str("abc".into()).approx_size() > 3);
        let r = row!(1i64, "abc");
        assert!(r.approx_size() > 16);
    }
}
