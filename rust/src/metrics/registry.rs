//! Thread-safe metrics registry: counters, gauges, histograms.

use crate::util::fnv1a64;
use crate::util::rng::Rng64;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Uniform-reservoir histogram: Vitter's Algorithm R over the whole
/// stream, so long-run p50/p99 reflect *all* samples, not just the most
/// recent window. (The previous implementation was a sliding ring of the
/// last `RESERVOIR` samples, which silently biased long-run quantiles to
/// recent batches.) Sampling uses the house PRNG with a seed derived
/// from the histogram's name, so summaries are deterministic across
/// runs. Non-finite observations (NaN/±inf) are excluded from the
/// reservoir and the min/mean/max aggregates — they would otherwise
/// poison every quantile — and surface separately as
/// [`HistogramSummary::nonfinite`].
struct Histogram {
    values: Mutex<HistState>,
}

struct HistState {
    buf: Vec<f64>,
    rng: Rng64,
    /// finite samples observed (reservoir population base)
    count: u64,
    /// NaN/±inf samples skipped
    nonfinite: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const RESERVOIR: usize = 4096;

impl Histogram {
    fn new(seed: u64) -> Self {
        Histogram {
            values: Mutex::new(HistState {
                buf: Vec::with_capacity(RESERVOIR),
                rng: Rng64::new(seed),
                count: 0,
                nonfinite: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
            }),
        }
    }

    fn record(&self, v: f64) {
        let mut s = self.values.lock().unwrap();
        if !v.is_finite() {
            s.nonfinite += 1;
            return;
        }
        s.count += 1;
        s.sum += v;
        s.min = s.min.min(v);
        s.max = s.max.max(v);
        if s.buf.len() < RESERVOIR {
            s.buf.push(v);
        } else {
            // Algorithm R: the n-th sample replaces a random slot with
            // probability RESERVOIR/n — every sample ends up in the
            // reservoir with equal probability
            let n = s.count;
            let j = s.rng.gen_range(n);
            if (j as usize) < RESERVOIR {
                s.buf[j as usize] = v;
            }
        }
    }

    fn summary(&self) -> HistogramSummary {
        let s = self.values.lock().unwrap();
        let mut sorted = s.buf.clone();
        // total order: never panics, even if a non-finite value slipped in
        sorted.sort_by(f64::total_cmp);
        let q = |p: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
            sorted[idx]
        };
        HistogramSummary {
            count: s.count,
            nonfinite: s.nonfinite,
            mean: if s.count > 0 { s.sum / s.count as f64 } else { 0.0 },
            min: if s.count > 0 { s.min } else { 0.0 },
            max: if s.count > 0 { s.max } else { 0.0 },
            p50: q(0.50),
            p90: q(0.90),
            p95: q(0.95),
            p99: q(0.99),
            p999: q(0.999),
        }
    }
}

/// Point-in-time histogram stats. `count` covers finite samples only;
/// `nonfinite` counts skipped NaN/±inf observations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistogramSummary {
    pub count: u64,
    pub nonfinite: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
}

impl HistogramSummary {
    /// Count-weighted mean across several summaries — the mean of the
    /// union stream, not the mean of the means. A plain average would let
    /// a 2-sample histogram pull as hard as a 2-million-sample one when
    /// rolling per-pipe latencies up to a service-level figure. Summaries
    /// with `count == 0` contribute nothing; returns 0.0 when every part
    /// is empty.
    pub fn weighted_mean(parts: &[HistogramSummary]) -> f64 {
        let total: u64 = parts.iter().map(|h| h.count).sum();
        if total == 0 {
            return 0.0;
        }
        parts.iter().map(|h| h.mean * h.count as f64).sum::<f64>() / total as f64
    }
}

/// The registry pipes write into. Cloneable handle (`Arc` inside).
#[derive(Clone)]
pub struct MetricsRegistry {
    inner: Arc<Inner>,
}

struct Inner {
    counters: RwLock<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: RwLock<BTreeMap<String, Arc<AtomicI64>>>,
    histograms: RwLock<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            inner: Arc::new(Inner {
                counters: RwLock::new(BTreeMap::new()),
                gauges: RwLock::new(BTreeMap::new()),
                histograms: RwLock::new(BTreeMap::new()),
            }),
        }
    }

    /// Add to a named counter (creating it on first use).
    pub fn counter_add(&self, name: &str, v: u64) {
        if let Some(c) = self.inner.counters.read().unwrap().get(name) {
            c.fetch_add(v, Ordering::Relaxed);
            return;
        }
        let mut w = self.inner.counters.write().unwrap();
        w.entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .fetch_add(v, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .counters
            .read()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Set a gauge value (stored as milli-units to stay atomic).
    pub fn gauge_set(&self, name: &str, v: f64) {
        let milli = (v * 1000.0) as i64;
        if let Some(g) = self.inner.gauges.read().unwrap().get(name) {
            g.store(milli, Ordering::Relaxed);
            return;
        }
        let mut w = self.inner.gauges.write().unwrap();
        w.entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicI64::new(0)))
            .store(milli, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.inner
            .gauges
            .read()
            .unwrap()
            .get(name)
            .map(|g| g.load(Ordering::Relaxed) as f64 / 1000.0)
            .unwrap_or(0.0)
    }

    /// Record an observation into a named histogram.
    pub fn observe(&self, name: &str, v: f64) {
        if let Some(h) = self.inner.histograms.read().unwrap().get(name) {
            h.record(v);
            return;
        }
        let mut w = self.inner.histograms.write().unwrap();
        // name-derived seed: deterministic reservoirs across runs
        w.entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new(fnv1a64(name.as_bytes()))))
            .record(v);
    }

    pub fn histogram(&self, name: &str) -> Option<HistogramSummary> {
        self.inner
            .histograms
            .read()
            .unwrap()
            .get(name)
            .map(|h| h.summary())
    }

    /// Snapshot everything (what the publisher ships).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .inner
            .counters
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .inner
            .gauges
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed) as f64 / 1000.0))
            .collect();
        let histograms = self
            .inner
            .histograms
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.summary()))
            .collect();
        MetricsSnapshot { counters, gauges, histograms }
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable snapshot shipped to sinks.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, HistogramSummary>,
}

impl MetricsSnapshot {
    /// Serialize to a JSON value for sinks.
    pub fn to_json(&self, timestamp_secs: f64) -> crate::json::Value {
        use crate::json::Value;
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("ts".to_string(), Value::Num(timestamp_secs));
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Value::Num(*v as f64)))
            .collect();
        obj.insert("counters".to_string(), Value::Obj(counters));
        let gauges = self
            .gauges
            .iter()
            .map(|(k, v)| (k.clone(), Value::Num(*v)))
            .collect();
        obj.insert("gauges".to_string(), Value::Obj(gauges));
        let hists = self
            .histograms
            .iter()
            .map(|(k, h)| {
                (
                    k.clone(),
                    Value::obj(vec![
                        ("count", Value::Num(h.count as f64)),
                        ("nonfinite", Value::Num(h.nonfinite as f64)),
                        ("mean", Value::Num(h.mean)),
                        ("min", Value::Num(h.min)),
                        ("max", Value::Num(h.max)),
                        ("p50", Value::Num(h.p50)),
                        ("p90", Value::Num(h.p90)),
                        ("p95", Value::Num(h.p95)),
                        ("p99", Value::Num(h.p99)),
                        ("p999", Value::Num(h.p999)),
                    ]),
                )
            })
            .collect();
        obj.insert("histograms".to_string(), Value::Obj(hists));
        Value::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let m = MetricsRegistry::new();
        m.counter_add("rows", 5);
        m.counter_add("rows", 7);
        assert_eq!(m.counter("rows"), 12);
        assert_eq!(m.counter("missing"), 0);
        m.gauge_set("util", 0.75);
        assert!((m.gauge("util") - 0.75).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles() {
        let m = MetricsRegistry::new();
        for i in 1..=100 {
            m.observe("lat", i as f64);
        }
        let h = m.histogram("lat").unwrap();
        assert_eq!(h.count, 100);
        assert!((h.mean - 50.5).abs() < 1e-9);
        assert!((h.p50 - 50.0).abs() <= 1.0);
        assert!((h.p95 - 95.0).abs() <= 1.0);
        assert_eq!(h.min, 1.0);
        assert_eq!(h.max, 100.0);
    }

    #[test]
    fn tail_quantiles_exact_below_reservoir_capacity() {
        // 1000 samples fit in the 4096-slot reservoir, so every quantile
        // is exact: idx = round((len-1) * p) over the sorted values
        // 1.0..=1000.0 gives round(999*0.9)=899 → 900.0 and
        // round(999*0.999)=998 → 999.0.
        let m = MetricsRegistry::new();
        for i in 1..=1000 {
            m.observe("lat", i as f64);
        }
        let h = m.histogram("lat").unwrap();
        assert_eq!(h.p90, 900.0);
        assert_eq!(h.p99, 990.0);
        assert_eq!(h.p999, 999.0);
        assert_eq!(h.max, 1000.0);
    }

    #[test]
    fn weighted_mean_weighs_by_count() {
        let m = MetricsRegistry::new();
        m.observe("a", 10.0);
        for _ in 0..3 {
            m.observe("b", 20.0);
        }
        let a = m.histogram("a").unwrap();
        let b = m.histogram("b").unwrap();
        // union stream is {10, 20, 20, 20} → 17.5, not mean-of-means 15
        assert!((HistogramSummary::weighted_mean(&[a, b]) - 17.5).abs() < 1e-9);
        assert_eq!(HistogramSummary::weighted_mean(&[]), 0.0);
        let empty = HistogramSummary {
            count: 0,
            nonfinite: 0,
            mean: 0.0,
            min: 0.0,
            max: 0.0,
            p50: 0.0,
            p90: 0.0,
            p95: 0.0,
            p99: 0.0,
            p999: 0.0,
        };
        assert!((HistogramSummary::weighted_mean(&[a, empty]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn reservoir_bounds_memory() {
        let m = MetricsRegistry::new();
        for i in 0..20_000 {
            m.observe("big", i as f64);
        }
        let h = m.histogram("big").unwrap();
        assert_eq!(h.count, 20_000);
        assert_eq!(h.max, 19_999.0);
    }

    #[test]
    fn reservoir_is_uniform_over_whole_stream_not_recent_window() {
        // ramp 0..20k: a uniform reservoir's p50 sits near 10k; the old
        // last-4096 ring would report ~17.9k. Deterministic (name-seeded).
        let m = MetricsRegistry::new();
        for i in 0..20_000 {
            m.observe("ramp", i as f64);
        }
        let h = m.histogram("ramp").unwrap();
        assert!(
            (8_000.0..=12_000.0).contains(&h.p50),
            "p50 {} biased away from stream median",
            h.p50
        );
        assert!(h.p99 > 18_000.0, "upper tail still represented: {}", h.p99);
    }

    #[test]
    fn non_finite_samples_do_not_panic_or_poison() {
        let m = MetricsRegistry::new();
        m.observe("lat", 1.0);
        m.observe("lat", f64::NAN);
        m.observe("lat", f64::INFINITY);
        m.observe("lat", f64::NEG_INFINITY);
        m.observe("lat", 3.0);
        // summary() used to panic on NaN via partial_cmp().unwrap()
        let h = m.histogram("lat").unwrap();
        assert_eq!(h.count, 2, "finite samples only");
        assert_eq!(h.nonfinite, 3, "skipped samples are counted");
        assert_eq!(h.min, 1.0);
        assert_eq!(h.max, 3.0);
        assert!((h.mean - 2.0).abs() < 1e-9);
        assert!(h.p50.is_finite() && h.p99.is_finite());
        // and the snapshot path (publisher) survives too
        let j = m.snapshot().to_json(1.0);
        assert!(j.get("histograms").unwrap().get("lat").unwrap().get("nonfinite").is_some());
    }

    #[test]
    fn reservoir_deterministic_across_identical_runs() {
        let run = || {
            let m = MetricsRegistry::new();
            for i in 0..10_000 {
                m.observe("d", (i % 977) as f64);
            }
            m.histogram("d").unwrap()
        };
        assert_eq!(run(), run(), "name-seeded Algorithm R is reproducible");
    }

    #[test]
    fn snapshot_json_shape() {
        let m = MetricsRegistry::new();
        m.counter_add("a", 1);
        m.gauge_set("g", 2.0);
        m.observe("h", 3.0);
        let j = m.snapshot().to_json(12.0);
        assert_eq!(j.get("ts").unwrap().as_f64(), Some(12.0));
        assert!(j.get("counters").unwrap().get("a").is_some());
        assert!(j.get("histograms").unwrap().get("h").unwrap().get("p50").is_some());
    }

    #[test]
    fn concurrent_counting() {
        let m = MetricsRegistry::new();
        let mut handles = vec![];
        for _ in 0..4 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.counter_add("c", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("c"), 4000);
    }
}
