//! Asynchronous metrics publisher: a background thread snapshots the
//! registry every `cadence` (paper default: 30 s) and ships it to a sink
//! (CloudWatch stand-ins: JSONL blob in storage, log lines, or memory).

use super::registry::{MetricsRegistry, MetricsSnapshot};
use crate::io::StorageRef;
use crate::util::clock::ClockRef;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// Destination for published snapshots.
pub trait Sink: Send + Sync {
    fn publish(&self, snapshot: &MetricsSnapshot, ts_secs: f64);
}

/// Collects snapshots in memory (tests, examples).
#[derive(Default)]
pub struct MemorySink {
    pub published: Mutex<Vec<(f64, MetricsSnapshot)>>,
}

impl MemorySink {
    pub fn new() -> Arc<MemorySink> {
        Arc::new(MemorySink::default())
    }

    pub fn count(&self) -> usize {
        self.published.lock().unwrap().len()
    }
}

impl Sink for MemorySink {
    fn publish(&self, snapshot: &MetricsSnapshot, ts: f64) {
        self.published.lock().unwrap().push((ts, snapshot.clone()));
    }
}

/// Logs snapshots through the `log` facade.
pub struct LogSink;

impl Sink for LogSink {
    fn publish(&self, snapshot: &MetricsSnapshot, ts: f64) {
        log::info!(
            "metrics@{ts:.1}s: {}",
            crate::json::to_string(&snapshot.to_json(ts))
        );
    }
}

/// Appends JSONL snapshots to a storage object (the CloudWatch stand-in).
pub struct StorageSink {
    storage: StorageRef,
    path: String,
    buffer: Mutex<String>,
}

impl StorageSink {
    pub fn new(storage: StorageRef, path: &str) -> Arc<StorageSink> {
        Arc::new(StorageSink {
            storage,
            path: path.to_string(),
            buffer: Mutex::new(String::new()),
        })
    }
}

impl Sink for StorageSink {
    fn publish(&self, snapshot: &MetricsSnapshot, ts: f64) {
        let mut buf = self.buffer.lock().unwrap();
        buf.push_str(&crate::json::to_string(&snapshot.to_json(ts)));
        buf.push('\n');
        let _ = self.storage.write(&self.path, buf.as_bytes());
    }
}

/// Publisher configuration.
#[derive(Clone)]
pub struct PublisherConfig {
    /// snapshot cadence; paper default 30 s
    pub cadence: Duration,
}

impl Default for PublisherConfig {
    fn default() -> Self {
        PublisherConfig { cadence: Duration::from_secs(30) }
    }
}

/// Handle to the background publisher thread. Stops (with a final flush)
/// on `stop()` or drop.
pub struct MetricsPublisher {
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl MetricsPublisher {
    /// Spawn the publisher thread.
    pub fn start(
        registry: MetricsRegistry,
        sink: Arc<dyn Sink>,
        clock: ClockRef,
        cfg: PublisherConfig,
    ) -> MetricsPublisher {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = thread::Builder::new()
            .name("ddp-metrics-publisher".into())
            .spawn(move || {
                // a sink panic (broken pipe, poisoned lock, bad
                // serializer) must not kill the cadence loop or skip the
                // final flush — drop that one snapshot and keep going
                let safe_publish = || {
                    let snap = registry.snapshot();
                    let ts = clock.now();
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        sink.publish(&snap, ts)
                    }));
                    if r.is_err() {
                        log::warn!("metrics sink panicked; snapshot at {ts:.1}s dropped");
                    }
                };
                // poll in small slices so stop() is responsive even with a
                // 30 s cadence
                let slice = Duration::from_millis(5).min(cfg.cadence);
                let mut elapsed = Duration::ZERO;
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    thread::sleep(slice);
                    elapsed += slice;
                    if elapsed >= cfg.cadence {
                        elapsed = Duration::ZERO;
                        safe_publish();
                    }
                }
                // final flush so short-lived runs still publish
                safe_publish();
            })
            .expect("spawn metrics publisher");
        MetricsPublisher { stop, handle: Some(handle) }
    }

    /// Stop the thread and flush a final snapshot.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsPublisher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock;

    #[test]
    fn publishes_at_cadence_and_flushes_on_stop() {
        let reg = MetricsRegistry::new();
        let sink = MemorySink::new();
        let pubr = MetricsPublisher::start(
            reg.clone(),
            sink.clone(),
            clock::wall(),
            PublisherConfig { cadence: Duration::from_millis(20) },
        );
        reg.counter_add("x", 1);
        thread::sleep(Duration::from_millis(90));
        pubr.stop();
        let n = sink.count();
        assert!(n >= 3, "expected >=3 publishes, got {n}");
        let last = sink.published.lock().unwrap().last().unwrap().1.clone();
        assert_eq!(*last.counters.get("x").unwrap(), 1);
    }

    #[test]
    fn storage_sink_accumulates_jsonl() {
        use crate::io::MemStore;
        let store: StorageRef = Arc::new(MemStore::new());
        let sink = StorageSink::new(store.clone(), "metrics/run1.jsonl");
        let reg = MetricsRegistry::new();
        reg.counter_add("a", 2);
        sink.publish(&reg.snapshot(), 1.0);
        sink.publish(&reg.snapshot(), 2.0);
        let blob = store.read("metrics/run1.jsonl").unwrap();
        let text = String::from_utf8(blob).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("\"a\":2"));
    }

    #[test]
    fn timestamps_come_from_the_injected_clock() {
        let vclock = clock::virt();
        vclock.set(123.5);
        let reg = MetricsRegistry::new();
        let sink = MemorySink::new();
        let cref: ClockRef = vclock.clone();
        let pubr = MetricsPublisher::start(
            reg,
            sink.clone(),
            cref,
            PublisherConfig { cadence: Duration::from_secs(3600) },
        );
        pubr.stop();
        let published = sink.published.lock().unwrap();
        assert_eq!(published.len(), 1, "huge cadence → only the final flush");
        assert_eq!(published[0].0, 123.5, "timestamp read from the virtual clock");
    }

    #[test]
    fn drop_flushes_exactly_once_with_huge_cadence() {
        let reg = MetricsRegistry::new();
        let sink = MemorySink::new();
        reg.counter_add("x", 7);
        {
            let _p = MetricsPublisher::start(
                reg,
                sink.clone(),
                clock::wall(),
                PublisherConfig { cadence: Duration::from_secs(3600) },
            );
        } // drop → shutdown → final flush
        let published = sink.published.lock().unwrap();
        assert_eq!(published.len(), 1, "one final snapshot, no duplicates");
        assert_eq!(*published[0].1.counters.get("x").unwrap(), 7);
    }

    #[test]
    fn panicking_sink_does_not_kill_the_publisher() {
        use std::sync::atomic::AtomicU64;

        struct PanicSink {
            attempts: AtomicU64,
        }
        impl Sink for PanicSink {
            fn publish(&self, _s: &MetricsSnapshot, _ts: f64) {
                self.attempts.fetch_add(1, Ordering::SeqCst);
                panic!("sink unavailable");
            }
        }

        let sink = Arc::new(PanicSink { attempts: AtomicU64::new(0) });
        let reg = MetricsRegistry::new();
        let pubr = MetricsPublisher::start(
            reg,
            sink.clone(),
            clock::wall(),
            PublisherConfig { cadence: Duration::from_millis(10) },
        );
        thread::sleep(Duration::from_millis(40));
        // stop() joins the thread: it must still be alive despite every
        // publish having panicked, and the final flush is still attempted
        pubr.stop();
        let n = sink.attempts.load(Ordering::SeqCst);
        assert!(n >= 2, "cadence publishes plus the final flush, got {n}");
    }

    #[test]
    fn drop_stops_thread() {
        let reg = MetricsRegistry::new();
        let sink = MemorySink::new();
        {
            let _p = MetricsPublisher::start(
                reg,
                sink.clone(),
                clock::wall(),
                PublisherConfig { cadence: Duration::from_millis(10) },
            );
            thread::sleep(Duration::from_millis(25));
        } // drop here
        let n = sink.count();
        thread::sleep(Duration::from_millis(30));
        assert_eq!(sink.count(), n, "no publishes after drop");
    }
}
