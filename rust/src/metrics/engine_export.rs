//! Engine → metrics bridge: surfaces the executor's internal counters —
//! including [`crate::engine::cache::CacheManager`] hit/eviction counts
//! and [`crate::engine::fault::FaultInjector`] injected-failure counts —
//! through the [`MetricsRegistry`], so pipeline and streaming runs can
//! alarm on cache thrash and retry storms from the same sink all other
//! metrics flow to.
//!
//! The exporter is delta-based: each [`EngineMetricsExporter::publish`]
//! adds only what accrued since the previous publish, so calling it at
//! every micro-batch (streaming) or at end of run (batch driver) yields
//! correct monotone counters either way.

use super::registry::MetricsRegistry;
use crate::engine::executor::EngineCtx;
use crate::engine::stats::StatsSnapshot;

/// Stateful delta publisher for one engine context.
#[derive(Default)]
pub struct EngineMetricsExporter {
    last: StatsSnapshot,
    last_cache_entry_hits: u64,
    last_cache_evictions: u64,
    last_fault_injected: u64,
}

impl EngineMetricsExporter {
    pub fn new() -> EngineMetricsExporter {
        EngineMetricsExporter::default()
    }

    /// Publish deltas since the previous call into `m`.
    pub fn publish(&mut self, m: &MetricsRegistry, engine: &EngineCtx) {
        // engine execution stats
        let s = engine.stats.snapshot();
        let d = s.delta(&self.last);
        self.last = s;
        m.counter_add("engine.tasks_launched", d.tasks_launched);
        m.counter_add("engine.tasks_retried", d.tasks_retried);
        m.counter_add("engine.stages_run", d.stages_run);
        m.counter_add("engine.rows_read", d.rows_read);
        m.counter_add("engine.rows_written", d.rows_written);
        m.counter_add("engine.shuffle_bytes", d.shuffle_bytes);
        m.counter_add("engine.shuffle_records", d.shuffle_records);
        m.counter_add("engine.cache_hits", d.cache_hits);
        m.counter_add("engine.cache_misses", d.cache_misses);
        m.counter_add("engine.plan_rewrites", d.plan_rewrites);
        m.counter_add("engine.spill_bytes", d.spill_bytes);
        m.counter_add("engine.spill_files", d.spill_files);
        m.counter_add("engine.sort_runs", d.sort_runs);
        m.counter_add("engine.sort_spill_bytes", d.sort_spill_bytes);
        m.counter_add("engine.vectorized_batches", d.vectorized_batches);
        m.counter_add("engine.vectorized_fallbacks", d.vectorized_fallbacks);
        m.counter_add("engine.vectorized_shuffle_batches", d.vectorized_shuffle_batches);
        m.counter_add("engine.vectorized_shuffle_fallbacks", d.vectorized_shuffle_fallbacks);
        m.counter_add("engine.analyzer_errors", d.analyzer_errors);
        m.counter_add("engine.analyzer_warnings", d.analyzer_warnings);
        m.counter_add("engine.analyzer_notes", d.analyzer_notes);
        m.counter_add("engine.dist_tasks_remote", d.dist_tasks_remote);
        m.counter_add("engine.dist_fallbacks", d.dist_fallbacks);
        m.counter_add("engine.dist_bytes_tx", d.dist_bytes_tx);
        m.counter_add("engine.dist_bytes_rx", d.dist_bytes_rx);
        m.counter_add("engine.dist_workers_lost", d.dist_workers_lost);
        m.gauge_set(
            "engine.memory.reserved_bytes",
            engine.governor.reserved_bytes() as f64,
        );

        // per-stage attribution gauges from the tracer; the rollup is
        // empty when tracing is disabled, so this is a no-op by default
        for st in engine.tracer.stage_rollup() {
            m.gauge_set(&format!("engine.stage.{}.seconds", st.name), st.wall_secs);
            m.gauge_set(
                &format!("engine.stage.{}.task_seconds", st.name),
                st.counters.stats.task_nanos as f64 / 1e9,
            );
            m.gauge_set(
                &format!("engine.stage.{}.rows_read", st.name),
                st.counters.stats.rows_read as f64,
            );
            m.gauge_set(
                &format!("engine.stage.{}.spill_bytes", st.name),
                st.counters.stats.spill_bytes as f64,
            );
        }

        // cache-manager counters (entry-level hits + byte-budget
        // evictions) and residency gauges
        let hits = engine.cache.hits();
        m.counter_add(
            "engine.cache.entry_hits",
            hits.saturating_sub(self.last_cache_entry_hits),
        );
        self.last_cache_entry_hits = hits;
        let ev = engine.cache.evictions();
        m.counter_add(
            "engine.cache.evictions",
            ev.saturating_sub(self.last_cache_evictions),
        );
        self.last_cache_evictions = ev;
        m.gauge_set("engine.cache.used_bytes", engine.cache.used_bytes() as f64);
        m.gauge_set("engine.cache.entries", engine.cache.len() as f64);

        // fault injector (when armed)
        if let Some(fault) = &engine.fault {
            let inj = fault.injected_count();
            m.counter_add(
                "engine.fault.injected",
                inj.saturating_sub(self.last_fault_injected),
            );
            self.last_fault_injected = inj;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::dataset::Dataset;
    use crate::engine::executor::EngineConfig;
    use crate::engine::fault::FaultInjector;
    use crate::engine::row::Schema;
    use crate::row;

    fn nums(n: i64) -> Dataset {
        let schema = Schema::of_names(&["x"]);
        Dataset::from_rows("n", schema, (0..n).map(|i| row!(i)).collect(), 8)
    }

    #[test]
    fn deltas_accumulate_not_double_count() {
        let c = EngineCtx::new(EngineConfig { workers: 2, ..Default::default() });
        let m = MetricsRegistry::new();
        let mut ex = EngineMetricsExporter::new();
        let ds = nums(20);
        c.count(&ds.map(ds.schema.clone(), |r| r.clone())).unwrap();
        ex.publish(&m, &c);
        let first = m.counter("engine.tasks_launched");
        assert!(first > 0);
        // publishing again with no work adds nothing
        ex.publish(&m, &c);
        assert_eq!(m.counter("engine.tasks_launched"), first);
        // more work adds only the delta
        c.count(&ds.filter(|_| true)).unwrap();
        ex.publish(&m, &c);
        assert!(m.counter("engine.tasks_launched") > first);
    }

    #[test]
    fn vectorized_counters_surface() {
        use crate::engine::expr::{BinOp, Expr};
        use crate::engine::row::Field;
        let c = EngineCtx::new(EngineConfig { workers: 2, vectorize: true, ..Default::default() });
        let m = MetricsRegistry::new();
        let mut ex = EngineMetricsExporter::new();
        let ds = nums(100);
        let pred = Expr::Binary(
            BinOp::Ge,
            Box::new(Expr::Col(0, "x".into())),
            Box::new(Expr::Lit(Field::I64(10))),
        );
        c.count(&ds.filter_expr(pred)).unwrap();
        ex.publish(&m, &c);
        assert!(m.counter("engine.vectorized_batches") > 0, "columnar batches must surface");
        assert_eq!(m.counter("engine.vectorized_fallbacks"), 0);
        // a column-keyed wide op surfaces the batch-native shuffle counters
        c.count(&ds.reduce_by_key_col(2, 0, |acc, _| acc)).unwrap();
        ex.publish(&m, &c);
        assert!(
            m.counter("engine.vectorized_shuffle_batches") > 0,
            "batch-native shuffle must surface"
        );
        assert_eq!(m.counter("engine.vectorized_shuffle_fallbacks"), 0);
    }

    #[test]
    fn spill_counters_surface_under_forced_spill() {
        let c = EngineCtx::new(EngineConfig {
            workers: 2,
            memory_budget_bytes: Some(512),
            ..Default::default()
        });
        let m = MetricsRegistry::new();
        let mut ex = EngineMetricsExporter::new();
        let ds = nums(500);
        c.count(&ds.distinct(4)).unwrap();
        ex.publish(&m, &c);
        assert!(m.counter("engine.spill_bytes") > 0, "forced spill must surface");
        assert!(m.counter("engine.spill_files") > 0);
        assert_eq!(m.gauge("engine.memory.reserved_bytes"), 0.0, "idle engine holds nothing");
    }

    #[test]
    fn sort_counters_surface_under_forced_spill() {
        let c = EngineCtx::new(EngineConfig {
            workers: 2,
            memory_budget_bytes: Some(512),
            ..Default::default()
        });
        let m = MetricsRegistry::new();
        let mut ex = EngineMetricsExporter::new();
        let ds = nums(2000);
        c.collect(&ds.sort_by(|a, b| a.get(0).canonical_cmp(b.get(0))))
            .unwrap();
        ex.publish(&m, &c);
        assert!(m.counter("engine.sort_runs") > 0, "sort must report its runs");
        assert!(
            m.counter("engine.sort_spill_bytes") > 0,
            "a 512-byte budget must spill sort runs"
        );
        assert!(m.counter("engine.spill_bytes") >= m.counter("engine.sort_spill_bytes"));
    }

    #[test]
    fn cache_and_fault_counters_surface() {
        let cfg = EngineConfig { workers: 2, max_task_attempts: 4, ..Default::default() };
        // prob 0.9, at most 1 failed attempt per task: across 8 map tasks
        // an injection is certain in practice, and every task succeeds by
        // its second attempt
        let c = EngineCtx::with_faults(cfg, FaultInjector::new(7, 0.9, 1));
        let m = MetricsRegistry::new();
        let mut ex = EngineMetricsExporter::new();
        let ds = nums(50);
        let mapped = ds.map(ds.schema.clone(), |r| r.clone());
        c.persist(&mapped);
        c.count(&mapped).unwrap();
        c.count(&mapped.filter(|_| true)).unwrap(); // cache hit
        ex.publish(&m, &c);
        assert!(m.counter("engine.cache.entry_hits") >= 1);
        assert!(m.counter("engine.fault.injected") >= 1);
        assert!(m.gauge("engine.cache.entries") >= 1.0);
        assert!(m.gauge("engine.cache.used_bytes") > 0.0);
    }
}
