//! Metrics & monitoring (paper §3.3.4): pipes record counters/gauges/
//! histograms into a shared registry; an asynchronous publisher thread
//! snapshots and ships them to a sink at a configurable cadence (30 s by
//! default, matching the paper) without any involvement from pipe code.

pub mod engine_export;
pub mod publisher;
pub mod registry;

pub use engine_export::EngineMetricsExporter;
pub use publisher::{LogSink, MemorySink, MetricsPublisher, PublisherConfig, Sink, StorageSink};
pub use registry::{HistogramSummary, MetricsRegistry, MetricsSnapshot};
