//! Self-contained crypto primitives: SHA-256 (FIPS 180-4), HMAC-SHA256
//! (RFC 2104), and the AES-128 block cipher (FIPS 197, encrypt-only —
//! CTR mode needs only the forward direction).
//!
//! These replace the `sha2`/`hmac`/`aes` crates, which are not in the
//! offline vendor set. Implementations are checked against the published
//! test vectors (FIPS 180-4 "abc", RFC 4231 cases, FIPS 197 appendix C)
//! in the tests below.

/// SHA-256 round constants (fractional parts of the cube roots of the
/// first 64 primes).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
    0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
    0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
    0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state (fractional parts of the square roots of the first
/// 8 primes).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
    0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// SHA-256 digest of `msg`.
pub fn sha256(msg: &[u8]) -> [u8; 32] {
    let mut h = H0;
    let bit_len = (msg.len() as u64).wrapping_mul(8);

    // padded message: msg || 0x80 || zeros || 64-bit big-endian length
    let mut padded = Vec::with_capacity(msg.len() + 72);
    padded.extend_from_slice(msg);
    padded.push(0x80);
    while padded.len() % 64 != 56 {
        padded.push(0);
    }
    padded.extend_from_slice(&bit_len.to_be_bytes());

    let mut w = [0u32; 64];
    for block in padded.chunks_exact(64) {
        for i in 0..16 {
            w[i] = u32::from_be_bytes([
                block[4 * i],
                block[4 * i + 1],
                block[4 * i + 2],
                block[4 * i + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }

    let mut out = [0u8; 32];
    for (i, word) in h.iter().enumerate() {
        out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
    }
    out
}

/// HMAC-SHA256 over `msg` with `key` (any key length).
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    const BLOCK: usize = 64;
    let mut k = [0u8; BLOCK];
    if key.len() > BLOCK {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut inner = Vec::with_capacity(BLOCK + msg.len());
    inner.extend(k.iter().map(|b| b ^ 0x36));
    inner.extend_from_slice(msg);
    let inner_hash = sha256(&inner);
    let mut outer = Vec::with_capacity(BLOCK + 32);
    outer.extend(k.iter().map(|b| b ^ 0x5c));
    outer.extend_from_slice(&inner_hash);
    sha256(&outer)
}

/// Constant-time equality for MAC tags.
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    a.iter().zip(b).fold(0u8, |acc, (x, y)| acc | (x ^ y)) == 0
}

/// AES S-box (multiplicative inverse in GF(2^8) + affine transform).
const SBOX: [u8; 256] = [
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b,
    0xfe, 0xd7, 0xab, 0x76, 0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0,
    0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4, 0x72, 0xc0, 0xb7, 0xfd, 0x93, 0x26,
    0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15,
    0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2,
    0xeb, 0x27, 0xb2, 0x75, 0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0,
    0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84, 0x53, 0xd1, 0x00, 0xed,
    0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf,
    0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f,
    0x50, 0x3c, 0x9f, 0xa8, 0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5,
    0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2, 0xcd, 0x0c, 0x13, 0xec,
    0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73,
    0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee, 0xb8, 0x14,
    0xde, 0x5e, 0x0b, 0xdb, 0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c,
    0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79, 0xe7, 0xc8, 0x37, 0x6d,
    0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08,
    0xba, 0x78, 0x25, 0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f,
    0x4b, 0xbd, 0x8b, 0x8a, 0x70, 0x3e, 0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e,
    0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e, 0xe1, 0xf8, 0x98, 0x11,
    0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f,
    0xb0, 0x54, 0xbb, 0x16,
];

const RCON: [u8; 10] = [0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1b, 0x36];

/// Multiply in GF(2^8) with the AES reduction polynomial x^8+x^4+x^3+x+1.
#[inline]
fn gmul(mut a: u8, mut b: u8) -> u8 {
    let mut p = 0u8;
    for _ in 0..8 {
        if b & 1 != 0 {
            p ^= a;
        }
        let hi = a & 0x80;
        a <<= 1;
        if hi != 0 {
            a ^= 0x1b;
        }
        b >>= 1;
    }
    p
}

/// AES-128 with a precomputed key schedule (11 round keys × 16 bytes,
/// column-major like the FIPS 197 state).
pub struct Aes128 {
    round_keys: [[u8; 4]; 44],
}

impl Aes128 {
    pub fn new(key: &[u8; 16]) -> Aes128 {
        let mut w = [[0u8; 4]; 44];
        for i in 0..4 {
            w[i].copy_from_slice(&key[4 * i..4 * i + 4]);
        }
        for i in 4..44 {
            let mut t = w[i - 1];
            if i % 4 == 0 {
                t.rotate_left(1);
                for b in t.iter_mut() {
                    *b = SBOX[*b as usize];
                }
                t[0] ^= RCON[i / 4 - 1];
            }
            for j in 0..4 {
                w[i][j] = w[i - 4][j] ^ t[j];
            }
        }
        Aes128 { round_keys: w }
    }

    /// Encrypt one 16-byte block in place.
    pub fn encrypt_block(&self, block: &mut [u8; 16]) {
        let s = block; // state: s[4*col + row]
        self.add_round_key(s, 0);
        for round in 1..10 {
            sub_bytes(s);
            shift_rows(s);
            mix_columns(s);
            self.add_round_key(s, round);
        }
        sub_bytes(s);
        shift_rows(s);
        self.add_round_key(s, 10);
    }

    fn add_round_key(&self, s: &mut [u8; 16], round: usize) {
        for c in 0..4 {
            for r in 0..4 {
                s[4 * c + r] ^= self.round_keys[4 * round + c][r];
            }
        }
    }
}

fn sub_bytes(s: &mut [u8; 16]) {
    for b in s.iter_mut() {
        *b = SBOX[*b as usize];
    }
}

fn shift_rows(s: &mut [u8; 16]) {
    for r in 1..4 {
        let row = [s[r], s[4 + r], s[8 + r], s[12 + r]];
        for c in 0..4 {
            s[4 * c + r] = row[(c + r) % 4];
        }
    }
}

fn mix_columns(s: &mut [u8; 16]) {
    for c in 0..4 {
        let a = [s[4 * c], s[4 * c + 1], s[4 * c + 2], s[4 * c + 3]];
        s[4 * c] = gmul(a[0], 2) ^ gmul(a[1], 3) ^ a[2] ^ a[3];
        s[4 * c + 1] = a[0] ^ gmul(a[1], 2) ^ gmul(a[2], 3) ^ a[3];
        s[4 * c + 2] = a[0] ^ a[1] ^ gmul(a[2], 2) ^ gmul(a[3], 3);
        s[4 * c + 3] = gmul(a[0], 3) ^ a[1] ^ a[2] ^ gmul(a[3], 2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn sha256_fips_vectors() {
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn sha256_padding_boundaries() {
        // lengths that straddle the 56-byte padding boundary
        for n in [55usize, 56, 63, 64, 65, 119, 120] {
            let msg = vec![b'a'; n];
            let d = sha256(&msg);
            assert_eq!(d.len(), 32);
            // digest must differ across lengths (trivial sanity)
            assert_ne!(hex(&d), hex(&sha256(&vec![b'a'; n + 1])));
        }
    }

    #[test]
    fn hmac_rfc4231_vectors() {
        // case 1
        let d = hmac_sha256(&[0x0b; 20], b"Hi There");
        assert_eq!(
            hex(&d),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        // case 2
        let d = hmac_sha256(b"Jefe", b"what do ya want for nothing?");
        assert_eq!(
            hex(&d),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // case 6: key longer than the block size gets hashed first
        let d = hmac_sha256(
            &[0xaa; 131],
            b"Test Using Larger Than Block-Size Key - Hash Key First",
        );
        assert_eq!(
            hex(&d),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn aes128_fips_vectors() {
        // FIPS 197 appendix C.1
        let key: [u8; 16] = [
            0x00, 0x01, 0x02, 0x03, 0x04, 0x05, 0x06, 0x07, 0x08, 0x09, 0x0a, 0x0b, 0x0c, 0x0d,
            0x0e, 0x0f,
        ];
        let mut block: [u8; 16] = [
            0x00, 0x11, 0x22, 0x33, 0x44, 0x55, 0x66, 0x77, 0x88, 0x99, 0xaa, 0xbb, 0xcc, 0xdd,
            0xee, 0xff,
        ];
        Aes128::new(&key).encrypt_block(&mut block);
        assert_eq!(hex(&block), "69c4e0d86a7b0430d8cdb78070b4c55a");

        // FIPS 197 appendix B
        let key: [u8; 16] = [
            0x2b, 0x7e, 0x15, 0x16, 0x28, 0xae, 0xd2, 0xa6, 0xab, 0xf7, 0x15, 0x88, 0x09, 0xcf,
            0x4f, 0x3c,
        ];
        let mut block: [u8; 16] = [
            0x32, 0x43, 0xf6, 0xa8, 0x88, 0x5a, 0x30, 0x8d, 0x31, 0x31, 0x98, 0xa2, 0xe0, 0x37,
            0x07, 0x34,
        ];
        Aes128::new(&key).encrypt_block(&mut block);
        assert_eq!(hex(&block), "3925841d02dc09fbdc118597196a0b32");
    }

    #[test]
    fn ct_eq_behaves() {
        assert!(ct_eq(b"same", b"same"));
        assert!(!ct_eq(b"same", b"sama"));
        assert!(!ct_eq(b"short", b"longer"));
    }
}
