//! Key hierarchy: master → service / dataset → record keys, derived with
//! HMAC-SHA256 (HKDF-expand style, single block — 16-byte AES keys).

use super::crypto::hmac_sha256;

/// 16-byte AES-128 key material.
#[derive(Clone, PartialEq, Eq)]
pub struct Key(pub [u8; 16]);

impl std::fmt::Debug for Key {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Key(****)") // never print key material
    }
}

/// Root secret for a deployment.
#[derive(Clone)]
pub struct MasterKey(Key);

impl MasterKey {
    /// Derive a master key from a passphrase (PBKDF-light: HMAC chain; the
    /// sim has no KMS, this stands in for envelope key fetch).
    pub fn from_passphrase(pass: &str) -> MasterKey {
        MasterKey(derive(&Key([0x5a; 16]), &format!("master:{pass}")))
    }

    pub fn from_bytes(bytes: [u8; 16]) -> MasterKey {
        MasterKey(Key(bytes))
    }
}

/// Derive a subkey from a parent key and a context label.
pub fn derive(parent: &Key, context: &str) -> Key {
    let out = hmac_sha256(&parent.0, context.as_bytes());
    let mut k = [0u8; 16];
    k.copy_from_slice(&out[..16]);
    Key(k)
}

/// The deployment's key chain (paper: "sophisticated encryption management
/// system" behind declarative config).
pub struct KeyChain {
    master: MasterKey,
}

impl KeyChain {
    pub fn new(master: MasterKey) -> KeyChain {
        KeyChain { master }
    }

    /// Single service-wide key (service-side encryption).
    pub fn service_key(&self) -> Key {
        derive(&self.master.0, "service")
    }

    /// Per-dataset key (dataset-level client-side encryption).
    pub fn dataset_key(&self, dataset_id: &str) -> Key {
        derive(&self.master.0, &format!("dataset:{dataset_id}"))
    }

    /// Per-record key (record-level client-side encryption).
    pub fn record_key(&self, dataset_id: &str, record_id: &str) -> Key {
        derive(&self.dataset_key(dataset_id), &format!("record:{record_id}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derivation_deterministic_and_distinct() {
        let c = KeyChain::new(MasterKey::from_passphrase("p"));
        assert_eq!(c.service_key().0, c.service_key().0);
        assert_ne!(c.service_key().0, c.dataset_key("a").0);
        assert_ne!(c.dataset_key("a").0, c.dataset_key("b").0);
        assert_ne!(c.record_key("a", "1").0, c.record_key("a", "2").0);
    }

    #[test]
    fn different_passphrases_different_keys() {
        let a = KeyChain::new(MasterKey::from_passphrase("a"));
        let b = KeyChain::new(MasterKey::from_passphrase("b"));
        assert_ne!(a.service_key().0, b.service_key().0);
    }

    #[test]
    fn debug_hides_material() {
        let k = derive(&Key([1; 16]), "x");
        assert_eq!(format!("{k:?}"), "Key(****)");
    }
}
