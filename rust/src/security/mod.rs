//! Declarative encryption (paper §3.3.3).
//!
//! Three security models, selected per-dataset in the `DataDeclare`:
//!
//! * **service-side** — every dataset under one service master key;
//! * **dataset-level client-side** — a distinct key per dataset, derived
//!   from the master key by HKDF-style expansion over the dataset id;
//! * **record-level client-side** — a distinct key per record, derived
//!   from the dataset key over the record index.
//!
//! Cipher: AES-128-CTR with an HMAC-SHA256 tag (encrypt-then-MAC). Nonce
//! is random per blob and stored in the envelope. The infrastructure (not
//! pipe code) performs all encryption — pipes only ever see plaintext
//! rows, which is the paper's separation-of-concerns claim.

pub mod crypto;
pub mod envelope;
pub mod keys;

pub use envelope::{decrypt, encrypt};
pub use keys::{KeyChain, MasterKey};

use crate::util::error::{DdpError, Result};

/// Declarative encryption mode, as named in the data specification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EncryptionMode {
    None,
    ServiceSide,
    DatasetLevel,
    RecordLevel,
}

impl EncryptionMode {
    pub fn parse(s: &str) -> Result<EncryptionMode> {
        Ok(match s {
            "" | "none" => EncryptionMode::None,
            "service" | "service-side" => EncryptionMode::ServiceSide,
            "dataset" | "dataset-level" => EncryptionMode::DatasetLevel,
            "record" | "record-level" => EncryptionMode::RecordLevel,
            other => {
                return Err(DdpError::security(format!("unknown encryption mode '{other}'")))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EncryptionMode::None => "none",
            EncryptionMode::ServiceSide => "service-side",
            EncryptionMode::DatasetLevel => "dataset-level",
            EncryptionMode::RecordLevel => "record-level",
        }
    }
}

/// Encrypt a serialized dataset blob according to the mode.
pub fn encrypt_blob(
    chain: &KeyChain,
    mode: EncryptionMode,
    dataset_id: &str,
    blob: &[u8],
) -> Result<Vec<u8>> {
    match mode {
        EncryptionMode::None => Ok(blob.to_vec()),
        EncryptionMode::ServiceSide => encrypt(&chain.service_key(), blob),
        EncryptionMode::DatasetLevel => encrypt(&chain.dataset_key(dataset_id), blob),
        EncryptionMode::RecordLevel => {
            // record-level applies per line (JSONL-shaped payloads); each
            // record gets its own derived key so a single compromised
            // record key reveals nothing else.
            let dk = chain.dataset_key(dataset_id);
            let mut out = Vec::new();
            for (i, line) in blob.split(|&b| b == b'\n').enumerate() {
                if line.is_empty() {
                    continue;
                }
                let rk = keys::derive(&dk, &format!("record:{i}"));
                let ct = encrypt(&rk, line)?;
                out.extend_from_slice(hex(&ct).as_bytes());
                out.push(b'\n');
            }
            Ok(out)
        }
    }
}

/// Inverse of [`encrypt_blob`].
pub fn decrypt_blob(
    chain: &KeyChain,
    mode: EncryptionMode,
    dataset_id: &str,
    blob: &[u8],
) -> Result<Vec<u8>> {
    match mode {
        EncryptionMode::None => Ok(blob.to_vec()),
        EncryptionMode::ServiceSide => decrypt(&chain.service_key(), blob),
        EncryptionMode::DatasetLevel => decrypt(&chain.dataset_key(dataset_id), blob),
        EncryptionMode::RecordLevel => {
            let dk = chain.dataset_key(dataset_id);
            let mut out = Vec::new();
            for (i, line) in blob.split(|&b| b == b'\n').enumerate() {
                if line.is_empty() {
                    continue;
                }
                let rk = keys::derive(&dk, &format!("record:{i}"));
                let ct = unhex(line)?;
                out.extend_from_slice(&decrypt(&rk, &ct)?);
                out.push(b'\n');
            }
            Ok(out)
        }
    }
}

fn hex(b: &[u8]) -> String {
    b.iter().map(|x| format!("{x:02x}")).collect()
}

fn unhex(s: &[u8]) -> Result<Vec<u8>> {
    let s = std::str::from_utf8(s).map_err(|_| DdpError::security("bad hex"))?;
    if s.len() % 2 != 0 {
        return Err(DdpError::security("odd hex length"));
    }
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).map_err(|_| DdpError::security("bad hex")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> KeyChain {
        KeyChain::new(MasterKey::from_passphrase("test-master"))
    }

    #[test]
    fn all_modes_roundtrip() {
        let c = chain();
        let blob = b"line one\nline two\nline three\n";
        for mode in [
            EncryptionMode::None,
            EncryptionMode::ServiceSide,
            EncryptionMode::DatasetLevel,
            EncryptionMode::RecordLevel,
        ] {
            let ct = encrypt_blob(&c, mode, "ds1", blob).unwrap();
            if mode != EncryptionMode::None {
                assert_ne!(&ct[..], &blob[..], "{} should not be plaintext", mode.name());
            }
            let pt = decrypt_blob(&c, mode, "ds1", &ct).unwrap();
            assert_eq!(pt, blob);
        }
    }

    #[test]
    fn dataset_keys_differ() {
        let c = chain();
        let ct1 = encrypt_blob(&c, EncryptionMode::DatasetLevel, "ds1", b"same").unwrap();
        // decrypting with the wrong dataset id must fail authentication
        assert!(decrypt_blob(&c, EncryptionMode::DatasetLevel, "ds2", &ct1).is_err());
    }

    #[test]
    fn tamper_detected() {
        let c = chain();
        let mut ct = encrypt_blob(&c, EncryptionMode::ServiceSide, "x", b"payload").unwrap();
        let n = ct.len();
        ct[n - 1] ^= 1;
        assert!(decrypt_blob(&c, EncryptionMode::ServiceSide, "x", &ct).is_err());
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(EncryptionMode::parse("record-level").unwrap(), EncryptionMode::RecordLevel);
        assert_eq!(EncryptionMode::parse("").unwrap(), EncryptionMode::None);
        assert!(EncryptionMode::parse("rot13").is_err());
    }
}
