//! AES-128-CTR + HMAC-SHA256 encrypt-then-MAC envelope.
//!
//! Layout: `nonce[16] || ciphertext || tag[32]`, where the tag
//! authenticates nonce+ciphertext under a MAC key derived from the data
//! key (distinct derivation contexts for cipher and MAC).

use super::crypto::{ct_eq, hmac_sha256, Aes128};
use super::keys::{derive, Key};

use crate::util::error::{DdpError, Result};
use std::sync::atomic::{AtomicU64, Ordering};

const TAG_LEN: usize = 32;
const NONCE_LEN: usize = 16;

static NONCE_COUNTER: AtomicU64 = AtomicU64::new(1);

/// Process-unique nonce: 8 random-ish bytes (address-space entropy +
/// time) plus a monotone counter. CTR security needs uniqueness, not
/// unpredictability.
fn fresh_nonce() -> [u8; NONCE_LEN] {
    let mut n = [0u8; NONCE_LEN];
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let c = NONCE_COUNTER.fetch_add(1, Ordering::Relaxed);
    n[..8].copy_from_slice(&t.to_le_bytes());
    n[8..].copy_from_slice(&c.to_le_bytes());
    n
}

fn ctr_xor(key: &Key, nonce: &[u8; NONCE_LEN], data: &mut [u8]) {
    let cipher = Aes128::new(&key.0);
    let mut counter_block = *nonce;
    let mut offset = 0usize;
    let mut ctr: u64 = 0;
    while offset < data.len() {
        // counter in the last 8 bytes, big endian (nonce provides the rest)
        counter_block[8..].copy_from_slice(&ctr.to_be_bytes());
        let mut block = counter_block;
        cipher.encrypt_block(&mut block);
        let n = (data.len() - offset).min(16);
        for i in 0..n {
            data[offset + i] ^= block[i];
        }
        offset += n;
        ctr += 1;
    }
}

/// Encrypt-then-MAC.
pub fn encrypt(key: &Key, plaintext: &[u8]) -> Result<Vec<u8>> {
    let enc_key = derive(key, "enc");
    let mac_key = derive(key, "mac");
    let nonce = fresh_nonce();
    let mut ct = plaintext.to_vec();
    ctr_xor(&enc_key, &nonce, &mut ct);

    let mut out = Vec::with_capacity(NONCE_LEN + ct.len() + TAG_LEN);
    out.extend_from_slice(&nonce);
    out.extend_from_slice(&ct);
    let tag = hmac_sha256(&mac_key.0, &out);
    out.extend_from_slice(&tag);
    Ok(out)
}

/// Verify tag, then decrypt.
pub fn decrypt(key: &Key, envelope: &[u8]) -> Result<Vec<u8>> {
    if envelope.len() < NONCE_LEN + TAG_LEN {
        return Err(DdpError::security("envelope too short"));
    }
    let enc_key = derive(key, "enc");
    let mac_key = derive(key, "mac");
    let (body, tag) = envelope.split_at(envelope.len() - TAG_LEN);
    let expected = hmac_sha256(&mac_key.0, body);
    if !ct_eq(&expected, tag) {
        return Err(DdpError::security(
            "authentication failed (wrong key or tampered data)",
        ));
    }

    let mut nonce = [0u8; NONCE_LEN];
    nonce.copy_from_slice(&body[..NONCE_LEN]);
    let mut pt = body[NONCE_LEN..].to_vec();
    ctr_xor(&enc_key, &nonce, &mut pt);
    Ok(pt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::property;

    fn key() -> Key {
        Key([7u8; 16])
    }

    #[test]
    fn roundtrip_various_sizes() {
        for n in [0usize, 1, 15, 16, 17, 100, 4096] {
            let pt: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
            let ct = encrypt(&key(), &pt).unwrap();
            assert_eq!(decrypt(&key(), &ct).unwrap(), pt, "size {n}");
        }
    }

    #[test]
    fn nonces_unique_so_ciphertexts_differ() {
        let a = encrypt(&key(), b"same plaintext").unwrap();
        let b = encrypt(&key(), b"same plaintext").unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn wrong_key_fails() {
        let ct = encrypt(&key(), b"data").unwrap();
        assert!(decrypt(&Key([8u8; 16]), &ct).is_err());
    }

    #[test]
    fn bit_flip_anywhere_fails() {
        let ct = encrypt(&key(), b"some data to protect").unwrap();
        for i in (0..ct.len()).step_by(7) {
            let mut t = ct.clone();
            t[i] ^= 0x40;
            assert!(decrypt(&key(), &t).is_err(), "flip at {i} not detected");
        }
    }

    #[test]
    fn too_short_envelope_rejected() {
        assert!(decrypt(&key(), &[0u8; 10]).is_err());
    }

    #[test]
    fn prop_roundtrip() {
        property(60, |g| {
            let pt: Vec<u8> = (0..g.usize(200)).map(|_| g.u64(256) as u8).collect();
            let ct = encrypt(&key(), &pt).unwrap();
            assert_eq!(decrypt(&key(), &ct).unwrap(), pt);
        });
    }
}
