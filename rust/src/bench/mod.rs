//! Custom bench harness (criterion is unavailable offline): timing,
//! stats, Markdown tables saved under `bench_results/`.

pub mod harness;

pub use harness::{measure, measure_once, ratio, BenchStats, JsonRecorder, Table};
