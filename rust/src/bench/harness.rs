//! Timing harness for `cargo bench` targets (criterion is not in the
//! offline vendor set): warmup + N samples, mean/p50/p95, and Markdown /
//! CSV table output so every bench prints the paper-table rows it
//! regenerates.

use std::time::Instant;

/// Summary statistics over bench samples.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub samples: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

/// Run `f` for `warmup` unmeasured + `samples` measured iterations.
pub fn measure<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    stats_from(&mut times)
}

/// Single timed run (for expensive end-to-end cases).
pub fn measure_once<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

fn stats_from(times: &mut [f64]) -> BenchStats {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = times.len();
    let q = |p: f64| times[((n - 1) as f64 * p).round() as usize];
    BenchStats {
        samples: n,
        mean_secs: times.iter().sum::<f64>() / n as f64,
        p50_secs: q(0.5),
        p95_secs: q(0.95),
        min_secs: times[0],
        max_secs: times[n - 1],
    }
}

/// Markdown table writer used by every bench binary.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Print as Markdown (and return the string for logging/files).
    pub fn print(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        println!("{out}");
        out
    }

    /// Append the rendered table to `bench_results/<name>.md`.
    pub fn save(&self, name: &str) {
        let rendered = self.print();
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(dir.join(format!("{name}.md")), rendered);
    }
}

/// Machine-readable companion to [`Table`]: collects one record per
/// bench case and writes `bench_results/BENCH_<name>.json` so CI and
/// regression tooling can diff runs without scraping Markdown. The
/// output carries no timestamps — identical runs produce identical
/// bytes.
pub struct JsonRecorder {
    name: String,
    smoke: bool,
    cases: Vec<crate::json::Value>,
}

impl JsonRecorder {
    pub fn new(name: &str, smoke: bool) -> JsonRecorder {
        JsonRecorder { name: name.to_string(), smoke, cases: Vec::new() }
    }

    /// Record one case: its wall clock plus any counters worth diffing.
    pub fn case(&mut self, case: &str, wall_secs: f64, counters: &[(&str, f64)]) {
        use crate::json::Value;
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("case".to_string(), Value::Str(case.to_string()));
        obj.insert("wall_secs".to_string(), Value::Num(wall_secs));
        let mut cs = std::collections::BTreeMap::new();
        for (k, v) in counters {
            cs.insert(k.to_string(), Value::Num(*v));
        }
        obj.insert("counters".to_string(), Value::Obj(cs));
        self.cases.push(Value::Obj(obj));
    }

    /// Render the collected cases as one JSON document.
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::Value;
        Value::obj(vec![
            ("bench", Value::Str(self.name.clone())),
            ("smoke", Value::Bool(self.smoke)),
            ("cases", Value::Arr(self.cases.clone())),
        ])
    }

    /// Write `bench_results/BENCH_<name>.json` (best effort, like
    /// [`Table::save`]).
    pub fn save(&self) {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_results");
        let _ = std::fs::create_dir_all(&dir);
        let text = crate::json::to_string_pretty(&self.to_json());
        let _ = std::fs::write(dir.join(format!("BENCH_{}.json", self.name)), text);
    }
}

/// `1.23x` style ratio formatting.
pub fn ratio(a: f64, b: f64) -> String {
    if b <= 0.0 {
        return "n/a".into();
    }
    format!("{:.1}x", a / b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_ordered_stats() {
        let mut i = 0u64;
        let s = measure(2, 10, || {
            i += 1;
            std::hint::black_box(i);
        });
        assert_eq!(s.samples, 10);
        assert!(s.min_secs <= s.p50_secs && s.p50_secs <= s.max_secs);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.print();
        assert!(md.contains("## Demo"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn ratio_formats() {
        assert_eq!(ratio(10.0, 2.0), "5.0x");
        assert_eq!(ratio(1.0, 0.0), "n/a");
    }

    #[test]
    fn json_recorder_shape_is_deterministic() {
        let mut r = JsonRecorder::new("demo", true);
        r.case("warm", 1.5, &[("rows", 10.0)]);
        r.case("cold", 2.0, &[]);
        let j = r.to_json();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("demo"));
        assert_eq!(j.get("smoke").unwrap().as_bool(), Some(true));
        let cases = j.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].get("case").unwrap().as_str(), Some("warm"));
        assert_eq!(cases[0].get("counters").unwrap().get("rows").unwrap().as_f64(), Some(10.0));
        // identical recordings render to identical bytes (no timestamps)
        let mut r2 = JsonRecorder::new("demo", true);
        r2.case("warm", 1.5, &[("rows", 10.0)]);
        r2.case("cold", 2.0, &[]);
        assert_eq!(
            crate::json::to_string_pretty(&r.to_json()),
            crate::json::to_string_pretty(&r2.to_json())
        );
    }
}
