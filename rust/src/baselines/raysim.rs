//! Ray-style execution baseline (Table 4 / Fig 5 comparator).
//!
//! Models what made the paper's Ray implementation slower than DDP:
//! every task's inputs/outputs pass through an *object store* with
//! serialization on both sides (we really serialize to JSONL and parse it
//! back — honest CPU cost, not a constant), plus a per-task scheduler
//! dispatch overhead (accounted, since wall-sleeping on 1 core would
//! measure nothing). DDP by contrast chains stages through memory.

use crate::corpus::web::Doc;
use crate::ml::embedded::LangDetector;
use crate::pipes::preprocess::clean_text;
use crate::util::error::{DdpError, Result};
use crate::util::fnv1a64;
use std::collections::{HashMap, HashSet};

/// Cost model knobs.
#[derive(Debug, Clone)]
pub struct RaySimConfig {
    /// docs per task (Ray tasks are sized by the user; paper used batches)
    pub batch_per_task: usize,
    /// accounted scheduler dispatch cost per task
    pub sched_overhead_secs: f64,
}

impl Default for RaySimConfig {
    fn default() -> Self {
        RaySimConfig { batch_per_task: 256, sched_overhead_secs: 0.010 }
    }
}

/// Outcome of a ray-sim run.
#[derive(Debug, Clone)]
pub struct RaySimReport {
    pub docs_in: usize,
    pub docs_after_dedup: usize,
    pub lang_counts: HashMap<String, usize>,
    /// real CPU seconds spent serializing/deserializing through the
    /// simulated object store
    pub object_store_secs: f64,
    /// accounted scheduler overhead
    pub sched_secs: f64,
    pub tasks: usize,
    pub total_secs: f64,
    /// the serial driver-gather portion (dedup): does NOT parallelize —
    /// the Amdahl term in the Fig 5 extrapolation
    pub gather_secs: f64,
}

/// Serialize docs to the "object store" (JSONL bytes) — real work.
fn put(docs: &[(i64, String)]) -> Vec<u8> {
    let mut out = String::new();
    for (id, text) in docs {
        let obj = crate::json::Value::obj(vec![
            ("id", crate::json::Value::Num(*id as f64)),
            ("text", crate::json::Value::Str(text.clone())),
        ]);
        out.push_str(&crate::json::to_string(&obj));
        out.push('\n');
    }
    out.into_bytes()
}

/// Fetch + deserialize from the object store — real work.
fn get(bytes: &[u8]) -> Result<Vec<(i64, String)>> {
    let text = std::str::from_utf8(bytes).map_err(|_| DdpError::other("bad utf8"))?;
    let mut out = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        let v = crate::json::parse(line)?;
        out.push((
            v.get("id").and_then(|x| x.as_i64()).unwrap_or(0),
            v.str_or("text", ""),
        ));
    }
    Ok(out)
}

/// Run the language-detection pipeline Ray-style.
pub fn run(detector: &LangDetector, docs: &[Doc], cfg: &RaySimConfig) -> Result<RaySimReport> {
    let t_total = std::time::Instant::now();
    let mut store_secs = 0.0;
    let mut tasks = 0usize;

    // driver puts the input into the object store in task-sized chunks
    let raw: Vec<(i64, String)> = docs.iter().map(|d| (d.id, d.text.clone())).collect();
    let mut objects: Vec<Vec<u8>> = Vec::new();
    for chunk in raw.chunks(cfg.batch_per_task) {
        let t0 = std::time::Instant::now();
        objects.push(put(chunk));
        store_secs += t0.elapsed().as_secs_f64();
    }

    // stage 1: clean (task per object: get → compute → put)
    let mut cleaned_objects = Vec::new();
    for obj in &objects {
        tasks += 1;
        let t0 = std::time::Instant::now();
        let input = get(obj)?;
        store_secs += t0.elapsed().as_secs_f64();
        let out: Vec<(i64, String)> = input
            .into_iter()
            .map(|(id, t)| (id, clean_text(&t)))
            .filter(|(_, t)| t.chars().count() >= 4)
            .collect();
        let t0 = std::time::Instant::now();
        cleaned_objects.push(put(&out));
        store_secs += t0.elapsed().as_secs_f64();
    }

    // stage 2: dedup — requires a driver-side gather (Ray's naive path);
    // the whole phase is serial on the driver
    tasks += 1;
    let t_gather = std::time::Instant::now();
    let t0 = std::time::Instant::now();
    let mut all: Vec<(i64, String)> = Vec::new();
    for obj in &cleaned_objects {
        all.extend(get(obj)?);
    }
    store_secs += t0.elapsed().as_secs_f64();
    let mut seen = HashSet::new();
    let mut unique: Vec<(i64, String)> = Vec::new();
    for (id, text) in all {
        if seen.insert(fnv1a64(text.to_lowercase().as_bytes())) {
            unique.push((id, text));
        }
    }
    let docs_after_dedup = unique.len();
    let mut unique_objects = Vec::new();
    for chunk in unique.chunks(cfg.batch_per_task) {
        let t0 = std::time::Instant::now();
        unique_objects.push(put(chunk));
        store_secs += t0.elapsed().as_secs_f64();
    }
    let gather_secs = t_gather.elapsed().as_secs_f64();

    // stage 3: detect (task per object)
    let mut lang_counts: HashMap<String, usize> = HashMap::new();
    for obj in &unique_objects {
        tasks += 1;
        let t0 = std::time::Instant::now();
        let input = get(obj)?;
        store_secs += t0.elapsed().as_secs_f64();
        let texts: Vec<&str> = input.iter().map(|(_, t)| t.as_str()).collect();
        for lang in detector.detect(&texts)? {
            *lang_counts.entry(lang).or_insert(0) += 1;
        }
    }

    Ok(RaySimReport {
        docs_in: docs.len(),
        docs_after_dedup,
        lang_counts,
        object_store_secs: store_secs,
        sched_secs: tasks as f64 * cfg.sched_overhead_secs,
        tasks,
        total_secs: t_total.elapsed().as_secs_f64(),
        gather_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::web::{CorpusGen, LangProfiles};
    use crate::pipes::model_predict::default_artifacts_dir;
    use crate::runtime::ModelRuntime;

    #[test]
    fn raysim_matches_singlethread_semantics() {
        if !std::path::Path::new(&default_artifacts_dir()).join("model_meta.json").exists() {
            return;
        }
        let rt = ModelRuntime::cpu().unwrap();
        let det = LangDetector::load(&rt, default_artifacts_dir()).unwrap();
        let profiles = LangProfiles::load_default().unwrap();
        let docs = CorpusGen { dup_rate: 0.2, ..Default::default() }.generate(&profiles, 150);
        let ray = run(&det, &docs, &RaySimConfig::default()).unwrap();
        let st = crate::baselines::singlethread::run(&det, &docs, 64).unwrap();
        assert_eq!(ray.docs_after_dedup, st.docs_after_dedup);
        assert_eq!(ray.lang_counts, st.lang_counts);
        assert!(ray.object_store_secs > 0.0, "object store must cost something");
        assert!(ray.tasks > 2);
    }

    #[test]
    fn object_store_roundtrip() {
        let docs = vec![(1i64, "héllo \"q\"".to_string()), (2, "".to_string())];
        let bytes = put(&docs);
        let back = get(&bytes).unwrap();
        assert_eq!(back[0].1, "héllo \"q\"");
        assert_eq!(back.len(), 2);
    }
}
