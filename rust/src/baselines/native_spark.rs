//! "Native Spark" monolithic baseline — the Table 3 comparator.
//!
//! Reproduces the anti-patterns the paper's case study replaced:
//!
//! * **19 fused computation units** instead of 10 contract-bounded pipes
//!   (each unit materializes eagerly — no fusion across units);
//! * **driver-side collects** between phases (the monolith passes data
//!   through the driver, which is exactly why its scalability ceiling in
//!   Table 3 was 1 M records while DDP streamed 500 M);
//! * **microservice ML** — model calls pay the REST latency tax;
//! * **no selective caching** — shared intermediates recompute.
//!
//! Two forms: a *real* small-scale implementation (wall-clock benches)
//! and analytic [`StageSpec`] builders that extrapolate both systems to
//! Table 3 scale in virtual time.

use crate::corpus::enterprise::Record;
use crate::engine::cluster::StageSpec;
use crate::ml::microservice::MicroserviceDetector;
use crate::pipes::matching::levenshtein_sim;
use crate::util::error::Result;
use std::collections::HashMap;

/// Report of a real monolithic run.
#[derive(Debug, Clone)]
pub struct NativeRunReport {
    pub records_in: usize,
    pub records_out: usize,
    pub matches: usize,
    /// bytes gathered on the "driver" between phases (the scalability
    /// killer)
    pub peak_driver_bytes: usize,
    pub rest_calls: u64,
    pub total_secs: f64,
}

/// The monolithic enterprise job: validate → normalize → dedupe-by-email
/// → pairwise match within city → score via REST "model" → aggregate.
/// Every phase materializes a full Vec (driver-resident).
pub fn run_native(
    svc: &MicroserviceDetector,
    records: &[Record],
    match_threshold: f64,
) -> Result<NativeRunReport> {
    let t0 = std::time::Instant::now();
    let mut peak = 0usize;
    let mut track = |v: usize| {
        if v > peak {
            peak = v;
        }
    };

    // unit 1-3: validate, trim, lowercase (three separate passes — the
    // monolith grew one pass per bugfix, as monoliths do)
    let step1: Vec<Record> = records.iter().filter(|r| !r.name.is_empty()).cloned().collect();
    track(step1.len() * 120);
    let step2: Vec<Record> = step1
        .into_iter()
        .map(|mut r| {
            r.name = r.name.trim().to_string();
            r
        })
        .collect();
    track(step2.len() * 120);
    let step3: Vec<Record> = step2
        .into_iter()
        .map(|mut r| {
            r.name = r.name.to_lowercase();
            r
        })
        .collect();
    track(step3.len() * 120);

    // unit 4-5: dedupe by email (build map, then filter)
    let mut first_by_email: HashMap<String, i64> = HashMap::new();
    for r in &step3 {
        first_by_email.entry(r.email.clone()).or_insert(r.id);
    }
    let deduped: Vec<Record> = step3
        .into_iter()
        .filter(|r| first_by_email[&r.email] == r.id)
        .collect();
    track(deduped.len() * 120 + first_by_email.len() * 64);

    // unit 6-8: group by city, pairwise match (O(b²) per city)
    let mut by_city: HashMap<String, Vec<&Record>> = HashMap::new();
    for r in &deduped {
        by_city.entry(r.city.clone()).or_default().push(r);
    }
    let mut matches = 0usize;
    let mut match_texts: Vec<String> = Vec::new();
    for group in by_city.values() {
        for i in 0..group.len() {
            for j in (i + 1)..group.len() {
                if levenshtein_sim(&group[i].name, &group[j].name) >= match_threshold {
                    matches += 1;
                    match_texts.push(format!("{} {}", group[i].name, group[j].name));
                }
            }
        }
    }
    track(match_texts.iter().map(|s| s.len()).sum::<usize>() + deduped.len() * 120);

    // unit 9-17: "enrichment" — the monolith calls the ML microservice
    // once per small batch (REST latency per call)
    for chunk in match_texts.chunks(16) {
        let texts: Vec<&str> = chunk.iter().map(|s| s.as_str()).collect();
        if !texts.is_empty() {
            let _ = svc.detect(&texts)?;
        }
    }

    // unit 18-19: aggregate + format
    let records_out = deduped.len();

    Ok(NativeRunReport {
        records_in: records.len(),
        records_out,
        matches,
        peak_driver_bytes: peak,
        rest_calls: svc.call_count(),
        total_secs: t0.elapsed().as_secs_f64(),
    })
}

/// Measured per-record costs feeding the Table 3 extrapolation.
#[derive(Debug, Clone, Copy)]
pub struct PerRecordCosts {
    /// CPU seconds per record for the transform phases
    pub transform_secs: f64,
    /// CPU seconds per record for matching (amortized, post-blocking)
    pub match_secs: f64,
    /// CPU seconds per record for model scoring (embedded path)
    pub model_secs: f64,
    /// REST latency per microservice call (batch of `rest_batch`)
    pub rest_latency_secs: f64,
    pub rest_batch: usize,
    /// serialized bytes per record
    pub record_bytes: u64,
}

impl Default for PerRecordCosts {
    fn default() -> Self {
        // Calibrated to the paper's own figures. Table 3 gives DDP 1 h at
        // 1 M records on 48 vCPUs -> ~173 core-ms of work per record
        // (entity-resolution pipelines stack several models + rules), and
        // native 20 h -> the monolith's sequential 60 ms REST call per
        // record (~16.7 h) plus its multi-pass compute. §1 quotes 20-100
        // ms per REST call and ~5 ms for one BERT encoder pass. Driver
        // bytes include the JVM object-bloat factor that OOMed the
        // monolith just past 1 M collected records.
        PerRecordCosts {
            transform_secs: 20.0e-3,
            match_secs: 33.0e-3,
            model_secs: 100.0e-3,
            rest_latency_secs: 0.060,
            rest_batch: 1,
            record_bytes: 120,
        }
    }
}

/// Native monolith as simulator stages: every phase collects to the
/// driver; the model phase pays REST latency serialized per call.
pub fn native_stage_specs(n_records: u64, c: &PerRecordCosts, tasks: usize) -> Vec<StageSpec> {
    let n = n_records as f64;
    // driver-collected footprint: serialized record × JVM object bloat ×
    // the copies the monolith keeps alive across phases
    let bytes = n_records * c.record_bytes * 17 * 3;
    // REST calls are latency-bound and sequential from the driver's view:
    // fold their total latency into a single-task stage
    let rest_calls = (n / c.rest_batch as f64).ceil();
    vec![
        StageSpec::uniform("validate+normalize(3 passes)", tasks, 3.0 * n * c.transform_secs / tasks as f64)
            .with_collect(bytes)
            .with_working_set(bytes),
        StageSpec::uniform("dedupe", tasks, n * c.transform_secs / tasks as f64)
            .with_collect(bytes)
            .with_working_set(bytes),
        StageSpec::uniform("pairwise-match", tasks, n * c.match_secs / tasks as f64)
            .with_collect(bytes)
            .with_working_set(2 * bytes),
        StageSpec {
            name: "ml-microservice".into(),
            task_secs: vec![rest_calls * c.rest_latency_secs],
            shuffle_bytes: bytes,
            collect_bytes: bytes,
            working_set_bytes: bytes,
        },
        StageSpec::uniform("aggregate+format", tasks, 2.0 * n * c.transform_secs / tasks as f64)
            .with_collect(bytes),
    ]
}

/// DDP as simulator stages: partitioned end-to-end (no driver collects),
/// embedded model (no REST), fused transforms (one pass), selective
/// caching (no recompute of the shared intermediate).
pub fn ddp_stage_specs(n_records: u64, c: &PerRecordCosts, tasks: usize) -> Vec<StageSpec> {
    let n = n_records as f64;
    let bytes = n_records * c.record_bytes; // columnar, partitioned: no bloat
    vec![
        // fused narrow chain: validate+normalize+dedupe map side
        StageSpec::uniform("fused-transform", tasks, n * c.transform_secs / tasks as f64)
            .with_working_set(bytes / 4),
        StageSpec::uniform("dedupe-shuffle", tasks, n * c.transform_secs / tasks as f64)
            .with_shuffle(bytes)
            .with_working_set(bytes / 4),
        StageSpec::uniform("blocked-match", tasks, n * c.match_secs / tasks as f64)
            .with_shuffle(bytes)
            .with_working_set(bytes / 4),
        StageSpec::uniform("embedded-model", tasks, n * c.model_secs / tasks as f64)
            .with_working_set(bytes / 4),
        StageSpec::uniform("aggregate", tasks, n * c.transform_secs / tasks as f64)
            .with_shuffle(bytes / 8),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::enterprise::EnterpriseGen;
    use crate::engine::cluster::{simulate, ClusterConfig};
    use crate::ml::embedded::LangDetector;
    use crate::ml::microservice::RestModel;
    use crate::pipes::model_predict::default_artifacts_dir;
    use crate::runtime::ModelRuntime;

    #[test]
    fn native_run_works_at_small_scale() {
        if !std::path::Path::new(&default_artifacts_dir()).join("model_meta.json").exists() {
            return;
        }
        let rt = ModelRuntime::cpu().unwrap();
        let det = LangDetector::load(&rt, default_artifacts_dir()).unwrap();
        let svc = MicroserviceDetector::new(det, RestModel::default(), 1);
        let recs = EnterpriseGen { seed: 5, dup_rate: 0.15 }.generate(400);
        let report = run_native(&svc, &recs, 0.75).unwrap();
        assert!(report.records_out < report.records_in);
        assert!(report.matches > 0);
        assert!(report.peak_driver_bytes > 0);
        assert!(report.rest_calls > 0);
    }

    #[test]
    fn table3_shape_native_ooms_ddp_scales() {
        let c = PerRecordCosts::default();
        let cluster = ClusterConfig::glue_like(48);
        // native dies at large N (driver collect), DDP survives
        let native_500m = simulate(&native_stage_specs(500_000_000, &c, 48), &cluster);
        assert!(!native_500m.ok(), "native should OOM at 500M");
        let ddp_500m = simulate(&ddp_stage_specs(500_000_000, &c, 48 * 16), &cluster);
        assert!(ddp_500m.ok(), "DDP must scale to 500M: {:?}", ddp_500m.failure);
        // at 1M both run, DDP much faster (REST + collect taxes)
        let native_1m = simulate(&native_stage_specs(1_000_000, &c, 48), &cluster);
        let ddp_1m = simulate(&ddp_stage_specs(1_000_000, &c, 48), &cluster);
        assert!(native_1m.ok());
        assert!(
            native_1m.makespan_secs > 10.0 * ddp_1m.makespan_secs,
            "native {} vs ddp {}",
            native_1m.makespan_secs,
            ddp_1m.makespan_secs
        );
    }
}
