//! Single-threaded reference implementation of the language-detection
//! pipeline (clean → dedup → detect → partition counts) — the structural
//! twin of `python/baselines/langdetect_single.py`, used to (a) measure
//! honest per-document costs that feed the cluster simulator and (b)
//! anchor the Table 4 "how much does the framework cost" comparison.

use crate::corpus::web::Doc;
use crate::ml::embedded::LangDetector;
use crate::pipes::preprocess::clean_text;
use crate::util::error::Result;
use crate::util::fnv1a64;
use std::collections::{HashMap, HashSet};

/// Timing breakdown of a sequential run.
#[derive(Debug, Clone)]
pub struct SingleThreadReport {
    pub docs_in: usize,
    pub docs_after_dedup: usize,
    pub lang_counts: HashMap<String, usize>,
    pub clean_secs: f64,
    pub dedup_secs: f64,
    pub detect_secs: f64,
    pub total_secs: f64,
}

/// Run the full pipeline on one thread.
pub fn run(detector: &LangDetector, docs: &[Doc], batch: usize) -> Result<SingleThreadReport> {
    let t_total = std::time::Instant::now();

    let t0 = std::time::Instant::now();
    let cleaned: Vec<(i64, String)> = docs
        .iter()
        .map(|d| (d.id, clean_text(&d.text)))
        .filter(|(_, t)| t.chars().count() >= 4)
        .collect();
    let clean_secs = t0.elapsed().as_secs_f64();

    let t0 = std::time::Instant::now();
    let mut seen = HashSet::new();
    let mut unique: Vec<(i64, String)> = Vec::with_capacity(cleaned.len());
    for (id, text) in cleaned {
        if seen.insert(fnv1a64(text.to_lowercase().as_bytes())) {
            unique.push((id, text));
        }
    }
    let dedup_secs = t0.elapsed().as_secs_f64();

    let t0 = std::time::Instant::now();
    let mut lang_counts: HashMap<String, usize> = HashMap::new();
    for chunk in unique.chunks(batch.max(1)) {
        let texts: Vec<&str> = chunk.iter().map(|(_, t)| t.as_str()).collect();
        for lang in detector.detect(&texts)? {
            *lang_counts.entry(lang).or_insert(0) += 1;
        }
    }
    let detect_secs = t0.elapsed().as_secs_f64();

    Ok(SingleThreadReport {
        docs_in: docs.len(),
        docs_after_dedup: unique.len(),
        lang_counts,
        clean_secs,
        dedup_secs,
        detect_secs,
        total_secs: t_total.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::web::{CorpusGen, LangProfiles};
    use crate::pipes::model_predict::default_artifacts_dir;
    use crate::runtime::ModelRuntime;

    #[test]
    fn sequential_pipeline_counts_languages() {
        if !std::path::Path::new(&default_artifacts_dir()).join("model_meta.json").exists() {
            return;
        }
        let rt = ModelRuntime::cpu().unwrap();
        let det = LangDetector::load(&rt, default_artifacts_dir()).unwrap();
        let profiles = LangProfiles::load_default().unwrap();
        let docs = CorpusGen { dup_rate: 0.2, ..Default::default() }.generate(&profiles, 200);
        let report = run(&det, &docs, 64).unwrap();
        assert!(report.docs_after_dedup < report.docs_in);
        let total: usize = report.lang_counts.values().sum();
        assert_eq!(total, report.docs_after_dedup);
        // accuracy: most detected languages should match ground truth mix
        assert!(report.lang_counts.len() >= 8, "saw {:?}", report.lang_counts);
    }
}
