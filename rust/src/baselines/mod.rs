//! Comparator implementations for the paper's experiments:
//!
//! * [`native_spark`] — the Table 3 monolith (driver collects, REST ML,
//!   no caching) + analytic stage builders for virtual-time extrapolation;
//! * [`raysim`] — Ray-style task/object-store execution (Table 4, Fig 5);
//! * [`singlethread`] — sequential reference, the honest per-doc cost
//!   source for the cluster simulator.

pub mod native_spark;
pub mod raysim;
pub mod singlethread;
