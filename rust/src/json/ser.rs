//! JSON serializer: compact and pretty printers with deterministic key
//! order (objects are BTreeMaps).

use super::Value;

/// Compact serialization.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Pretty serialization (2-space indent).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Value::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_str(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like most serializers in lenient mode
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // shortest roundtrip repr rust provides
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn compact() {
        let v = parse(r#"{"b": 2, "a": [1, true, null, "x\ny"]}"#).unwrap();
        // keys sort (BTreeMap)
        assert_eq!(to_string(&v), r#"{"a":[1,true,null,"x\ny"],"b":2}"#);
    }

    #[test]
    fn pretty_roundtrips() {
        let v = parse(r#"{"a": {"b": [1, 2]}}"#).unwrap();
        let p = to_string_pretty(&v);
        assert!(p.contains("\n"));
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn integers_have_no_decimal_point() {
        assert_eq!(to_string(&Value::Num(5.0)), "5");
        assert_eq!(to_string(&Value::Num(5.5)), "5.5");
    }

    #[test]
    fn control_chars_escaped() {
        assert_eq!(to_string(&Value::Str("\u{0001}".into())), "\"\\u0001\"");
    }
}
