//! Recursive-descent JSON parser (RFC 8259) with byte-offset error
//! reporting. Accepts exactly standard JSON; no comments or trailing
//! commas — pipeline configs should be portable.

use super::Value;
use crate::util::error::{DdpError, Result};
use std::collections::BTreeMap;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document; trailing whitespace allowed, trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> DdpError {
        DdpError::Json { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.bump() {
            Some(x) if x == b => Ok(()),
            Some(x) => Err(self.err(format!("expected '{}', found '{}'", b as char, x as char))),
            None => Err(self.err(format!("expected '{}', found EOF", b as char))),
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.err(format!("unexpected character '{}'", b as char))),
            None => Err(self.err("unexpected EOF")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control character in string")),
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // multi-byte UTF-8: determine length from the lead byte
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("EOF in \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // int part
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
            }
            Some(b) if b.is_ascii_digit() => {
                while self.peek().map(|b| b.is_ascii_digit()).unwrap_or(false) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // frac
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !self.peek().map(|b| b.is_ascii_digit()).unwrap_or(false) {
                return Err(self.err("digit expected after '.'"));
            }
            while self.peek().map(|b| b.is_ascii_digit()).unwrap_or(false) {
                self.pos += 1;
            }
        }
        // exp
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !self.peek().map(|b| b.is_ascii_digit()).unwrap_or(false) {
                return Err(self.err("digit expected in exponent"));
            }
            while self.peek().map(|b| b.is_ascii_digit()).unwrap_or(false) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("number out of range"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::property;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, {"b": null}], "c": "d"}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(1));
        assert_eq!(a[1].get("b"), Some(&Value::Null));
    }

    #[test]
    fn escapes_and_unicode() {
        assert_eq!(
            parse(r#""a\nb\t\"c\" é 中""#).unwrap(),
            Value::Str("a\nb\t\"c\" é 中".into())
        );
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("01").is_err());
        assert!(parse("1 x").is_err());
        assert!(parse(r#""\q""#).is_err());
        assert!(parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn error_offset_reported() {
        match parse("[1, 2, x]") {
            Err(crate::util::error::DdpError::Json { offset, .. }) => assert_eq!(offset, 7),
            other => panic!("expected Json error, got {other:?}"),
        }
    }

    #[test]
    fn prop_roundtrip_via_serializer() {
        use crate::json::{to_string, Value};
        property(150, |g| {
            // build a random value tree of bounded depth
            fn gen_val(g: &mut crate::util::testkit::Gen, depth: usize) -> Value {
                match if depth == 0 { g.u64(4) } else { g.u64(6) } {
                    0 => Value::Null,
                    1 => Value::Bool(g.bool()),
                    2 => Value::Num((g.i64(-1_000_000, 1_000_000) as f64) / 8.0),
                    3 => Value::Str(g.string(0, 12)),
                    4 => Value::Arr(g.vec(0, 4, |g| gen_val(g, depth - 1))),
                    _ => {
                        let n = g.usize(4);
                        let mut m = std::collections::BTreeMap::new();
                        for _ in 0..n {
                            m.insert(g.ident(1, 8), gen_val(g, depth - 1));
                        }
                        Value::Obj(m)
                    }
                }
            }
            let v = gen_val(g, 3);
            let s = to_string(&v);
            let back = parse(&s).unwrap();
            assert_eq!(back, v, "roundtrip failed for {s}");
        });
    }
}
