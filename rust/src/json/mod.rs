//! JSON substrate — parser, value model, and serializer built from scratch
//! (serde/serde_json are not in the offline vendor set). Used for pipeline
//! declarations, language profiles, metrics sinks, and golden files.

pub mod parser;
pub mod ser;

pub use parser::parse;
pub use ser::{to_string, to_string_pretty};

use std::collections::BTreeMap;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — important for golden tests and artifact diffs.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that tolerates either a string or an array of strings —
    /// pipeline configs allow `"inputDataId": "X"` and `["X", "Y"]`.
    pub fn get_string_list(&self, key: &str) -> Vec<String> {
        match self.get(key) {
            Some(Value::Str(s)) => vec![s.clone()],
            Some(Value::Arr(a)) => a
                .iter()
                .filter_map(|v| v.as_str().map(|s| s.to_string()))
                .collect(),
            _ => vec![],
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.as_u64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<Vec<Value>> for Value {
    fn from(a: Vec<Value>) -> Self {
        Value::Arr(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": 1, "b": "x", "c": [1, 2], "d": true, "ids": "one"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.str_or("b", "z"), "x");
        assert_eq!(v.get("c").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.bool_or("d", false));
        assert_eq!(v.get_string_list("ids"), vec!["one"]);
        assert_eq!(v.u64_or("missing", 9), 9);
    }

    #[test]
    fn string_list_from_array() {
        let v = parse(r#"{"ids": ["a", "b"]}"#).unwrap();
        assert_eq!(v.get_string_list("ids"), vec!["a", "b"]);
    }
}
