//! Per-run context handed to every pipe: engine handle, metrics, I/O
//! registry, instance-scope object pool, clock, and the explicit-state
//! cleanup ledger (§3.2).

use super::lifecycle::ObjectPool;
use crate::engine::dataset::Dataset;
use crate::engine::executor::{EngineConfig, EngineCtx};
use crate::io::IoRegistry;
use crate::metrics::MetricsRegistry;
use crate::util::clock::{self, ClockRef};
use std::sync::{Arc, Mutex};

/// Everything a pipe may touch beyond its input datasets.
pub struct PipeContext {
    pub engine: Arc<EngineCtx>,
    pub metrics: MetricsRegistry,
    pub io: Arc<IoRegistry>,
    pub objects: Arc<ObjectPool>,
    pub clock: ClockRef,
    /// datasets registered for cleanup when the current pipe completes
    cleanups: Mutex<Vec<u64>>,
}

impl PipeContext {
    pub fn new(
        engine: Arc<EngineCtx>,
        metrics: MetricsRegistry,
        io: Arc<IoRegistry>,
        clock: ClockRef,
    ) -> PipeContext {
        PipeContext {
            engine,
            metrics,
            io,
            objects: Arc::new(ObjectPool::new()),
            clock,
            cleanups: Mutex::new(Vec::new()),
        }
    }

    /// Small local context for unit tests.
    pub fn for_tests() -> PipeContext {
        PipeContext::new(
            EngineCtx::new(EngineConfig { workers: 2, ..Default::default() }),
            MetricsRegistry::new(),
            Arc::new(IoRegistry::with_sim_cloud()),
            clock::wall(),
        )
    }

    /// Persist an intermediate dataset *and* register it for cleanup when
    /// the calling pipe completes — the paper's "delete clause" (§3.2).
    pub fn persist_scoped(&self, ds: &Dataset) {
        self.engine.persist(ds);
        self.cleanups.lock().unwrap().push(ds.id);
    }

    /// Persist without automatic cleanup (driver-managed anchors).
    pub fn persist(&self, ds: &Dataset) {
        self.engine.persist(ds);
    }

    /// Run the cleanup ledger (called by the driver after each pipe).
    pub fn run_cleanups(&self) -> usize {
        let ids: Vec<u64> = std::mem::take(&mut *self.cleanups.lock().unwrap());
        let n = ids.len();
        for id in ids {
            self.engine.cache.unpersist(id);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::{FieldType, Schema};
    use crate::row;

    #[test]
    fn scoped_persist_cleans_up() {
        let ctx = PipeContext::for_tests();
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        let ds = Dataset::from_rows("t", schema, vec![row!(1i64)], 1);
        ctx.persist_scoped(&ds);
        ctx.engine.collect(&ds).unwrap();
        assert_eq!(ctx.engine.cache.len(), 1);
        assert_eq!(ctx.run_cleanups(), 1);
        assert_eq!(ctx.engine.cache.len(), 0);
        // ledger drained
        assert_eq!(ctx.run_cleanups(), 0);
    }

    #[test]
    fn unscoped_persist_survives_cleanup() {
        let ctx = PipeContext::for_tests();
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        let ds = Dataset::from_rows("t", schema, vec![row!(1i64)], 1);
        ctx.persist(&ds);
        ctx.engine.collect(&ds).unwrap();
        ctx.run_cleanups();
        assert_eq!(ctx.engine.cache.len(), 1);
    }
}
