//! Per-run context handed to every pipe: engine handle, metrics, I/O
//! registry, instance-scope object pool, clock, and the explicit-state
//! cleanup ledger (§3.2).
//!
//! The ledger is *scoped*: while the driver executes pipe `i`, datasets
//! registered through [`PipeContext::persist_scoped`] are tagged with
//! `i`, and only that pipe's completion drains them. Under the
//! stage-parallel scheduler this is what keeps §3.2 cleanup correct —
//! pipe A finishing must not tear down state pipe B registered while
//! running concurrently.

use super::lifecycle::ObjectPool;
use crate::engine::dataset::Dataset;
use crate::engine::executor::{EngineConfig, EngineCtx};
use crate::io::IoRegistry;
use crate::metrics::MetricsRegistry;
use crate::util::clock::{self, ClockRef};
use std::cell::Cell;
use std::sync::{Arc, Mutex};

thread_local! {
    /// The pipe whose `transform` is running on this thread, if any.
    static CLEANUP_SCOPE: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Everything a pipe may touch beyond its input datasets.
pub struct PipeContext {
    pub engine: Arc<EngineCtx>,
    pub metrics: MetricsRegistry,
    pub io: Arc<IoRegistry>,
    pub objects: Arc<ObjectPool>,
    pub clock: ClockRef,
    /// datasets registered for cleanup, tagged with the registering pipe
    /// (None when registered outside any pipe scope)
    cleanups: Mutex<Vec<(Option<usize>, u64)>>,
}

impl PipeContext {
    pub fn new(
        engine: Arc<EngineCtx>,
        metrics: MetricsRegistry,
        io: Arc<IoRegistry>,
        clock: ClockRef,
    ) -> PipeContext {
        PipeContext {
            engine,
            metrics,
            io,
            objects: Arc::new(ObjectPool::new()),
            clock,
            cleanups: Mutex::new(Vec::new()),
        }
    }

    /// Small local context for unit tests.
    pub fn for_tests() -> PipeContext {
        PipeContext::new(
            EngineCtx::new(EngineConfig { workers: 2, ..Default::default() }),
            MetricsRegistry::new(),
            Arc::new(IoRegistry::with_sim_cloud()),
            clock::wall(),
        )
    }

    /// Enter pipe `pipe`'s cleanup scope on this thread; the scope is
    /// restored when the guard drops. Used by the driver around each
    /// `transform` call.
    pub fn enter_scope(&self, pipe: usize) -> ScopeGuard {
        let prev = CLEANUP_SCOPE.with(|s| s.replace(Some(pipe)));
        ScopeGuard { prev }
    }

    /// Persist an intermediate dataset *and* register it for cleanup when
    /// the calling pipe completes — the paper's "delete clause" (§3.2).
    pub fn persist_scoped(&self, ds: &Dataset) {
        self.engine.persist(ds);
        let scope = CLEANUP_SCOPE.with(|s| s.get());
        self.cleanups.lock().unwrap().push((scope, ds.id));
    }

    /// Persist without automatic cleanup (driver-managed anchors).
    pub fn persist(&self, ds: &Dataset) {
        self.engine.persist(ds);
    }

    /// Drain the whole cleanup ledger (end of run, failure path, tests).
    pub fn run_cleanups(&self) -> usize {
        let ids: Vec<u64> = std::mem::take(&mut *self.cleanups.lock().unwrap())
            .into_iter()
            .map(|(_, id)| id)
            .collect();
        let n = ids.len();
        for id in ids {
            self.engine.cache.unpersist(id);
        }
        n
    }

    /// Drain only the entries pipe `pipe` registered (called by the
    /// driver when that pipe completes). Entries registered outside any
    /// scope are left for the end-of-run drain.
    pub fn run_cleanups_for(&self, pipe: usize) -> usize {
        let mut ledger = self.cleanups.lock().unwrap();
        let mut mine = Vec::new();
        ledger.retain(|(scope, id)| {
            if *scope == Some(pipe) {
                mine.push(*id);
                false
            } else {
                true
            }
        });
        drop(ledger);
        let n = mine.len();
        for id in mine {
            self.engine.cache.unpersist(id);
        }
        n
    }
}

/// Restores the previous cleanup scope on drop (see
/// [`PipeContext::enter_scope`]).
pub struct ScopeGuard {
    prev: Option<usize>,
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        CLEANUP_SCOPE.with(|s| s.set(self.prev));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::row::{FieldType, Schema};
    use crate::row;

    fn one_row_ds(name: &str) -> Dataset {
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        Dataset::from_rows(name, schema, vec![row!(1i64)], 1)
    }

    #[test]
    fn scoped_persist_cleans_up() {
        let ctx = PipeContext::for_tests();
        let ds = one_row_ds("t");
        ctx.persist_scoped(&ds);
        ctx.engine.collect(&ds).unwrap();
        assert_eq!(ctx.engine.cache.len(), 1);
        assert_eq!(ctx.run_cleanups(), 1);
        assert_eq!(ctx.engine.cache.len(), 0);
        // ledger drained
        assert_eq!(ctx.run_cleanups(), 0);
    }

    #[test]
    fn unscoped_persist_survives_cleanup() {
        let ctx = PipeContext::for_tests();
        let ds = one_row_ds("t");
        ctx.persist(&ds);
        ctx.engine.collect(&ds).unwrap();
        ctx.run_cleanups();
        assert_eq!(ctx.engine.cache.len(), 1);
    }

    #[test]
    fn per_pipe_scope_isolates_cleanup() {
        let ctx = PipeContext::for_tests();
        let a = one_row_ds("a");
        let b = one_row_ds("b");
        {
            let _s = ctx.enter_scope(0);
            ctx.persist_scoped(&a);
        }
        {
            let _s = ctx.enter_scope(1);
            ctx.persist_scoped(&b);
        }
        ctx.engine.collect(&a).unwrap();
        ctx.engine.collect(&b).unwrap();
        assert_eq!(ctx.engine.cache.len(), 2);

        // pipe 0 completing must only drop pipe 0's state
        assert_eq!(ctx.run_cleanups_for(0), 1);
        assert_eq!(ctx.engine.cache.len(), 1);
        assert!(ctx.engine.cache.get(b.id).is_some(), "pipe 1's state survives");

        assert_eq!(ctx.run_cleanups_for(1), 1);
        assert_eq!(ctx.engine.cache.len(), 0);
    }

    #[test]
    fn scope_guard_restores_previous() {
        let ctx = PipeContext::for_tests();
        let outer = one_row_ds("outer");
        let _s0 = ctx.enter_scope(7);
        {
            let _s1 = ctx.enter_scope(8);
        }
        // back in scope 7 after the inner guard dropped
        ctx.persist_scoped(&outer);
        assert_eq!(ctx.run_cleanups_for(8), 0);
        assert_eq!(ctx.run_cleanups_for(7), 1);
    }
}
