//! The DDP coordinator — the paper's contribution, assembled:
//!
//! * [`pipe`] — the Pipe trait (`Inputs → Pipe → Outputs`, §3.1);
//! * [`registry`] — dynamic pipe discovery from declarative configs (§3.4);
//! * [`dag`] — data-driven execution flow: topo sort + cycle detection (§3.5);
//! * [`driver`] — anchor resolution, ordered execution, explicit state
//!   management (§3.2), declarative I/O + encryption, metrics publishing;
//! * [`lifecycle`] — record/partition/instance object scopes (§3.7);
//! * [`context`] — what a pipe may touch;
//! * [`streaming`] — continuous micro-batch execution of the same
//!   declarative specs (unmodified pipes in a backpressured loop);
//! * [`viz`] — real-time GraphViz rendering (§3.6, Fig. 3).

pub mod pipe;
pub mod registry;
pub mod dag;
pub mod driver;
pub mod lifecycle;
pub mod context;
pub mod streaming;
pub mod viz;

pub use context::PipeContext;
pub use dag::{DataDag, ReadyTracker};
pub use driver::{DriverConfig, PipeReport, PipeState, PipelineDriver, RunReport};
pub use lifecycle::{AnchorRefCounts, ObjectPool, Scope};
pub use pipe::{Pipe, PipeContract};
pub use registry::{PipeRegistry, GLOBAL};
pub use streaming::{StreamReport, StreamingConfig, StreamingDriver};
