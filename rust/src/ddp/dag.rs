//! Data-driven execution flow (paper §3.5): the pipe execution order is
//! *derived* from the declared data relationships, never hand-written.
//! We build the data DAG (datasets ↔ pipes bipartite graph), validate it
//! (single producer per anchor, no undeclared references, no cycles),
//! and topologically sort it. Cycle detection reports the offending
//! chain for debuggability.

use crate::config::PipelineSpec;
use crate::util::error::{DdpError, Result};
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

/// The analyzed pipeline graph.
#[derive(Debug, Clone)]
pub struct DataDag {
    /// pipe execution order (indices into `spec.pipes`)
    pub order: Vec<usize>,
    /// producing pipe index per data id (sources absent)
    pub producer: BTreeMap<String, usize>,
    /// consuming pipe indices per data id
    pub consumers: BTreeMap<String, Vec<usize>>,
    /// data ids with no producer (must be loaded / provided)
    pub sources: Vec<String>,
    /// data ids with no consumer (pipeline outputs)
    pub sinks: Vec<String>,
    /// pipe-level downstream adjacency: `pipe_dependents[p]` lists the
    /// pipes consuming one of `p`'s outputs (duplicate edges preserved —
    /// a consumer wiring two of `p`'s outputs appears twice, matching
    /// [`DataDag::pipe_indegree`])
    pub pipe_dependents: Vec<Vec<usize>>,
    /// number of upstream edges per pipe (counted per anchor wire)
    pub pipe_indegree: Vec<usize>,
}

impl DataDag {
    /// Build and validate the DAG for a spec.
    pub fn build(spec: &PipelineSpec) -> Result<DataDag> {
        // 1. producer / consumer maps, single-producer rule
        let mut producer: BTreeMap<String, usize> = BTreeMap::new();
        let mut consumers: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, pipe) in spec.pipes.iter().enumerate() {
            for out in &pipe.output_data_ids {
                if let Some(prev) = producer.insert(out.clone(), i) {
                    return Err(DdpError::dag(format!(
                        "data '{out}' produced by both '{}' and '{}' — anchors must have exactly one producer",
                        spec.pipes[prev].name, pipe.name
                    )));
                }
            }
            for inp in &pipe.input_data_ids {
                consumers.entry(inp.clone()).or_default().push(i);
            }
        }

        // 2. every referenced id must be declared (spec auto-declares, but
        //    a hand-built spec could violate this)
        for pipe in &spec.pipes {
            for id in pipe.input_data_ids.iter().chain(&pipe.output_data_ids) {
                if !spec.data.contains_key(id) {
                    return Err(DdpError::dag(format!(
                        "pipe '{}' references undeclared data '{id}'",
                        pipe.name
                    )));
                }
            }
        }

        // 3. pipe-level edges: producer(pipe) -> consumer(pipe)
        let n = spec.pipes.len();
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for (i, pipe) in spec.pipes.iter().enumerate() {
            for inp in &pipe.input_data_ids {
                if let Some(&p) = producer.get(inp) {
                    if p == i {
                        return Err(DdpError::dag(format!(
                            "pipe '{}' consumes its own output '{inp}'",
                            pipe.name
                        )));
                    }
                    adj[p].push(i);
                    indeg[i] += 1;
                }
            }
        }

        // 4. Kahn topological sort with deterministic tie-break (config
        //    order), cycle detection with a reported chain
        let mut queue: VecDeque<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut indeg_mut = indeg.clone();
        while let Some(i) = queue.pop_front() {
            order.push(i);
            for &j in &adj[i] {
                indeg_mut[j] -= 1;
                if indeg_mut[j] == 0 {
                    queue.push_back(j);
                }
            }
        }
        if order.len() != n {
            let cycle = find_cycle(&adj, n).unwrap_or_default();
            let names: Vec<&str> = cycle.iter().map(|&i| spec.pipes[i].name.as_str()).collect();
            return Err(DdpError::dag(format!(
                "cycle detected among pipes: {}",
                names.join(" → ")
            )));
        }

        // 5. sources / sinks
        let produced: HashSet<&String> = producer.keys().collect();
        let consumed: HashSet<&String> = consumers.keys().collect();
        let mut sources: Vec<String> = consumed
            .iter()
            .filter(|id| !produced.contains(**id))
            .map(|s| (*s).clone())
            .collect();
        sources.sort();
        let mut sinks: Vec<String> = produced
            .iter()
            .filter(|id| !consumed.contains(**id))
            .map(|s| (*s).clone())
            .collect();
        sinks.sort();

        Ok(DataDag {
            order,
            producer,
            consumers,
            sources,
            sinks,
            pipe_dependents: adj,
            pipe_indegree: indeg,
        })
    }

    /// All transitive downstream pipes of `pipe` (BFS over
    /// [`DataDag::pipe_dependents`]), excluding `pipe` itself, in
    /// ascending index order. The scheduler cancels these on failure.
    pub fn descendants(&self, pipe: usize) -> Vec<usize> {
        let mut seen = vec![false; self.pipe_dependents.len()];
        let mut queue = VecDeque::from([pipe]);
        while let Some(p) = queue.pop_front() {
            for &d in &self.pipe_dependents[p] {
                if !seen[d] {
                    seen[d] = true;
                    queue.push_back(d);
                }
            }
        }
        seen[pipe] = false;
        (0..seen.len()).filter(|&i| seen[i]).collect()
    }

    /// Pipes with no unfinished upstream — used by live visualization.
    pub fn ready_after(&self, spec: &PipelineSpec, done: &HashSet<usize>) -> Vec<usize> {
        self.order
            .iter()
            .copied()
            .filter(|i| !done.contains(i))
            .filter(|&i| {
                spec.pipes[i].input_data_ids.iter().all(|inp| {
                    match self.producer.get(inp) {
                        Some(p) => done.contains(p),
                        None => true, // source data
                    }
                })
            })
            .collect()
    }
}

/// Incremental ready-set over the pipe-level DAG — the scheduler's core
/// bookkeeping. Mirrors Kahn's algorithm: seeding the dispatch queue with
/// [`ReadyTracker::initially_ready`] (index order) and appending each
/// [`ReadyTracker::complete`] result (adjacency order) reproduces
/// [`DataDag::order`] exactly when pipes run one at a time.
#[derive(Debug, Clone)]
pub struct ReadyTracker {
    indegree: Vec<usize>,
    completed: usize,
}

impl ReadyTracker {
    pub fn new(dag: &DataDag) -> ReadyTracker {
        ReadyTracker { indegree: dag.pipe_indegree.clone(), completed: 0 }
    }

    /// Pipes with no upstream dependencies, in declaration-index order.
    pub fn initially_ready(&self) -> Vec<usize> {
        (0..self.indegree.len())
            .filter(|&i| self.indegree[i] == 0)
            .collect()
    }

    /// Record `pipe` as finished; returns the pipes that just became
    /// ready, in adjacency order.
    pub fn complete(&mut self, dag: &DataDag, pipe: usize) -> Vec<usize> {
        self.completed += 1;
        let mut newly = Vec::new();
        for &d in &dag.pipe_dependents[pipe] {
            debug_assert!(self.indegree[d] > 0, "dependency edge counted twice");
            self.indegree[d] -= 1;
            if self.indegree[d] == 0 {
                newly.push(d);
            }
        }
        newly
    }

    /// Number of pipes recorded as finished.
    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Pipes not yet recorded as finished.
    pub fn remaining(&self) -> usize {
        self.indegree.len() - self.completed
    }
}

/// DFS-based cycle extraction for error messages.
fn find_cycle(adj: &[Vec<usize>], n: usize) -> Option<Vec<usize>> {
    #[derive(Clone, Copy, PartialEq)]
    enum Mark {
        White,
        Gray,
        Black,
    }
    let mut mark = vec![Mark::White; n];
    let mut parent: HashMap<usize, usize> = HashMap::new();
    for start in 0..n {
        if mark[start] != Mark::White {
            continue;
        }
        let mut stack = vec![(start, 0usize)];
        mark[start] = Mark::Gray;
        while let Some(&mut (u, ref mut ei)) = stack.last_mut() {
            if *ei < adj[u].len() {
                let v = adj[u][*ei];
                *ei += 1;
                match mark[v] {
                    Mark::White => {
                        parent.insert(v, u);
                        mark[v] = Mark::Gray;
                        stack.push((v, 0));
                    }
                    Mark::Gray => {
                        // found a back edge u -> v; reconstruct the loop
                        let mut chain = vec![v, u];
                        let mut cur = u;
                        while let Some(&p) = parent.get(&cur) {
                            if p == v {
                                break;
                            }
                            chain.push(p);
                            cur = p;
                        }
                        chain.reverse();
                        return Some(chain);
                    }
                    Mark::Black => {}
                }
            } else {
                mark[u] = Mark::Black;
                stack.pop();
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PipelineSpec, PAPER_EXAMPLE};

    #[test]
    fn paper_example_order() {
        let spec = PipelineSpec::parse(PAPER_EXAMPLE).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        assert_eq!(dag.order, vec![0, 1, 2, 3]);
        assert_eq!(dag.sources, vec!["InputData"]);
        assert_eq!(dag.sinks, vec!["OutputData"]);
        assert_eq!(dag.producer["PredictionData"], 2);
        assert_eq!(dag.consumers["InputData"], vec![0, 3]);
    }

    #[test]
    fn order_respects_dependencies_regardless_of_config_order() {
        // declare pipes in reverse order
        let text = r#"[
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C", "name": "second"},
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "first"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        assert_eq!(dag.order, vec![1, 0]);
    }

    #[test]
    fn cycle_detected_with_chain() {
        let text = r#"[
          {"inputDataId": "C", "transformerType": "X", "outputDataId": "A", "name": "pa"},
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "pb"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C", "name": "pc"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let err = DataDag::build(&spec).unwrap_err().to_string();
        assert!(err.contains("cycle"), "{err}");
        assert!(err.contains("pa") && err.contains("pb") && err.contains("pc"), "{err}");
    }

    #[test]
    fn double_producer_rejected() {
        let text = r#"[
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "p1"},
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "p2"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let err = DataDag::build(&spec).unwrap_err().to_string();
        assert!(err.contains("exactly one producer"), "{err}");
    }

    #[test]
    fn self_loop_rejected() {
        let text = r#"[
          {"inputDataId": ["A", "B"], "transformerType": "X", "outputDataId": "B"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        assert!(DataDag::build(&spec).is_err());
    }

    #[test]
    fn diamond_dependencies() {
        let text = r#"[
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "top"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C", "name": "l"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "D", "name": "r"},
          {"inputDataId": ["C", "D"], "transformerType": "X", "outputDataId": "E", "name": "join"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        assert_eq!(dag.order[0], 0);
        assert_eq!(dag.order[3], 3);
        assert_eq!(dag.sinks, vec!["E"]);
    }

    #[test]
    fn pipe_level_edges_and_indegree() {
        let spec = PipelineSpec::parse(PAPER_EXAMPLE).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        // preprocess -> feature-gen -> model -> postprocess, and the
        // postprocess also reads the source anchor (no pipe edge for it)
        assert_eq!(dag.pipe_dependents[0], vec![1]);
        assert_eq!(dag.pipe_dependents[2], vec![3]);
        assert_eq!(dag.pipe_indegree, vec![0, 1, 1, 1]);
    }

    #[test]
    fn ready_tracker_replays_topo_order() {
        // reversed declaration order: dag.order = [1, 0]
        let text = r#"[
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C", "name": "second"},
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "first"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        let mut tracker = ReadyTracker::new(&dag);
        let mut queue: std::collections::VecDeque<usize> =
            tracker.initially_ready().into();
        let mut replay = Vec::new();
        while let Some(p) = queue.pop_front() {
            replay.push(p);
            queue.extend(tracker.complete(&dag, p));
        }
        assert_eq!(replay, dag.order);
        assert_eq!(tracker.remaining(), 0);
    }

    #[test]
    fn diamond_ready_tracker_fans_out() {
        let text = r#"[
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "top"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C", "name": "l"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "D", "name": "r"},
          {"inputDataId": ["C", "D"], "transformerType": "X", "outputDataId": "E", "name": "join"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        let mut tracker = ReadyTracker::new(&dag);
        assert_eq!(tracker.initially_ready(), vec![0]);
        // finishing the top releases both branches at once
        assert_eq!(tracker.complete(&dag, 0), vec![1, 2]);
        // the join waits for both branches
        assert_eq!(tracker.complete(&dag, 1), Vec::<usize>::new());
        assert_eq!(tracker.complete(&dag, 2), vec![3]);
    }

    #[test]
    fn descendants_are_transitive() {
        let text = r#"[
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "top"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C", "name": "l"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "D", "name": "r"},
          {"inputDataId": ["C", "D"], "transformerType": "X", "outputDataId": "E", "name": "join"},
          {"inputDataId": "Z", "transformerType": "X", "outputDataId": "Y", "name": "island"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        assert_eq!(dag.descendants(0), vec![1, 2, 3]);
        assert_eq!(dag.descendants(1), vec![3]);
        assert_eq!(dag.descendants(3), Vec::<usize>::new());
        assert_eq!(dag.descendants(4), Vec::<usize>::new());
    }

    #[test]
    fn ready_after_tracks_progress() {
        let spec = PipelineSpec::parse(PAPER_EXAMPLE).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        let mut done = HashSet::new();
        assert_eq!(dag.ready_after(&spec, &done), vec![0]);
        done.insert(0);
        assert_eq!(dag.ready_after(&spec, &done), vec![1]);
        done.insert(1);
        done.insert(2);
        // postprocess needs InputData (source, ok) + PredictionData (done)
        assert_eq!(dag.ready_after(&spec, &done), vec![3]);
    }
}
