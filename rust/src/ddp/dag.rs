//! Data-driven execution flow (paper §3.5): the pipe execution order is
//! *derived* from the declared data relationships, never hand-written.
//! We build the data DAG (datasets ↔ pipes bipartite graph), validate it
//! (single producer per anchor, no undeclared references, no cycles),
//! and topologically sort it. Cycle detection reports the offending
//! chain for debuggability.

use crate::config::PipelineSpec;
use crate::util::error::{DdpError, Result};
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

/// The analyzed pipeline graph.
#[derive(Debug, Clone)]
pub struct DataDag {
    /// pipe execution order (indices into `spec.pipes`)
    pub order: Vec<usize>,
    /// producing pipe index per data id (sources absent)
    pub producer: BTreeMap<String, usize>,
    /// consuming pipe indices per data id
    pub consumers: BTreeMap<String, Vec<usize>>,
    /// data ids with no producer (must be loaded / provided)
    pub sources: Vec<String>,
    /// data ids with no consumer (pipeline outputs)
    pub sinks: Vec<String>,
}

impl DataDag {
    /// Build and validate the DAG for a spec.
    pub fn build(spec: &PipelineSpec) -> Result<DataDag> {
        // 1. producer / consumer maps, single-producer rule
        let mut producer: BTreeMap<String, usize> = BTreeMap::new();
        let mut consumers: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, pipe) in spec.pipes.iter().enumerate() {
            for out in &pipe.output_data_ids {
                if let Some(prev) = producer.insert(out.clone(), i) {
                    return Err(DdpError::dag(format!(
                        "data '{out}' produced by both '{}' and '{}' — anchors must have exactly one producer",
                        spec.pipes[prev].name, pipe.name
                    )));
                }
            }
            for inp in &pipe.input_data_ids {
                consumers.entry(inp.clone()).or_default().push(i);
            }
        }

        // 2. every referenced id must be declared (spec auto-declares, but
        //    a hand-built spec could violate this)
        for pipe in &spec.pipes {
            for id in pipe.input_data_ids.iter().chain(&pipe.output_data_ids) {
                if !spec.data.contains_key(id) {
                    return Err(DdpError::dag(format!(
                        "pipe '{}' references undeclared data '{id}'",
                        pipe.name
                    )));
                }
            }
        }

        // 3. pipe-level edges: producer(pipe) -> consumer(pipe)
        let n = spec.pipes.len();
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for (i, pipe) in spec.pipes.iter().enumerate() {
            for inp in &pipe.input_data_ids {
                if let Some(&p) = producer.get(inp) {
                    if p == i {
                        return Err(DdpError::dag(format!(
                            "pipe '{}' consumes its own output '{inp}'",
                            pipe.name
                        )));
                    }
                    adj[p].push(i);
                    indeg[i] += 1;
                }
            }
        }

        // 4. Kahn topological sort with deterministic tie-break (config
        //    order), cycle detection with a reported chain
        let mut queue: VecDeque<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut indeg_mut = indeg.clone();
        while let Some(i) = queue.pop_front() {
            order.push(i);
            for &j in &adj[i] {
                indeg_mut[j] -= 1;
                if indeg_mut[j] == 0 {
                    queue.push_back(j);
                }
            }
        }
        if order.len() != n {
            let cycle = find_cycle(&adj, n).unwrap_or_default();
            let names: Vec<&str> = cycle.iter().map(|&i| spec.pipes[i].name.as_str()).collect();
            return Err(DdpError::dag(format!(
                "cycle detected among pipes: {}",
                names.join(" → ")
            )));
        }

        // 5. sources / sinks
        let produced: HashSet<&String> = producer.keys().collect();
        let consumed: HashSet<&String> = consumers.keys().collect();
        let mut sources: Vec<String> = consumed
            .iter()
            .filter(|id| !produced.contains(**id))
            .map(|s| (*s).clone())
            .collect();
        sources.sort();
        let mut sinks: Vec<String> = produced
            .iter()
            .filter(|id| !consumed.contains(**id))
            .map(|s| (*s).clone())
            .collect();
        sinks.sort();

        Ok(DataDag { order, producer, consumers, sources, sinks })
    }

    /// Pipes with no unfinished upstream — used by live visualization.
    pub fn ready_after(&self, spec: &PipelineSpec, done: &HashSet<usize>) -> Vec<usize> {
        self.order
            .iter()
            .copied()
            .filter(|i| !done.contains(i))
            .filter(|&i| {
                spec.pipes[i].input_data_ids.iter().all(|inp| {
                    match self.producer.get(inp) {
                        Some(p) => done.contains(p),
                        None => true, // source data
                    }
                })
            })
            .collect()
    }
}

/// DFS-based cycle extraction for error messages.
fn find_cycle(adj: &[Vec<usize>], n: usize) -> Option<Vec<usize>> {
    #[derive(Clone, Copy, PartialEq)]
    enum Mark {
        White,
        Gray,
        Black,
    }
    let mut mark = vec![Mark::White; n];
    let mut parent: HashMap<usize, usize> = HashMap::new();
    for start in 0..n {
        if mark[start] != Mark::White {
            continue;
        }
        let mut stack = vec![(start, 0usize)];
        mark[start] = Mark::Gray;
        while let Some(&mut (u, ref mut ei)) = stack.last_mut() {
            if *ei < adj[u].len() {
                let v = adj[u][*ei];
                *ei += 1;
                match mark[v] {
                    Mark::White => {
                        parent.insert(v, u);
                        mark[v] = Mark::Gray;
                        stack.push((v, 0));
                    }
                    Mark::Gray => {
                        // found a back edge u -> v; reconstruct the loop
                        let mut chain = vec![v, u];
                        let mut cur = u;
                        while let Some(&p) = parent.get(&cur) {
                            if p == v {
                                break;
                            }
                            chain.push(p);
                            cur = p;
                        }
                        chain.reverse();
                        return Some(chain);
                    }
                    Mark::Black => {}
                }
            } else {
                mark[u] = Mark::Black;
                stack.pop();
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PipelineSpec, PAPER_EXAMPLE};

    #[test]
    fn paper_example_order() {
        let spec = PipelineSpec::parse(PAPER_EXAMPLE).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        assert_eq!(dag.order, vec![0, 1, 2, 3]);
        assert_eq!(dag.sources, vec!["InputData"]);
        assert_eq!(dag.sinks, vec!["OutputData"]);
        assert_eq!(dag.producer["PredictionData"], 2);
        assert_eq!(dag.consumers["InputData"], vec![0, 3]);
    }

    #[test]
    fn order_respects_dependencies_regardless_of_config_order() {
        // declare pipes in reverse order
        let text = r#"[
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C", "name": "second"},
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "first"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        assert_eq!(dag.order, vec![1, 0]);
    }

    #[test]
    fn cycle_detected_with_chain() {
        let text = r#"[
          {"inputDataId": "C", "transformerType": "X", "outputDataId": "A", "name": "pa"},
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "pb"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C", "name": "pc"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let err = DataDag::build(&spec).unwrap_err().to_string();
        assert!(err.contains("cycle"), "{err}");
        assert!(err.contains("pa") && err.contains("pb") && err.contains("pc"), "{err}");
    }

    #[test]
    fn double_producer_rejected() {
        let text = r#"[
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "p1"},
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "p2"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let err = DataDag::build(&spec).unwrap_err().to_string();
        assert!(err.contains("exactly one producer"), "{err}");
    }

    #[test]
    fn self_loop_rejected() {
        let text = r#"[
          {"inputDataId": ["A", "B"], "transformerType": "X", "outputDataId": "B"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        assert!(DataDag::build(&spec).is_err());
    }

    #[test]
    fn diamond_dependencies() {
        let text = r#"[
          {"inputDataId": "A", "transformerType": "X", "outputDataId": "B", "name": "top"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "C", "name": "l"},
          {"inputDataId": "B", "transformerType": "X", "outputDataId": "D", "name": "r"},
          {"inputDataId": ["C", "D"], "transformerType": "X", "outputDataId": "E", "name": "join"}
        ]"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        assert_eq!(dag.order[0], 0);
        assert_eq!(dag.order[3], 3);
        assert_eq!(dag.sinks, vec!["E"]);
    }

    #[test]
    fn ready_after_tracks_progress() {
        let spec = PipelineSpec::parse(PAPER_EXAMPLE).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        let mut done = HashSet::new();
        assert_eq!(dag.ready_after(&spec, &done), vec![0]);
        done.insert(0);
        assert_eq!(dag.ready_after(&spec, &done), vec![1]);
        done.insert(1);
        done.insert(2);
        // postprocess needs InputData (source, ok) + PredictionData (done)
        assert_eq!(dag.ready_after(&spec, &done), vec![3]);
    }
}
