//! Pipeline driver: resolves a [`PipelineSpec`] against the pipe registry,
//! loads source anchors, executes pipes with a data-driven stage-parallel
//! scheduler, manages explicit state (persist + refcounted cleanup),
//! publishes metrics asynchronously, writes stored outputs, and tracks
//! per-pipe progress for live visualization.
//!
//! This is the runtime half of the paper's contribution: *deterministic
//! DAG execution driven by declarative definitions* — no cost-based
//! optimizer, no hand-written control flow.
//!
//! ## Scheduling
//!
//! Execution is a ready-set loop over the pipe-level DAG
//! ([`ReadyTracker`]): a pipe is dispatched once every input anchor is
//! materialized, onto a bounded pool of `maxConcurrentPipes` scheduler
//! threads. The dispatch queue is FIFO and seeded/extended exactly like
//! the Kahn topological sort in [`DataDag::build`], so with
//! `maxConcurrentPipes = 1` the driver reproduces the legacy serial
//! topo-order execution — same outputs, same report order, same cleanup.
//! Wider settings overlap independent branches (tf.data / MLlib-style
//! stage parallelism). Failures are fail-fast: the first error stops all
//! further dispatch, transitively cancels not-yet-started dependents
//! (marked [`PipeState::Failed`]), waits out pipes already in flight, and
//! releases every driver-persisted anchor.
//!
//! ## Anchor lifecycle (§3.2)
//!
//! Anchors consumed by more than one pipe (or flagged `cache: true`) are
//! persisted in the engine cache; shared anchors are materialized at
//! persist time so concurrent consumers share one computation. Implicitly
//! persisted anchors are reference-counted ([`AnchorRefCounts`]) and
//! dropped from the cache when their last consumer finishes; `cache:
//! true` anchors stay resident for post-run use. Pipe-scoped state
//! registered via [`PipeContext::persist_scoped`] is cleaned when exactly
//! that pipe completes, which stays correct under concurrency.

use super::context::PipeContext;
use super::dag::{DataDag, ReadyTracker};
use super::lifecycle::AnchorRefCounts;
use super::registry::PipeRegistry;
use super::viz::{self, VizOptions};
use crate::config::{DataLocation, PipelineSpec};
use crate::engine::analyze;
use crate::engine::dataset::Dataset;
use crate::engine::executor::{EngineConfig, EngineCtx};
use crate::engine::stats::Stat;
use crate::io::IoRegistry;
use crate::metrics::{
    EngineMetricsExporter, MetricsPublisher, MetricsRegistry, PublisherConfig, Sink,
};
use crate::util::clock::{self, ClockRef};
use crate::util::error::{DdpError, Result};
use crate::util::threadpool::ThreadPool;
use std::any::Any;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// Per-pipe execution state (drives the Fig 3 progress palette).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipeState {
    #[default]
    Pending,
    Running,
    Done,
    Failed,
}

/// Per-pipe result line.
#[derive(Debug, Clone)]
pub struct PipeReport {
    pub name: String,
    pub transformer_type: String,
    pub duration_secs: f64,
    /// rows in each materialized output (None if left lazy)
    pub output_rows: Vec<Option<usize>>,
}

/// Whole-run result.
pub struct RunReport {
    pub pipeline: String,
    pub pipes: Vec<PipeReport>,
    pub total_secs: f64,
    pub metrics: crate::metrics::MetricsSnapshot,
    /// final rendered DOT (all pipes green)
    pub dot: String,
    /// anchor handles for every dataset (lazily evaluable)
    pub anchors: BTreeMap<String, Dataset>,
    /// estimated CPU utilization of the engine during the run
    pub cpu_utilization: f64,
}

/// Driver configuration knobs beyond the spec.
pub struct DriverConfig {
    pub engine: EngineConfig,
    /// force materialization after every pipe (simpler failure attribution,
    /// pays the fusion cost — ablation knob)
    pub eager: bool,
    /// metrics sink (None = log sink)
    pub sink: Option<Arc<dyn Sink>>,
    pub clock: ClockRef,
    /// scheduler width override; None = use the spec's
    /// `settings.maxConcurrentPipes` (itself defaulting to `workers`)
    pub max_concurrent_pipes: Option<usize>,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            engine: EngineConfig::default(),
            eager: false,
            sink: None,
            clock: clock::wall(),
            max_concurrent_pipes: None,
        }
    }
}

/// The pipeline driver.
pub struct PipelineDriver {
    pub spec: Arc<PipelineSpec>,
    pub dag: Arc<DataDag>,
    registry: PipeRegistry,
    pub ctx: Arc<PipeContext>,
    states: Arc<Mutex<HashMap<usize, PipeState>>>,
    cfg_eager: bool,
    sink: Option<Arc<dyn Sink>>,
    max_concurrent: usize,
    /// delta-publishes engine counters (cache hits/evictions, fault
    /// injections, shuffle bytes) into the run's metrics registry
    exporter: Mutex<EngineMetricsExporter>,
}

/// One scheduled pipe's terminal message back to the dispatch loop.
enum Outcome {
    /// report + whether this pipe's outputs all cut their lineage (sink
    /// or cached), making it safe to release its input anchors
    Done(PipeReport, bool),
    Failed(DdpError),
    Panicked(Box<dyn Any + Send>),
}

/// Everything a scheduler worker needs, shareable across threads.
struct RunState {
    spec: Arc<PipelineSpec>,
    dag: Arc<DataDag>,
    registry: PipeRegistry,
    ctx: Arc<PipeContext>,
    eager: bool,
    anchors: Mutex<BTreeMap<String, Dataset>>,
    refcounts: AnchorRefCounts,
    /// the run's root span — pipe spans executed on scheduler worker
    /// threads parent to it explicitly
    run_span: u64,
}

impl PipelineDriver {
    /// Build a driver: parses nothing itself — give it a parsed spec, a
    /// registry and the IO registry that resolves anchor locations.
    pub fn new(
        spec: PipelineSpec,
        registry: PipeRegistry,
        io: Arc<IoRegistry>,
        cfg: DriverConfig,
    ) -> Result<PipelineDriver> {
        let dag = DataDag::build(&spec)?;
        // fail fast on unknown transformer types (§3.8 validation)
        for pipe in &spec.pipes {
            if !registry.contains(&pipe.transformer_type) {
                return Err(DdpError::config(format!(
                    "pipe '{}' needs unregistered transformerType '{}'",
                    pipe.name, pipe.transformer_type
                )));
            }
        }
        let mut engine_cfg = cfg.engine;
        engine_cfg.workers = engine_cfg.workers.max(spec.settings.workers);
        let engine = EngineCtx::new(engine_cfg);
        let metrics = MetricsRegistry::new();
        let ctx = Arc::new(PipeContext::new(engine, metrics, io, cfg.clock));
        let max_concurrent = cfg
            .max_concurrent_pipes
            .unwrap_or_else(|| spec.settings.effective_max_concurrent_pipes())
            .max(1);
        Ok(PipelineDriver {
            spec: Arc::new(spec),
            dag: Arc::new(dag),
            registry,
            ctx,
            states: Arc::new(Mutex::new(HashMap::new())),
            cfg_eager: cfg.eager,
            sink: cfg.sink,
            max_concurrent,
            exporter: Mutex::new(EngineMetricsExporter::new()),
        })
    }

    /// Render the current DOT (live view).
    pub fn dot(&self) -> String {
        viz::to_dot(
            &self.spec,
            &self.dag,
            &VizOptions {
                states: self.states.lock().unwrap().clone(),
                metrics: Some(self.ctx.metrics.snapshot()),
            },
        )
    }

    /// Current state of every pipe, indexed by declaration position
    /// (live progress for viz and tests).
    pub fn pipe_states(&self) -> Vec<PipeState> {
        let map = self.states.lock().unwrap();
        (0..self.spec.pipes.len())
            .map(|i| map.get(&i).copied().unwrap_or_default())
            .collect()
    }

    /// Effective scheduler width for this driver.
    pub fn max_concurrent_pipes(&self) -> usize {
        self.max_concurrent
    }

    /// Thread-safe monotone state transition: `Pending → Running →
    /// Done|Failed` (plus `Pending → Failed` for cancellations); terminal
    /// states never regress, so a racing late update cannot un-fail a pipe.
    fn advance_state(&self, pipe: usize, next: PipeState) {
        let mut map = self.states.lock().unwrap();
        let cur = map.get(&pipe).copied().unwrap_or_default();
        let legal = matches!(
            (cur, next),
            (PipeState::Pending, PipeState::Running)
                | (PipeState::Pending, PipeState::Done)
                | (PipeState::Pending, PipeState::Failed)
                | (PipeState::Running, PipeState::Done)
                | (PipeState::Running, PipeState::Failed)
        );
        if legal {
            map.insert(pipe, next);
        }
    }

    /// Execute the pipeline. `provided` supplies in-memory source anchors;
    /// sources with stored locations load automatically.
    pub fn run(&self, provided: BTreeMap<String, Dataset>) -> Result<RunReport> {
        let start = std::time::Instant::now();
        let stats0 = self.ctx.engine.stats.snapshot();
        // root span for this run; pipe spans parent to it explicitly
        // (pipes execute on scheduler worker threads, not this one)
        let tracer = self.ctx.engine.tracer.clone();
        let run_span =
            tracer.begin(crate::engine::SpanKind::Run, || format!("run:{}", self.spec.name), None);
        let _run_scope = tracer.scope(run_span);

        // metrics publisher for the run (cadence from settings)
        let cadence = Duration::from_secs_f64(self.spec.settings.metrics_cadence_secs.max(0.005));
        let sink: Arc<dyn Sink> = self
            .sink
            .clone()
            .unwrap_or_else(|| Arc::new(crate::metrics::LogSink));
        let publisher = MetricsPublisher::start(
            self.ctx.metrics.clone(),
            sink,
            self.ctx.clock.clone(),
            PublisherConfig { cadence },
        );

        let result = self.run_inner(provided);
        publisher.stop();

        let elapsed = start.elapsed().as_secs_f64();
        let (pipes, anchors) = result?;
        // surface engine counters (cache/fault/shuffle) in the metrics
        // snapshot the report carries
        self.exporter
            .lock()
            .unwrap()
            .publish(&self.ctx.metrics, &self.ctx.engine);
        let stats1 = self.ctx.engine.stats.snapshot();
        let delta = stats1.delta(&stats0);
        let cpu_utilization = if elapsed > 0.0 {
            (delta.task_nanos as f64 / 1e9 / (elapsed * self.ctx.engine.cfg.workers as f64)).min(1.0)
        } else {
            0.0
        };
        Ok(RunReport {
            pipeline: self.spec.name.clone(),
            pipes,
            total_secs: elapsed,
            metrics: self.ctx.metrics.snapshot(),
            dot: self.dot(),
            anchors,
            cpu_utilization,
        })
    }

    fn run_inner(
        &self,
        provided: BTreeMap<String, Dataset>,
    ) -> Result<(Vec<PipeReport>, BTreeMap<String, Dataset>)> {
        let mut anchors: BTreeMap<String, Dataset> = BTreeMap::new();

        // 1. resolve sources: provided datasets win, else load from storage
        for src in &self.dag.sources {
            let decl = &self.spec.data[src];
            if let Some(ds) = provided.get(src) {
                anchors.insert(src.clone(), ds.clone());
                continue;
            }
            match &decl.location {
                DataLocation::Stored(loc) => {
                    let rows = self.ctx.io.read_rows(
                        loc,
                        decl.format,
                        &decl.schema,
                        decl.encryption,
                        &decl.id,
                    )?;
                    self.ctx
                        .metrics
                        .counter_add(&format!("data.{src}.rows_loaded"), rows.len() as u64);
                    anchors.insert(
                        src.clone(),
                        Dataset::from_rows(src, decl.schema.clone(), rows, decl.partitions),
                    );
                }
                DataLocation::Memory => {
                    return Err(DdpError::validation(format!(
                        "source data '{src}' is memory-located but was not provided to run()"
                    )));
                }
            }
        }

        // 2. stage-parallel execution over the ready set
        let n = self.spec.pipes.len();
        let width = self.max_concurrent.min(n.max(1));
        let state = Arc::new(RunState {
            spec: self.spec.clone(),
            dag: self.dag.clone(),
            registry: self.registry.clone(),
            ctx: self.ctx.clone(),
            eager: self.cfg_eager,
            anchors: Mutex::new(anchors),
            refcounts: AnchorRefCounts::from_consumers(&self.dag.consumers),
            run_span: self.ctx.engine.tracer.current(),
        });

        let pool = ThreadPool::new(width);
        let (tx, rx) = mpsc::channel::<(usize, Outcome)>();
        let mut tracker = ReadyTracker::new(&self.dag);
        // FIFO queue seeded/extended exactly like the Kahn sort, so a
        // width-1 pool replays `dag.order` verbatim
        let mut queue: VecDeque<usize> = tracker.initially_ready().into();
        let mut reports: Vec<Option<PipeReport>> = (0..n).map(|_| None).collect();
        let mut in_flight = 0usize;
        let mut failure: Option<Outcome> = None;

        loop {
            while failure.is_none() && in_flight < width {
                let Some(i) = queue.pop_front() else { break };
                self.advance_state(i, PipeState::Running);
                in_flight += 1;
                let state = Arc::clone(&state);
                let tx = tx.clone();
                pool.execute(move || {
                    let outcome = match catch_unwind(AssertUnwindSafe(|| state.exec_pipe(i))) {
                        Ok(Ok((report, cuts))) => Outcome::Done(report, cuts),
                        Ok(Err(e)) => Outcome::Failed(e),
                        Err(payload) => Outcome::Panicked(payload),
                    };
                    let _ = tx.send((i, outcome));
                });
            }
            if in_flight == 0 {
                break;
            }
            let (i, outcome) = rx.recv().expect("scheduler worker channel closed");
            in_flight -= 1;
            match outcome {
                Outcome::Done(report, cuts_lineage) => {
                    self.advance_state(i, PipeState::Done);
                    reports[i] = Some(report);
                    queue.extend(tracker.complete(&self.dag, i));
                    // refcounted §3.2 cleanup: drop shared anchors whose
                    // last consumer just finished. Only a consumer whose
                    // outputs all cut the lineage (sink or cached) counts —
                    // a lazy pass-through consumer would re-read this
                    // anchor when its own output is evaluated downstream,
                    // so releasing on its completion would force recompute.
                    if cuts_lineage {
                        for input in &self.spec.pipes[i].input_data_ids {
                            if let Some(ds_id) = state.refcounts.release(input) {
                                self.ctx.engine.cache.unpersist(ds_id);
                                self.ctx.metrics.counter_add("driver.anchors_released", 1);
                            }
                        }
                    }
                }
                Outcome::Failed(e) => {
                    self.advance_state(i, PipeState::Failed);
                    if failure.is_none() {
                        // fail fast: cancel every not-yet-dispatched
                        // transitive dependent
                        for d in self.dag.descendants(i) {
                            self.advance_state(d, PipeState::Failed);
                        }
                        failure = Some(Outcome::Failed(e));
                    }
                }
                Outcome::Panicked(payload) => {
                    self.advance_state(i, PipeState::Failed);
                    if failure.is_none() {
                        for d in self.dag.descendants(i) {
                            self.advance_state(d, PipeState::Failed);
                        }
                        failure = Some(Outcome::Panicked(payload));
                    }
                }
            }
        }
        drop(tx);

        if let Some(outcome) = failure {
            // failure-path cleanup: unrelated branches must leave nothing
            // behind — drop every driver-persisted anchor and any scoped
            // state still in the ledger
            for ds_id in state.refcounts.drain_persisted() {
                self.ctx.engine.cache.unpersist(ds_id);
            }
            self.ctx.run_cleanups();
            match outcome {
                Outcome::Failed(e) => return Err(e),
                Outcome::Panicked(payload) => std::panic::resume_unwind(payload),
                Outcome::Done(..) => unreachable!("success is not a failure"),
            }
        }

        // end-of-run drain: scoped entries were cleaned per pipe; this
        // catches registrations made outside any pipe scope (e.g. from a
        // thread the scope tag doesn't reach)
        self.ctx.run_cleanups();

        // 3. deterministic reports: topo (declaration-tie-broken) order,
        // independent of completion order
        let reports: Vec<PipeReport> = self
            .dag
            .order
            .iter()
            .map(|&i| reports[i].take().expect("completed pipe must report"))
            .collect();
        let anchors = std::mem::take(&mut *state.anchors.lock().unwrap());
        Ok((reports, anchors))
    }
}

impl RunState {
    /// Run one pipe end-to-end: contract validation, transform, output
    /// binding (persist / store / sink materialization), scoped cleanup.
    /// Runs on a scheduler worker thread.
    ///
    /// Returns the report plus a *lineage-cut* flag: true when every
    /// output is either a sink (nothing downstream re-reads it) or was
    /// persisted and materialized (downstream evaluation stops at its
    /// cache entry) — the condition under which completing this pipe
    /// makes releasing its input anchors safe.
    fn exec_pipe(&self, i: usize) -> Result<(PipeReport, bool)> {
        let decl = &self.spec.pipes[i];
        // pipe span on this scheduler worker thread: engine stage spans
        // opened during transform nest under it, and driver-side charges
        // (plan rewrites, cache hits) attribute to this pipe
        let tracer = self.ctx.engine.tracer.clone();
        let span = tracer.begin(
            crate::engine::SpanKind::Pipe,
            || format!("pipe:{}", decl.name),
            Some(self.run_span),
        );
        let _pipe_scope = tracer.scope(span);
        let pipe = self.registry.create(&decl.transformer_type, &decl.params)?;

        // contract validation (§3.8): arity, then declared-schema
        // compatibility between the anchor and the pipe's contract — the
        // column checks are analyzer diagnostics (E008 missing column,
        // E009 type conflict) whose messages are the long-standing error
        // contract, pinned under test
        let contract = pipe.contract();
        if let Some(arity) = contract.arity {
            if arity != decl.input_data_ids.len() {
                return Err(DdpError::validation(format!(
                    "pipe '{}' expects {arity} inputs, config wires {}",
                    decl.name,
                    decl.input_data_ids.len()
                )));
            }
        }
        for (pos, want) in contract.input_schemas.iter().enumerate() {
            let (Some(want), Some(input_id)) = (want, decl.input_data_ids.get(pos)) else {
                continue;
            };
            let have = &self.spec.data[input_id];
            if !have.schema_declared {
                continue; // undeclared anchors are schema-agnostic
            }
            let diags = analyze::check_contract(&decl.name, want, input_id, &have.schema);
            if let Some(first) = diags.first() {
                self.ctx.engine.charge(Stat::AnalyzerErrors, diags.len() as u64);
                return Err(DdpError::validation(first.message.clone()));
            }
        }

        let inputs: Vec<Dataset> = {
            let anchors = self.anchors.lock().unwrap();
            decl.input_data_ids
                .iter()
                .map(|id| {
                    anchors.get(id).cloned().ok_or_else(|| {
                        DdpError::dag(format!("anchor '{id}' missing for pipe '{}'", decl.name))
                    })
                })
                .collect::<Result<_>>()?
        };

        let t0 = std::time::Instant::now();
        let outputs = {
            // §3.2 scoped state: persist_scoped calls during transform are
            // tagged to this pipe and cleaned when it completes
            let _scope = self.ctx.enter_scope(i);
            pipe.transform(&self.ctx, &inputs)
                .map_err(|e| DdpError::pipe(decl.name.clone(), e.to_string()))?
        };
        if outputs.len() != decl.output_data_ids.len() {
            return Err(DdpError::pipe(
                decl.name.clone(),
                format!(
                    "produced {} outputs, config declares {}",
                    outputs.len(),
                    decl.output_data_ids.len()
                ),
            ));
        }

        // validate-then-execute: statically analyze every output plan
        // before any task runs. Transforms only build lazy lineage, so
        // rejecting here guarantees a broken plan never launches a task.
        // Cost is proportional to plan size (memoized node walk), never
        // to data size; `analyze: false` skips the walk entirely.
        if self.ctx.engine.cfg.analyze {
            let cache = &self.ctx.engine.cache;
            for ds in &outputs {
                let analysis = analyze::analyze_with_lints(ds, &|id| cache.is_registered(id));
                let (errs, warns, notes) = (
                    analysis.count(analyze::Severity::Error) as u64,
                    analysis.count(analyze::Severity::Warning) as u64,
                    analysis.count(analyze::Severity::Note) as u64,
                );
                if errs > 0 {
                    self.ctx.engine.charge(Stat::AnalyzerErrors, errs);
                }
                if warns > 0 {
                    self.ctx.engine.charge(Stat::AnalyzerWarnings, warns);
                }
                if notes > 0 {
                    self.ctx.engine.charge(Stat::AnalyzerNotes, notes);
                }
                if errs > 0 {
                    return Err(DdpError::validation(format!(
                        "pipe '{}' produced an invalid plan:\n  {}",
                        decl.name,
                        analysis.error_summary()
                    )));
                }
            }
        }

        // bind outputs to anchors; apply declared state management
        let mut output_rows = Vec::with_capacity(outputs.len());
        let mut cuts_lineage = true;
        for (out_id, ds) in decl.output_data_ids.iter().zip(outputs) {
            let odecl = &self.spec.data[out_id];
            // §3.2 selective caching: anchors consumed by >1 pipe, or
            // flagged `cache: true`, persist in the engine cache
            let consumers = self.dag.consumers.get(out_id).map(|v| v.len()).unwrap_or(0);
            let persisted = odecl.cache || consumers > 1;
            // a single-consumer, uncached output is a lazy pass-through:
            // its downstream evaluation re-walks lineage through this
            // pipe's inputs
            cuts_lineage &= persisted || consumers == 0;
            if persisted {
                self.ctx.persist(&ds);
                // materialize now so concurrent consumers share one
                // computation instead of racing to evaluate the anchor
                self.ctx.engine.collect(&ds)?;
                if !odecl.cache {
                    // implicitly-shared anchors are refcounted and released
                    // after their last consumer; explicit `cache: true`
                    // stays resident for post-run use
                    if let Some(ds_id) = self.refcounts.register_persisted(out_id, ds.id) {
                        self.ctx.engine.cache.unpersist(ds_id);
                    }
                }
            }
            let mut rows_out = None;
            if let DataLocation::Stored(loc) = &odecl.location {
                let data = self.ctx.engine.collect(&ds)?;
                let rows = data.rows();
                self.ctx.io.write_rows(
                    loc,
                    odecl.format,
                    &ds.schema,
                    &rows,
                    odecl.encryption,
                    out_id,
                )?;
                rows_out = Some(rows.len());
            } else if self.eager {
                rows_out = Some(self.ctx.engine.count(&ds)?);
            }
            if let Some(rows) = rows_out {
                self.ctx
                    .metrics
                    .counter_add(&format!("pipe.{}.rows_out", decl.name), rows as u64);
            }
            output_rows.push(rows_out);
            let is_memory_sink = matches!(odecl.location, DataLocation::Memory)
                && self.dag.sinks.binary_search(out_id).is_ok();
            self.anchors.lock().unwrap().insert(out_id.clone(), ds.clone());
            // memory sinks materialize at producer completion, so branch
            // work runs inside the (possibly concurrent) pipe execution
            if is_memory_sink {
                let rows = self.ctx.engine.count(&ds)?;
                self.ctx
                    .metrics
                    .counter_add(&format!("data.{out_id}.rows"), rows as u64);
            }
        }

        // explicit cleanup ledger (§3.2), this pipe's scope only
        let cleaned = self.ctx.run_cleanups_for(i);
        if cleaned > 0 {
            self.ctx
                .metrics
                .counter_add(&format!("pipe.{}.cleanups", decl.name), cleaned as u64);
        }

        let dur = t0.elapsed().as_secs_f64();
        self.ctx
            .metrics
            .observe(&format!("pipe.{}.duration_secs", decl.name), dur);
        Ok((
            PipeReport {
                name: decl.name.clone(),
                transformer_type: decl.transformer_type.clone(),
                duration_secs: dur,
                output_rows,
            },
            cuts_lineage,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddp::pipe::Pipe;
    use crate::engine::row::{FieldType, Schema};
    use crate::json::Value;
    use crate::metrics::MemorySink;
    use crate::row;

    struct AddOne;
    impl Pipe for AddOne {
        fn type_name(&self) -> &str {
            "AddOne"
        }
        fn transform(&self, _: &PipeContext, inputs: &[Dataset]) -> Result<Vec<Dataset>> {
            let ds = &inputs[0];
            Ok(vec![ds.map(ds.schema.clone(), |r| {
                row!(r.get(0).as_i64().unwrap() + 1)
            })])
        }
    }

    struct Failing;
    impl Pipe for Failing {
        fn type_name(&self) -> &str {
            "Failing"
        }
        fn transform(&self, _: &PipeContext, _: &[Dataset]) -> Result<Vec<Dataset>> {
            Err(DdpError::other("intentional"))
        }
    }

    fn registry() -> PipeRegistry {
        let reg = PipeRegistry::new();
        reg.register("AddOne", |_: &Value| Ok(Box::new(AddOne)));
        reg.register("Failing", |_: &Value| Ok(Box::new(Failing)));
        reg
    }

    fn nums_ds(n: i64) -> Dataset {
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        Dataset::from_rows("in", schema, (0..n).map(|i| row!(i)).collect(), 2)
    }

    fn fast_settings(cfgtext: &str) -> PipelineSpec {
        let mut spec = PipelineSpec::parse(cfgtext).unwrap();
        spec.settings.metrics_cadence_secs = 0.01;
        spec
    }

    #[test]
    fn two_pipe_chain_runs() {
        let spec = fast_settings(
            r#"[
              {"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Mid", "name": "p1"},
              {"inputDataId": "Mid", "transformerType": "AddOne", "outputDataId": "Out", "name": "p2"}
            ]"#,
        );
        let sink = MemorySink::new();
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig { sink: Some(sink.clone()), ..Default::default() },
        )
        .unwrap();
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), nums_ds(10));
        let report = driver.run(provided).unwrap();
        assert_eq!(report.pipes.len(), 2);
        let out = report.anchors.get("Out").unwrap();
        let mut vals: Vec<i64> = driver
            .ctx
            .engine
            .collect_rows(out)
            .unwrap()
            .iter()
            .map(|r| r.get(0).as_i64().unwrap())
            .collect();
        vals.sort_unstable();
        assert_eq!(vals, (2..12).collect::<Vec<_>>());
        // publisher flushed at least once
        assert!(sink.count() >= 1);
        // final dot shows both pipes done
        assert_eq!(report.dot.matches("#9fdf9f").count(), 2);
    }

    #[test]
    fn stored_output_written_and_loaded_source() {
        let io = Arc::new(IoRegistry::with_sim_cloud());
        // pre-write source data to sim-s3
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        io.write_rows(
            "s3://bucket/in.jsonl",
            crate::io::Format::Jsonl,
            &schema,
            &[row!(1i64), row!(2i64)],
            crate::security::EncryptionMode::None,
            "In",
        )
        .unwrap();
        let spec = fast_settings(
            r#"{
              "data": [
                {"id": "In", "location": "s3://bucket/in.jsonl", "format": "jsonl",
                 "schema": [{"name": "x", "type": "i64"}]},
                {"id": "Out", "location": "s3://bucket/out.csv", "format": "csv",
                 "schema": [{"name": "x", "type": "i64"}]}
              ],
              "pipes": [
                {"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Out"}
              ]
            }"#,
        );
        let driver =
            PipelineDriver::new(spec, registry(), io.clone(), DriverConfig::default()).unwrap();
        let report = driver.run(BTreeMap::new()).unwrap();
        assert_eq!(report.pipes[0].output_rows[0], Some(2));
        // file exists and parses
        let schema_out = Schema::new(vec![("x", FieldType::I64)]);
        let rows = io
            .read_rows(
                "s3://bucket/out.csv",
                crate::io::Format::Csv,
                &schema_out,
                crate::security::EncryptionMode::None,
                "Out",
            )
            .unwrap();
        let mut vals: Vec<i64> = rows.iter().map(|r| r.get(0).as_i64().unwrap()).collect();
        vals.sort_unstable();
        assert_eq!(vals, vec![2, 3]);
    }

    #[test]
    fn missing_source_errors() {
        let spec = fast_settings(
            r#"[{"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Out"}]"#,
        );
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .unwrap();
        let err = driver.run(BTreeMap::new()).err().unwrap().to_string();
        assert!(err.contains("not provided"), "{err}");
    }

    #[test]
    fn failing_pipe_attributed() {
        let spec = fast_settings(
            r#"[{"inputDataId": "In", "transformerType": "Failing", "outputDataId": "Out", "name": "boom"}]"#,
        );
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .unwrap();
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), nums_ds(3));
        let err = driver.run(provided).err().unwrap().to_string();
        assert!(err.contains("boom") && err.contains("intentional"), "{err}");
        // failed pipe renders red
        assert!(driver.dot().contains("#f28b82"));
    }

    #[test]
    fn unknown_transformer_fails_fast() {
        let spec = fast_settings(
            r#"[{"inputDataId": "In", "transformerType": "Mystery", "outputDataId": "Out"}]"#,
        );
        let err = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .err()
        .map(|e| e.to_string())
        .unwrap();
        assert!(err.contains("Mystery"), "{err}");
    }

    #[test]
    fn shared_anchor_auto_cached() {
        // Mid feeds two consumers -> driver should persist it
        let spec = fast_settings(
            r#"[
              {"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Mid", "name": "a"},
              {"inputDataId": "Mid", "transformerType": "AddOne", "outputDataId": "O1", "name": "b"},
              {"inputDataId": "Mid", "transformerType": "AddOne", "outputDataId": "O2", "name": "c"}
            ]"#,
        );
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .unwrap();
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), nums_ds(10));
        driver.run(provided).unwrap();
        let s = driver.ctx.engine.stats.snapshot();
        assert!(s.cache_hits >= 1, "Mid should be cache-hit by the second consumer");
    }

    #[test]
    fn shared_anchor_released_after_last_consumer() {
        let spec = fast_settings(
            r#"[
              {"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Mid", "name": "a"},
              {"inputDataId": "Mid", "transformerType": "AddOne", "outputDataId": "O1", "name": "b"},
              {"inputDataId": "Mid", "transformerType": "AddOne", "outputDataId": "O2", "name": "c"}
            ]"#,
        );
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .unwrap();
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), nums_ds(10));
        let report = driver.run(provided).unwrap();
        // refcounted cleanup freed the shared anchor once both consumers ran
        assert_eq!(driver.ctx.engine.cache.len(), 0, "Mid released after last consumer");
        assert_eq!(*report.metrics.counters.get("driver.anchors_released").unwrap(), 1);
    }

    #[test]
    fn explicit_cache_flag_survives_run() {
        let spec = fast_settings(
            r#"{
              "data": [{"id": "Mid", "cache": true}],
              "pipes": [
                {"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Mid", "name": "a"},
                {"inputDataId": "Mid", "transformerType": "AddOne", "outputDataId": "Out", "name": "b"}
              ]
            }"#,
        );
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .unwrap();
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), nums_ds(4));
        driver.run(provided).unwrap();
        // user-requested cache stays resident for post-run use
        assert_eq!(driver.ctx.engine.cache.len(), 1);
    }

    #[test]
    fn serial_override_forces_width_one() {
        let spec = fast_settings(
            r#"[{"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Out"}]"#,
        );
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig { max_concurrent_pipes: Some(1), ..Default::default() },
        )
        .unwrap();
        assert_eq!(driver.max_concurrent_pipes(), 1);
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), nums_ds(3));
        assert!(driver.run(provided).is_ok());
    }
}
