//! Pipeline driver: resolves a [`PipelineSpec`] against the pipe registry,
//! loads source anchors, executes pipes in DAG order, manages explicit
//! state (persist + cleanup), publishes metrics asynchronously, writes
//! stored outputs, and tracks per-pipe progress for live visualization.
//!
//! This is the runtime half of the paper's contribution: *deterministic
//! DAG execution driven by declarative definitions* — no cost-based
//! optimizer, no hand-written control flow.

use super::context::PipeContext;
use super::dag::DataDag;
use super::registry::PipeRegistry;
use super::viz::{self, VizOptions};
use crate::config::{DataLocation, PipelineSpec};
use crate::engine::dataset::Dataset;
use crate::engine::executor::{EngineConfig, EngineCtx};
use crate::io::IoRegistry;
use crate::metrics::{MetricsPublisher, MetricsRegistry, PublisherConfig, Sink};
use crate::util::clock::{self, ClockRef};
use crate::util::error::{DdpError, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Per-pipe execution state (drives the Fig 3 progress palette).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipeState {
    #[default]
    Pending,
    Running,
    Done,
    Failed,
}

/// Per-pipe result line.
#[derive(Debug, Clone)]
pub struct PipeReport {
    pub name: String,
    pub transformer_type: String,
    pub duration_secs: f64,
    /// rows in each materialized output (None if left lazy)
    pub output_rows: Vec<Option<usize>>,
}

/// Whole-run result.
pub struct RunReport {
    pub pipeline: String,
    pub pipes: Vec<PipeReport>,
    pub total_secs: f64,
    pub metrics: crate::metrics::MetricsSnapshot,
    /// final rendered DOT (all pipes green)
    pub dot: String,
    /// anchor handles for every dataset (lazily evaluable)
    pub anchors: BTreeMap<String, Dataset>,
    /// estimated CPU utilization of the engine during the run
    pub cpu_utilization: f64,
}

/// Driver configuration knobs beyond the spec.
pub struct DriverConfig {
    pub engine: EngineConfig,
    /// force materialization after every pipe (simpler failure attribution,
    /// pays the fusion cost — ablation knob)
    pub eager: bool,
    /// metrics sink (None = log sink)
    pub sink: Option<Arc<dyn Sink>>,
    pub clock: ClockRef,
}

impl Default for DriverConfig {
    fn default() -> Self {
        DriverConfig {
            engine: EngineConfig::default(),
            eager: false,
            sink: None,
            clock: clock::wall(),
        }
    }
}

/// The pipeline driver.
pub struct PipelineDriver {
    pub spec: PipelineSpec,
    pub dag: DataDag,
    registry: PipeRegistry,
    pub ctx: Arc<PipeContext>,
    states: Mutex<HashMap<usize, PipeState>>,
    cfg_eager: bool,
    sink: Option<Arc<dyn Sink>>,
}

impl PipelineDriver {
    /// Build a driver: parses nothing itself — give it a parsed spec, a
    /// registry and the IO registry that resolves anchor locations.
    pub fn new(
        spec: PipelineSpec,
        registry: PipeRegistry,
        io: Arc<IoRegistry>,
        cfg: DriverConfig,
    ) -> Result<PipelineDriver> {
        let dag = DataDag::build(&spec)?;
        // fail fast on unknown transformer types (§3.8 validation)
        for pipe in &spec.pipes {
            if !registry.contains(&pipe.transformer_type) {
                return Err(DdpError::config(format!(
                    "pipe '{}' needs unregistered transformerType '{}'",
                    pipe.name, pipe.transformer_type
                )));
            }
        }
        let mut engine_cfg = cfg.engine;
        engine_cfg.workers = engine_cfg.workers.max(spec.settings.workers);
        let engine = EngineCtx::new(engine_cfg);
        let metrics = MetricsRegistry::new();
        let ctx = Arc::new(PipeContext::new(engine, metrics, io, cfg.clock));
        Ok(PipelineDriver {
            spec,
            dag,
            registry,
            ctx,
            states: Mutex::new(HashMap::new()),
            cfg_eager: cfg.eager,
            sink: cfg.sink,
        })
    }

    /// Render the current DOT (live view).
    pub fn dot(&self) -> String {
        viz::to_dot(
            &self.spec,
            &self.dag,
            &VizOptions {
                states: self.states.lock().unwrap().clone(),
                metrics: Some(self.ctx.metrics.snapshot()),
            },
        )
    }

    fn set_state(&self, pipe: usize, state: PipeState) {
        self.states.lock().unwrap().insert(pipe, state);
    }

    /// Execute the pipeline. `provided` supplies in-memory source anchors;
    /// sources with stored locations load automatically.
    pub fn run(&self, provided: BTreeMap<String, Dataset>) -> Result<RunReport> {
        let start = std::time::Instant::now();
        let stats0 = self.ctx.engine.stats.snapshot();

        // metrics publisher for the run (cadence from settings)
        let cadence = Duration::from_secs_f64(self.spec.settings.metrics_cadence_secs.max(0.005));
        let sink: Arc<dyn Sink> = self
            .sink
            .clone()
            .unwrap_or_else(|| Arc::new(crate::metrics::LogSink));
        let publisher = MetricsPublisher::start(
            self.ctx.metrics.clone(),
            sink,
            self.ctx.clock.clone(),
            PublisherConfig { cadence },
        );

        let result = self.run_inner(provided);
        publisher.stop();

        let elapsed = start.elapsed().as_secs_f64();
        let (pipes, anchors) = result?;
        let stats1 = self.ctx.engine.stats.snapshot();
        let delta = stats1.delta(&stats0);
        let cpu_utilization = if elapsed > 0.0 {
            (delta.task_nanos as f64 / 1e9 / (elapsed * self.ctx.engine.cfg.workers as f64)).min(1.0)
        } else {
            0.0
        };
        Ok(RunReport {
            pipeline: self.spec.name.clone(),
            pipes,
            total_secs: elapsed,
            metrics: self.ctx.metrics.snapshot(),
            dot: self.dot(),
            anchors,
            cpu_utilization,
        })
    }

    fn run_inner(
        &self,
        provided: BTreeMap<String, Dataset>,
    ) -> Result<(Vec<PipeReport>, BTreeMap<String, Dataset>)> {
        let mut anchors: BTreeMap<String, Dataset> = BTreeMap::new();

        // 1. resolve sources: provided datasets win, else load from storage
        for src in &self.dag.sources {
            let decl = &self.spec.data[src];
            if let Some(ds) = provided.get(src) {
                anchors.insert(src.clone(), ds.clone());
                continue;
            }
            match &decl.location {
                DataLocation::Stored(loc) => {
                    let rows = self.ctx.io.read_rows(
                        loc,
                        decl.format,
                        &decl.schema,
                        decl.encryption,
                        &decl.id,
                    )?;
                    self.ctx
                        .metrics
                        .counter_add(&format!("data.{src}.rows_loaded"), rows.len() as u64);
                    anchors.insert(
                        src.clone(),
                        Dataset::from_rows(src, decl.schema.clone(), rows, decl.partitions),
                    );
                }
                DataLocation::Memory => {
                    return Err(DdpError::validation(format!(
                        "source data '{src}' is memory-located but was not provided to run()"
                    )));
                }
            }
        }

        // 2. execute pipes in DAG order
        let mut reports = Vec::with_capacity(self.spec.pipes.len());
        for &i in &self.dag.order {
            let decl = &self.spec.pipes[i];
            self.set_state(i, PipeState::Running);
            let pipe = self.registry.create(&decl.transformer_type, &decl.params)?;

            // contract validation (§3.8): arity, then declared-schema
            // compatibility between the anchor and the pipe's contract
            let contract = pipe.contract();
            if let Some(arity) = contract.arity {
                if arity != decl.input_data_ids.len() {
                    self.set_state(i, PipeState::Failed);
                    return Err(DdpError::validation(format!(
                        "pipe '{}' expects {arity} inputs, config wires {}",
                        decl.name,
                        decl.input_data_ids.len()
                    )));
                }
            }
            for (pos, want) in contract.input_schemas.iter().enumerate() {
                let (Some(want), Some(input_id)) = (want, decl.input_data_ids.get(pos)) else {
                    continue;
                };
                let have = &self.spec.data[input_id];
                if !have.schema_declared {
                    continue; // undeclared anchors are schema-agnostic
                }
                for wi in 0..want.len() {
                    let (wname, wty) = want.field(wi);
                    match have.schema.idx(wname) {
                        None => {
                            self.set_state(i, PipeState::Failed);
                            return Err(DdpError::validation(format!(
                                "pipe '{}' requires column '{wname}' on input '{input_id}',                                  which declares only [{}]",
                                decl.name,
                                have.schema.names().join(", ")
                            )));
                        }
                        Some(hi) => {
                            let hty = have.schema.field_type(hi);
                            use crate::engine::row::FieldType;
                            if wty != FieldType::Any && hty != FieldType::Any && wty != hty {
                                self.set_state(i, PipeState::Failed);
                                return Err(DdpError::validation(format!(
                                    "pipe '{}' needs '{wname}: {}' on '{input_id}', declared as {}",
                                    decl.name,
                                    wty.name(),
                                    hty.name()
                                )));
                            }
                        }
                    }
                }
            }

            let inputs: Vec<Dataset> = decl
                .input_data_ids
                .iter()
                .map(|id| {
                    anchors.get(id).cloned().ok_or_else(|| {
                        DdpError::dag(format!("anchor '{id}' missing for pipe '{}'", decl.name))
                    })
                })
                .collect::<Result<_>>()?;

            let t0 = std::time::Instant::now();
            let outputs = pipe.transform(&self.ctx, &inputs).map_err(|e| {
                self.set_state(i, PipeState::Failed);
                DdpError::pipe(decl.name.clone(), e.to_string())
            })?;
            if outputs.len() != decl.output_data_ids.len() {
                self.set_state(i, PipeState::Failed);
                return Err(DdpError::pipe(
                    decl.name.clone(),
                    format!(
                        "produced {} outputs, config declares {}",
                        outputs.len(),
                        decl.output_data_ids.len()
                    ),
                ));
            }

            // 3. bind outputs to anchors; apply declared state management
            let mut output_rows = Vec::with_capacity(outputs.len());
            for (out_id, ds) in decl.output_data_ids.iter().zip(outputs) {
                let odecl = &self.spec.data[out_id];
                // §3.2 selective caching: anchors consumed by >1 pipe, or
                // flagged `cache: true`, persist in the engine cache
                let consumers = self.dag.consumers.get(out_id).map(|v| v.len()).unwrap_or(0);
                if odecl.cache || consumers > 1 {
                    self.ctx.persist(&ds);
                }
                let mut rows_out = None;
                if let DataLocation::Stored(loc) = &odecl.location {
                    let data = self.ctx.engine.collect(&ds)?;
                    let rows = data.rows();
                    self.ctx.io.write_rows(
                        loc,
                        odecl.format,
                        &ds.schema,
                        &rows,
                        odecl.encryption,
                        out_id,
                    )?;
                    rows_out = Some(rows.len());
                } else if self.cfg_eager {
                    rows_out = Some(self.ctx.engine.count(&ds)?);
                }
                if let Some(n) = rows_out {
                    self.ctx
                        .metrics
                        .counter_add(&format!("pipe.{}.rows_out", decl.name), n as u64);
                }
                output_rows.push(rows_out);
                anchors.insert(out_id.clone(), ds);
            }

            // explicit cleanup ledger (§3.2)
            let cleaned = self.ctx.run_cleanups();
            if cleaned > 0 {
                self.ctx
                    .metrics
                    .counter_add(&format!("pipe.{}.cleanups", decl.name), cleaned as u64);
            }

            let dur = t0.elapsed().as_secs_f64();
            self.ctx
                .metrics
                .observe(&format!("pipe.{}.duration_secs", decl.name), dur);
            self.set_state(i, PipeState::Done);
            reports.push(PipeReport {
                name: decl.name.clone(),
                transformer_type: decl.transformer_type.clone(),
                duration_secs: dur,
                output_rows,
            });
        }

        // 4. materialize sinks that stayed lazy so the run is complete
        for sink_id in &self.dag.sinks {
            let decl = &self.spec.data[sink_id];
            if matches!(decl.location, DataLocation::Memory) {
                if let Some(ds) = anchors.get(sink_id) {
                    let n = self.ctx.engine.count(ds)?;
                    self.ctx
                        .metrics
                        .counter_add(&format!("data.{sink_id}.rows"), n as u64);
                }
            }
        }

        Ok((reports, anchors))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddp::pipe::Pipe;
    use crate::engine::row::{FieldType, Schema};
    use crate::json::Value;
    use crate::metrics::MemorySink;
    use crate::row;

    struct AddOne;
    impl Pipe for AddOne {
        fn type_name(&self) -> &str {
            "AddOne"
        }
        fn transform(&self, _: &PipeContext, inputs: &[Dataset]) -> Result<Vec<Dataset>> {
            let ds = &inputs[0];
            Ok(vec![ds.map(ds.schema.clone(), |r| {
                row!(r.get(0).as_i64().unwrap() + 1)
            })])
        }
    }

    struct Failing;
    impl Pipe for Failing {
        fn type_name(&self) -> &str {
            "Failing"
        }
        fn transform(&self, _: &PipeContext, _: &[Dataset]) -> Result<Vec<Dataset>> {
            Err(DdpError::other("intentional"))
        }
    }

    fn registry() -> PipeRegistry {
        let reg = PipeRegistry::new();
        reg.register("AddOne", |_: &Value| Ok(Box::new(AddOne)));
        reg.register("Failing", |_: &Value| Ok(Box::new(Failing)));
        reg
    }

    fn nums_ds(n: i64) -> Dataset {
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        Dataset::from_rows("in", schema, (0..n).map(|i| row!(i)).collect(), 2)
    }

    fn fast_settings(cfgtext: &str) -> PipelineSpec {
        let mut spec = PipelineSpec::parse(cfgtext).unwrap();
        spec.settings.metrics_cadence_secs = 0.01;
        spec
    }

    #[test]
    fn two_pipe_chain_runs() {
        let spec = fast_settings(
            r#"[
              {"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Mid", "name": "p1"},
              {"inputDataId": "Mid", "transformerType": "AddOne", "outputDataId": "Out", "name": "p2"}
            ]"#,
        );
        let sink = MemorySink::new();
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig { sink: Some(sink.clone()), ..Default::default() },
        )
        .unwrap();
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), nums_ds(10));
        let report = driver.run(provided).unwrap();
        assert_eq!(report.pipes.len(), 2);
        let out = report.anchors.get("Out").unwrap();
        let mut vals: Vec<i64> = driver
            .ctx
            .engine
            .collect_rows(out)
            .unwrap()
            .iter()
            .map(|r| r.get(0).as_i64().unwrap())
            .collect();
        vals.sort_unstable();
        assert_eq!(vals, (2..12).collect::<Vec<_>>());
        // publisher flushed at least once
        assert!(sink.count() >= 1);
        // final dot shows both pipes done
        assert_eq!(report.dot.matches("#9fdf9f").count(), 2);
    }

    #[test]
    fn stored_output_written_and_loaded_source() {
        let io = Arc::new(IoRegistry::with_sim_cloud());
        // pre-write source data to sim-s3
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        io.write_rows(
            "s3://bucket/in.jsonl",
            crate::io::Format::Jsonl,
            &schema,
            &[row!(1i64), row!(2i64)],
            crate::security::EncryptionMode::None,
            "In",
        )
        .unwrap();
        let spec = fast_settings(
            r#"{
              "data": [
                {"id": "In", "location": "s3://bucket/in.jsonl", "format": "jsonl",
                 "schema": [{"name": "x", "type": "i64"}]},
                {"id": "Out", "location": "s3://bucket/out.csv", "format": "csv",
                 "schema": [{"name": "x", "type": "i64"}]}
              ],
              "pipes": [
                {"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Out"}
              ]
            }"#,
        );
        let driver =
            PipelineDriver::new(spec, registry(), io.clone(), DriverConfig::default()).unwrap();
        let report = driver.run(BTreeMap::new()).unwrap();
        assert_eq!(report.pipes[0].output_rows[0], Some(2));
        // file exists and parses
        let schema_out = Schema::new(vec![("x", FieldType::I64)]);
        let rows = io
            .read_rows(
                "s3://bucket/out.csv",
                crate::io::Format::Csv,
                &schema_out,
                crate::security::EncryptionMode::None,
                "Out",
            )
            .unwrap();
        let mut vals: Vec<i64> = rows.iter().map(|r| r.get(0).as_i64().unwrap()).collect();
        vals.sort_unstable();
        assert_eq!(vals, vec![2, 3]);
    }

    #[test]
    fn missing_source_errors() {
        let spec = fast_settings(
            r#"[{"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Out"}]"#,
        );
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .unwrap();
        let err = driver.run(BTreeMap::new()).err().unwrap().to_string();
        assert!(err.contains("not provided"), "{err}");
    }

    #[test]
    fn failing_pipe_attributed() {
        let spec = fast_settings(
            r#"[{"inputDataId": "In", "transformerType": "Failing", "outputDataId": "Out", "name": "boom"}]"#,
        );
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .unwrap();
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), nums_ds(3));
        let err = driver.run(provided).err().unwrap().to_string();
        assert!(err.contains("boom") && err.contains("intentional"), "{err}");
        // failed pipe renders red
        assert!(driver.dot().contains("#f28b82"));
    }

    #[test]
    fn unknown_transformer_fails_fast() {
        let spec = fast_settings(
            r#"[{"inputDataId": "In", "transformerType": "Mystery", "outputDataId": "Out"}]"#,
        );
        let err = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .err()
        .map(|e| e.to_string())
        .unwrap();
        assert!(err.contains("Mystery"), "{err}");
    }

    #[test]
    fn shared_anchor_auto_cached() {
        // Mid feeds two consumers -> driver should persist it
        let spec = fast_settings(
            r#"[
              {"inputDataId": "In", "transformerType": "AddOne", "outputDataId": "Mid", "name": "a"},
              {"inputDataId": "Mid", "transformerType": "AddOne", "outputDataId": "O1", "name": "b"},
              {"inputDataId": "Mid", "transformerType": "AddOne", "outputDataId": "O2", "name": "c"}
            ]"#,
        );
        let driver = PipelineDriver::new(
            spec,
            registry(),
            Arc::new(IoRegistry::with_sim_cloud()),
            DriverConfig::default(),
        )
        .unwrap();
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), nums_ds(10));
        driver.run(provided).unwrap();
        let s = driver.ctx.engine.stats.snapshot();
        assert!(s.cache_hits >= 1, "Mid should be cache-hit by the second consumer");
    }
}
