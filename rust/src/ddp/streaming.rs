//! Continuous (micro-batch) execution of declarative pipelines.
//!
//! [`StreamingDriver`] is the streaming twin of
//! [`super::driver::PipelineDriver`]: the same [`PipelineSpec`], the same
//! registry, the same Pipes — but one source anchor is a live
//! [`StreamSource`] instead of a bounded dataset, and the run is a loop:
//!
//! ```text
//! source → bounded queue → micro-batch → Plan DAG (per batch) → state
//!             ▲                                     │
//!             └── backpressure (AIMD batch size) ◄──┘  latency feedback
//! ```
//!
//! At construction the driver executes every pipe **once** over a
//! placeholder source to build the template plan (pipes are lazy plan
//! constructors — they transform `Dataset` handles, not rows), then
//! compiles one [`StreamingCtx`] per sink. Each loop iteration polls the
//! source for at most the bounded queue's free space (structural
//! backpressure), takes an adaptively sized batch, and drives every sink
//! query. Draining yields outputs byte-identical to a
//! `PipelineDriver::run` over the full corpus — the contract
//! `tests/streaming.rs` proves differentially.
//!
//! Throughput and latency (p50/p99 per batch) are recorded in the run's
//! [`MetricsRegistry`] alongside the engine counters published by
//! [`EngineMetricsExporter`] (cache hits/evictions, fault injections),
//! so a streaming service alarms from one metrics surface.

use super::context::PipeContext;
use super::dag::DataDag;
use super::registry::PipeRegistry;
use crate::config::{DataLocation, PipelineSpec};
use crate::engine::dataset::Dataset;
use crate::engine::executor::{EngineConfig, EngineCtx};
use crate::engine::stream::{BackpressureController, BoundedRowQueue, StreamSource, StreamingCtx};
use crate::io::IoRegistry;
use crate::metrics::{EngineMetricsExporter, MetricsRegistry, MetricsSnapshot};
use crate::util::clock;
use crate::util::error::{DdpError, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Streaming-loop knobs.
#[derive(Debug, Clone)]
pub struct StreamingConfig {
    /// which source anchor the stream feeds
    pub source_id: String,
    /// micro-batch size the AIMD controller starts from
    pub initial_batch_rows: usize,
    /// controller floor (fix all three to the same value for a constant
    /// batch size, e.g. in differential tests)
    pub min_batch_rows: usize,
    /// controller ceiling
    pub max_batch_rows: usize,
    /// per-batch latency target the controller steers under
    pub target_batch_latency_secs: f64,
    /// bounded ingest queue capacity in rows (caps in-flight memory when
    /// the source outpaces the pipeline)
    pub queue_capacity_rows: usize,
    /// retain append-mode emissions so drain can return the full output
    /// (disable for unbounded runs whose sink is external)
    pub retain_output: bool,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            source_id: "InputData".to_string(),
            initial_batch_rows: 256,
            min_batch_rows: 16,
            max_batch_rows: 8192,
            target_batch_latency_secs: 0.05,
            queue_capacity_rows: 16_384,
            retain_output: true,
        }
    }
}

/// Whole-run result of a streaming execution.
pub struct StreamReport {
    pub pipeline: String,
    pub batches: u64,
    pub records_in: u64,
    pub elapsed_secs: f64,
    pub records_per_sec: f64,
    pub p50_batch_latency_secs: f64,
    pub p99_batch_latency_secs: f64,
    /// bounded-queue high-water mark (≤ configured capacity, always)
    pub max_queue_depth_rows: usize,
    /// loop iterations that found the ingest queue full
    pub backpressure_waits: u64,
    /// drained output per sink anchor — byte-identical to the one-shot
    /// batch run over the replayed corpus
    pub outputs: BTreeMap<String, crate::engine::Partitioned>,
    pub metrics: MetricsSnapshot,
}

/// The streaming pipeline driver.
pub struct StreamingDriver {
    pub spec: Arc<PipelineSpec>,
    pub ctx: Arc<PipeContext>,
    cfg: StreamingConfig,
    queries: BTreeMap<String, StreamingCtx>,
    exporter: EngineMetricsExporter,
}

impl StreamingDriver {
    /// Build the driver: resolve static sources, run every pipe once to
    /// construct the template plan, compile one streaming query per sink.
    ///
    /// `provided` supplies in-memory *static* source anchors; it may also
    /// carry an (empty) template dataset under the streaming source id to
    /// define its schema when the spec leaves it undeclared.
    pub fn new(
        spec: PipelineSpec,
        registry: PipeRegistry,
        io: Arc<IoRegistry>,
        engine_cfg: EngineConfig,
        cfg: StreamingConfig,
        provided: BTreeMap<String, Dataset>,
    ) -> Result<StreamingDriver> {
        let dag = DataDag::build(&spec)?;
        for pipe in &spec.pipes {
            if !registry.contains(&pipe.transformer_type) {
                return Err(DdpError::config(format!(
                    "pipe '{}' needs unregistered transformerType '{}'",
                    pipe.name, pipe.transformer_type
                )));
            }
        }
        if !dag.sources.contains(&cfg.source_id) {
            return Err(DdpError::config(format!(
                "streaming source '{}' is not a source anchor (sources: {})",
                cfg.source_id,
                dag.sources.join(", ")
            )));
        }
        let engine = EngineCtx::new(engine_cfg);
        let ctx = Arc::new(PipeContext::new(
            engine.clone(),
            MetricsRegistry::new(),
            io,
            clock::wall(),
        ));

        // resolve source anchors; the streaming source becomes an empty
        // placeholder whose node the per-batch splice targets
        let mut anchors: BTreeMap<String, Dataset> = BTreeMap::new();
        for src in &dag.sources {
            if *src == cfg.source_id {
                let decl = &spec.data[src];
                let schema = if let Some(t) = provided.get(src) {
                    t.schema.clone()
                } else if decl.schema_declared {
                    decl.schema.clone()
                } else {
                    return Err(DdpError::config(format!(
                        "streaming source '{src}' needs a declared schema \
                         (or a template dataset in `provided`)"
                    )));
                };
                anchors.insert(src.clone(), Dataset::from_rows(src, schema, Vec::new(), 1));
                continue;
            }
            if let Some(ds) = provided.get(src) {
                anchors.insert(src.clone(), ds.clone());
                continue;
            }
            let decl = &spec.data[src];
            match &decl.location {
                DataLocation::Stored(loc) => {
                    let rows = ctx.io.read_rows(
                        loc,
                        decl.format,
                        &decl.schema,
                        decl.encryption,
                        &decl.id,
                    )?;
                    anchors.insert(
                        src.clone(),
                        Dataset::from_rows(src, decl.schema.clone(), rows, decl.partitions),
                    );
                }
                DataLocation::Memory => {
                    return Err(DdpError::validation(format!(
                        "static source '{src}' is memory-located but was not provided"
                    )));
                }
            }
        }

        // run every pipe once: plan construction over the template anchors
        for &i in &dag.order {
            let decl = &spec.pipes[i];
            let pipe = registry.create(&decl.transformer_type, &decl.params)?;
            if let Some(arity) = pipe.contract().arity {
                if arity != decl.input_data_ids.len() {
                    return Err(DdpError::validation(format!(
                        "pipe '{}' expects {arity} inputs, config wires {}",
                        decl.name,
                        decl.input_data_ids.len()
                    )));
                }
            }
            let inputs: Vec<Dataset> = decl
                .input_data_ids
                .iter()
                .map(|id| {
                    anchors.get(id).cloned().ok_or_else(|| {
                        DdpError::dag(format!("anchor '{id}' missing for pipe '{}'", decl.name))
                    })
                })
                .collect::<Result<_>>()?;
            let outputs = pipe
                .transform(&ctx, &inputs)
                .map_err(|e| DdpError::pipe(decl.name.clone(), e.to_string()))?;
            if outputs.len() != decl.output_data_ids.len() {
                return Err(DdpError::pipe(
                    decl.name.clone(),
                    format!(
                        "produced {} outputs, config declares {}",
                        outputs.len(),
                        decl.output_data_ids.len()
                    ),
                ));
            }
            for (out_id, ds) in decl.output_data_ids.iter().zip(outputs) {
                anchors.insert(out_id.clone(), ds);
            }
        }

        let placeholder = anchors[&cfg.source_id].clone();
        let mut queries = BTreeMap::new();
        for sink in &dag.sinks {
            let mut q = StreamingCtx::new(engine.clone(), &anchors[sink], &placeholder)?;
            q.set_retain_output(cfg.retain_output);
            queries.insert(sink.clone(), q);
        }
        Ok(StreamingDriver {
            spec: Arc::new(spec),
            ctx,
            cfg,
            queries,
            exporter: EngineMetricsExporter::new(),
        })
    }

    /// Run the continuous loop until the source is exhausted, then drain.
    pub fn run_stream(&mut self, source: &mut dyn StreamSource) -> Result<StreamReport> {
        let t0 = Instant::now();
        let m = self.ctx.metrics.clone();
        let mut queue = BoundedRowQueue::new(self.cfg.queue_capacity_rows);
        let mut controller = BackpressureController::new(
            self.cfg.target_batch_latency_secs,
            self.cfg.min_batch_rows,
            self.cfg.max_batch_rows,
            self.cfg.initial_batch_rows,
        );
        let mut records_in = 0u64;
        let mut batches = 0u64;
        let mut backpressure_waits = 0u64;
        let mut source_done = false;
        loop {
            // structural backpressure: never ask for more than fits
            while !source_done && queue.free() > 0 {
                match source.next_batch(queue.free()) {
                    None => source_done = true,
                    Some(rows) => {
                        if rows.is_empty() {
                            break; // nothing available this poll
                        }
                        records_in += rows.len() as u64;
                        m.counter_add("stream.records_in", rows.len() as u64);
                        queue.push(rows);
                    }
                }
            }
            if !source_done && queue.is_full() {
                backpressure_waits += 1;
                m.counter_add("stream.backpressure_waits", 1);
            }
            let batch = queue.take(controller.batch_rows());
            if batch.is_empty() {
                if source_done {
                    break;
                }
                // live source with nothing available this poll: back off
                // briefly instead of spinning a core on empty re-polls
                std::thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            let bt = Instant::now();
            for q in self.queries.values_mut() {
                let emitted = q.push_batch(&batch)?;
                if !emitted.is_empty() {
                    m.counter_add("stream.records_emitted", emitted.len() as u64);
                }
            }
            let dt = bt.elapsed().as_secs_f64();
            batches += 1;
            m.counter_add("stream.batches", 1);
            m.counter_add("stream.records_processed", batch.len() as u64);
            m.observe("stream.batch_latency_secs", dt);
            m.gauge_set("stream.queue_depth_rows", queue.len() as f64);
            m.gauge_set("stream.batch_rows", controller.batch_rows() as f64);
            let state_rows: usize = self.queries.values().map(|q| q.state_rows()).sum();
            m.gauge_set("stream.state_rows", state_rows as f64);
            controller.observe(dt);
            self.exporter.publish(&m, &self.ctx.engine);
        }

        // drain: batch-identical final outputs per sink
        let mut outputs = BTreeMap::new();
        for (sink, q) in self.queries.iter_mut() {
            let out = q.finish()?;
            m.counter_add(&format!("data.{sink}.rows"), out.num_rows() as u64);
            outputs.insert(sink.clone(), out);
        }
        self.exporter.publish(&m, &self.ctx.engine);

        let elapsed = t0.elapsed().as_secs_f64();
        let rps = if elapsed > 0.0 { records_in as f64 / elapsed } else { 0.0 };
        m.gauge_set("stream.records_per_sec", rps);
        let (p50, p99) = m
            .histogram("stream.batch_latency_secs")
            .map(|h| (h.p50, h.p99))
            .unwrap_or((0.0, 0.0));
        Ok(StreamReport {
            pipeline: self.spec.name.clone(),
            batches,
            records_in,
            elapsed_secs: elapsed,
            records_per_sec: rps,
            p50_batch_latency_secs: p50,
            p99_batch_latency_secs: p99,
            max_queue_depth_rows: queue.max_depth(),
            backpressure_waits,
            outputs,
            metrics: m.snapshot(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineSpec;
    use crate::ddp::registry;
    use crate::engine::stream::CorpusSource;
    use crate::engine::row::{FieldType, Row, Schema};
    use crate::row;

    const SPEC: &str = r#"{
      "name": "stream_test",
      "settings": {"metricsCadenceSecs": 0.05, "workers": 2},
      "data": [
        {"id": "In", "schema": [
          {"name": "id", "type": "i64"},
          {"name": "text", "type": "str"}]}
      ],
      "pipes": [
        {"inputDataId": "In", "transformerType": "SqlFilterTransformer",
         "outputDataId": "Out", "params": {"filter": "id >= 10"}}
      ]
    }"#;

    fn rows(n: i64) -> Vec<Row> {
        (0..n).map(|i| row!(i, format!("doc {i}"))).collect()
    }

    fn schema() -> crate::engine::row::SchemaRef {
        Schema::new(vec![("id", FieldType::I64), ("text", FieldType::Str)])
    }

    fn driver(cfg: StreamingConfig) -> StreamingDriver {
        let spec = PipelineSpec::parse(SPEC).unwrap();
        StreamingDriver::new(
            spec,
            registry::GLOBAL.clone(),
            Arc::new(IoRegistry::with_sim_cloud()),
            EngineConfig { workers: 2, ..Default::default() },
            cfg,
            BTreeMap::new(),
        )
        .unwrap()
    }

    #[test]
    fn stateless_pipeline_streams_and_drains() {
        let cfg = StreamingConfig {
            source_id: "In".into(),
            initial_batch_rows: 7,
            min_batch_rows: 7,
            max_batch_rows: 7,
            ..Default::default()
        };
        let mut d = driver(cfg);
        let mut src = CorpusSource::new(schema(), rows(50));
        let report = d.run_stream(&mut src).unwrap();
        assert_eq!(report.records_in, 50);
        assert!(report.batches >= 7);
        let out = &report.outputs["Out"];
        assert_eq!(out.num_rows(), 40, "ids 10..50 survive the filter");
        // order preserved end to end
        let ids: Vec<i64> = out.rows().iter().map(|r| r.get(0).as_i64().unwrap()).collect();
        assert_eq!(ids, (10..50).collect::<Vec<_>>());
        // metrics wired: throughput + latency + engine counters
        assert!(report.records_per_sec > 0.0);
        assert!(report.metrics.histograms.contains_key("stream.batch_latency_secs"));
        assert!(report.metrics.counters.contains_key("engine.tasks_launched"));
    }

    #[test]
    fn unknown_streaming_source_rejected() {
        let spec = PipelineSpec::parse(SPEC).unwrap();
        let cfg = StreamingConfig { source_id: "Nope".into(), ..Default::default() };
        let err = StreamingDriver::new(
            spec,
            registry::GLOBAL.clone(),
            Arc::new(IoRegistry::with_sim_cloud()),
            EngineConfig { workers: 2, ..Default::default() },
            cfg,
            BTreeMap::new(),
        )
        .err()
        .map(|e| e.to_string())
        .unwrap();
        assert!(err.contains("Nope"), "{err}");
    }

    #[test]
    fn undeclared_schema_needs_template() {
        let bare = r#"[{"inputDataId": "In", "transformerType": "IdentityTransformer",
                        "outputDataId": "Out"}]"#;
        let spec = PipelineSpec::parse(bare).unwrap();
        let cfg = StreamingConfig { source_id: "In".into(), ..Default::default() };
        let err = StreamingDriver::new(
            spec.clone(),
            registry::GLOBAL.clone(),
            Arc::new(IoRegistry::with_sim_cloud()),
            EngineConfig { workers: 2, ..Default::default() },
            cfg.clone(),
            BTreeMap::new(),
        )
        .err()
        .map(|e| e.to_string())
        .unwrap();
        assert!(err.contains("schema"), "{err}");
        // a template dataset under the source id fixes it
        let mut provided = BTreeMap::new();
        provided.insert("In".to_string(), Dataset::from_rows("In", schema(), vec![], 1));
        let mut d = StreamingDriver::new(
            spec,
            registry::GLOBAL.clone(),
            Arc::new(IoRegistry::with_sim_cloud()),
            EngineConfig { workers: 2, ..Default::default() },
            cfg,
            provided,
        )
        .unwrap();
        let mut src = CorpusSource::new(schema(), rows(5));
        let report = d.run_stream(&mut src).unwrap();
        assert_eq!(report.outputs["Out"].num_rows(), 5);
    }
}
