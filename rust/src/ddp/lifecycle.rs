//! Object lifecycle scopes (paper §3.7).
//!
//! Distributed lazy evaluation makes naïve object construction expensive:
//! a model loaded per *record* initializes millions of times; per
//! *partition*, once per task; per *instance* (singleton), once per
//! process. The paper's framework prioritizes instance-level scope for
//! expensive objects (ML models, clients). [`ObjectPool`] implements the
//! instance level: a typed, named singleton registry with per-key
//! initialization counters so tests (and the ablation bench) can observe
//! exactly how many constructions each scope costs.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// The three lifecycle scopes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// constructed for every record (anti-pattern for heavy objects)
    Record,
    /// constructed once per partition task
    Partition,
    /// constructed once per process and shared (the optimization §3.7
    /// recommends)
    Instance,
}

impl Scope {
    pub fn parse(s: &str) -> Option<Scope> {
        match s {
            "record" => Some(Scope::Record),
            "partition" => Some(Scope::Partition),
            "instance" => Some(Scope::Instance),
            _ => None,
        }
    }
}

/// Instance-scope singleton pool: `get_or_init` returns the shared object,
/// constructing it at most once per key.
pub struct ObjectPool {
    objects: Mutex<HashMap<String, Arc<dyn Any + Send + Sync>>>,
    init_counts: Mutex<HashMap<String, Arc<AtomicU64>>>,
}

impl ObjectPool {
    pub fn new() -> ObjectPool {
        ObjectPool {
            objects: Mutex::new(HashMap::new()),
            init_counts: Mutex::new(HashMap::new()),
        }
    }

    /// Fetch the singleton for `key`, constructing it with `init` if absent.
    /// The constructor runs under the pool lock, so concurrent callers
    /// observe exactly one initialization.
    pub fn get_or_init<T, F>(&self, key: &str, init: F) -> Arc<T>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> T,
    {
        let mut objects = self.objects.lock().unwrap();
        if let Some(existing) = objects.get(key) {
            if let Ok(t) = existing.clone().downcast::<T>() {
                return t;
            }
            panic!("ObjectPool key '{key}' holds a different type");
        }
        self.bump(key);
        let value = Arc::new(init());
        objects.insert(key.to_string(), value.clone());
        value
    }

    /// How many times `key` was initialized (≤1 for instance scope).
    pub fn init_count(&self, key: &str) -> u64 {
        self.init_counts
            .lock()
            .unwrap()
            .get(key)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    fn bump(&self, key: &str) {
        self.init_counts
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Record an initialization that happened outside the pool (record- or
    /// partition-scope constructions, counted for the ablation bench).
    pub fn count_external_init(&self, key: &str) {
        self.bump(key);
    }

    /// Drop all singletons (end of run / explicit cleanup).
    pub fn clear(&self) {
        self.objects.lock().unwrap().clear();
    }

    pub fn len(&self) -> usize {
        self.objects.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for ObjectPool {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singleton_initialized_once() {
        let pool = ObjectPool::new();
        for _ in 0..10 {
            let v: Arc<Vec<u32>> = pool.get_or_init("model", || vec![1, 2, 3]);
            assert_eq!(*v, vec![1, 2, 3]);
        }
        assert_eq!(pool.init_count("model"), 1);
    }

    #[test]
    fn distinct_keys_distinct_objects() {
        let pool = ObjectPool::new();
        let a: Arc<String> = pool.get_or_init("a", || "A".to_string());
        let b: Arc<String> = pool.get_or_init("b", || "B".to_string());
        assert_ne!(*a, *b);
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn concurrent_get_or_init_single_construction() {
        let pool = Arc::new(ObjectPool::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                let _: Arc<u64> = pool.get_or_init("heavy", || {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    42u64
                });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.init_count("heavy"), 1);
    }

    #[test]
    fn external_init_counting() {
        let pool = ObjectPool::new();
        for _ in 0..5 {
            pool.count_external_init("per-record-model");
        }
        assert_eq!(pool.init_count("per-record-model"), 5);
    }

    #[test]
    fn clear_resets_objects_not_counts() {
        let pool = ObjectPool::new();
        let _: Arc<u8> = pool.get_or_init("x", || 1u8);
        pool.clear();
        assert!(pool.is_empty());
        assert_eq!(pool.init_count("x"), 1);
        let _: Arc<u8> = pool.get_or_init("x", || 2u8);
        assert_eq!(pool.init_count("x"), 2);
    }

    #[test]
    fn scope_parse() {
        assert_eq!(Scope::parse("instance"), Some(Scope::Instance));
        assert_eq!(Scope::parse("bogus"), None);
    }
}
