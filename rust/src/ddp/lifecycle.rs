//! Object lifecycle scopes (paper §3.7) and anchor lifecycle accounting
//! (§3.2).
//!
//! Distributed lazy evaluation makes naïve object construction expensive:
//! a model loaded per *record* initializes millions of times; per
//! *partition*, once per task; per *instance* (singleton), once per
//! process. The paper's framework prioritizes instance-level scope for
//! expensive objects (ML models, clients). [`ObjectPool`] implements the
//! instance level: a typed, named singleton registry with per-key
//! initialization counters so tests (and the ablation bench) can observe
//! exactly how many constructions each scope costs.
//!
//! [`AnchorRefCounts`] is the data-side counterpart: per-anchor consumer
//! reference counts that let the stage-parallel driver release a cached
//! shared anchor exactly when its last consumer finishes — the explicit
//! "delete clause" of §3.2, made safe under concurrent consumers.

use std::any::Any;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// The three lifecycle scopes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// constructed for every record (anti-pattern for heavy objects)
    Record,
    /// constructed once per partition task
    Partition,
    /// constructed once per process and shared (the optimization §3.7
    /// recommends)
    Instance,
}

impl Scope {
    pub fn parse(s: &str) -> Option<Scope> {
        match s {
            "record" => Some(Scope::Record),
            "partition" => Some(Scope::Partition),
            "instance" => Some(Scope::Instance),
            _ => None,
        }
    }
}

/// Instance-scope singleton pool: `get_or_init` returns the shared object,
/// constructing it at most once per key.
pub struct ObjectPool {
    objects: Mutex<HashMap<String, Arc<dyn Any + Send + Sync>>>,
    init_counts: Mutex<HashMap<String, Arc<AtomicU64>>>,
}

impl ObjectPool {
    pub fn new() -> ObjectPool {
        ObjectPool {
            objects: Mutex::new(HashMap::new()),
            init_counts: Mutex::new(HashMap::new()),
        }
    }

    /// Fetch the singleton for `key`, constructing it with `init` if absent.
    /// The constructor runs under the pool lock, so concurrent callers
    /// observe exactly one initialization.
    pub fn get_or_init<T, F>(&self, key: &str, init: F) -> Arc<T>
    where
        T: Send + Sync + 'static,
        F: FnOnce() -> T,
    {
        let mut objects = self.objects.lock().unwrap();
        if let Some(existing) = objects.get(key) {
            if let Ok(t) = existing.clone().downcast::<T>() {
                return t;
            }
            panic!("ObjectPool key '{key}' holds a different type");
        }
        self.bump(key);
        let value = Arc::new(init());
        objects.insert(key.to_string(), value.clone());
        value
    }

    /// How many times `key` was initialized (≤1 for instance scope).
    pub fn init_count(&self, key: &str) -> u64 {
        self.init_counts
            .lock()
            .unwrap()
            .get(key)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    fn bump(&self, key: &str) {
        self.init_counts
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Record an initialization that happened outside the pool (record- or
    /// partition-scope constructions, counted for the ablation bench).
    pub fn count_external_init(&self, key: &str) {
        self.bump(key);
    }

    /// Drop all singletons (end of run / explicit cleanup).
    pub fn clear(&self) {
        self.objects.lock().unwrap().clear();
    }

    pub fn len(&self) -> usize {
        self.objects.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for ObjectPool {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Debug, Default)]
struct AnchorEntry {
    /// consumer pipes that have not finished yet
    remaining: usize,
    /// engine dataset id of the driver-persisted materialization, if any
    persisted_ds: Option<u64>,
}

/// Per-anchor consumer reference counts for the stage-parallel driver.
///
/// The driver seeds one count per declared consumer wire, registers the
/// engine dataset id when it persists a shared anchor, and calls
/// [`AnchorRefCounts::release`] as each consumer pipe finishes. When the
/// count of a persisted anchor reaches zero, `release` hands back the
/// dataset id so the caller can unpersist it from the engine cache —
/// thread-safe, so concurrent consumers cannot double-free or free early.
#[derive(Debug, Default)]
pub struct AnchorRefCounts {
    entries: Mutex<HashMap<String, AnchorEntry>>,
}

impl AnchorRefCounts {
    /// Seed counts from the DAG's anchor→consumers map.
    pub fn from_consumers(consumers: &BTreeMap<String, Vec<usize>>) -> AnchorRefCounts {
        let entries = consumers
            .iter()
            .map(|(id, pipes)| {
                (id.clone(), AnchorEntry { remaining: pipes.len(), persisted_ds: None })
            })
            .collect();
        AnchorRefCounts { entries: Mutex::new(entries) }
    }

    /// Record that the driver persisted `anchor` as engine dataset
    /// `ds_id`, making it eligible for release-on-last-consumer. If every
    /// consumer already finished, the id is handed straight back.
    pub fn register_persisted(&self, anchor: &str, ds_id: u64) -> Option<u64> {
        let mut entries = self.entries.lock().unwrap();
        let entry = entries.entry(anchor.to_string()).or_default();
        if entry.remaining == 0 {
            return Some(ds_id);
        }
        entry.persisted_ds = Some(ds_id);
        None
    }

    /// One consumer of `anchor` finished. Returns the persisted dataset id
    /// exactly once, when the final consumer releases.
    pub fn release(&self, anchor: &str) -> Option<u64> {
        let mut entries = self.entries.lock().unwrap();
        let entry = entries.get_mut(anchor)?;
        entry.remaining = entry.remaining.saturating_sub(1);
        if entry.remaining == 0 {
            entry.persisted_ds.take()
        } else {
            None
        }
    }

    /// Remaining consumer count (0 for unknown anchors).
    pub fn remaining(&self, anchor: &str) -> usize {
        self.entries
            .lock()
            .unwrap()
            .get(anchor)
            .map(|e| e.remaining)
            .unwrap_or(0)
    }

    /// Drain every still-persisted dataset id (failure-path cleanup).
    pub fn drain_persisted(&self) -> Vec<u64> {
        self.entries
            .lock()
            .unwrap()
            .values_mut()
            .filter_map(|e| e.persisted_ds.take())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singleton_initialized_once() {
        let pool = ObjectPool::new();
        for _ in 0..10 {
            let v: Arc<Vec<u32>> = pool.get_or_init("model", || vec![1, 2, 3]);
            assert_eq!(*v, vec![1, 2, 3]);
        }
        assert_eq!(pool.init_count("model"), 1);
    }

    #[test]
    fn distinct_keys_distinct_objects() {
        let pool = ObjectPool::new();
        let a: Arc<String> = pool.get_or_init("a", || "A".to_string());
        let b: Arc<String> = pool.get_or_init("b", || "B".to_string());
        assert_ne!(*a, *b);
        assert_eq!(pool.len(), 2);
    }

    #[test]
    fn concurrent_get_or_init_single_construction() {
        let pool = Arc::new(ObjectPool::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                let _: Arc<u64> = pool.get_or_init("heavy", || {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    42u64
                });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.init_count("heavy"), 1);
    }

    #[test]
    fn external_init_counting() {
        let pool = ObjectPool::new();
        for _ in 0..5 {
            pool.count_external_init("per-record-model");
        }
        assert_eq!(pool.init_count("per-record-model"), 5);
    }

    #[test]
    fn clear_resets_objects_not_counts() {
        let pool = ObjectPool::new();
        let _: Arc<u8> = pool.get_or_init("x", || 1u8);
        pool.clear();
        assert!(pool.is_empty());
        assert_eq!(pool.init_count("x"), 1);
        let _: Arc<u8> = pool.get_or_init("x", || 2u8);
        assert_eq!(pool.init_count("x"), 2);
    }

    #[test]
    fn scope_parse() {
        assert_eq!(Scope::parse("instance"), Some(Scope::Instance));
        assert_eq!(Scope::parse("bogus"), None);
    }

    fn two_consumer_counts() -> AnchorRefCounts {
        let mut consumers = BTreeMap::new();
        consumers.insert("Mid".to_string(), vec![1usize, 2]);
        consumers.insert("In".to_string(), vec![0usize]);
        AnchorRefCounts::from_consumers(&consumers)
    }

    #[test]
    fn release_fires_once_on_last_consumer() {
        let rc = two_consumer_counts();
        assert!(rc.register_persisted("Mid", 77).is_none());
        assert_eq!(rc.remaining("Mid"), 2);
        assert_eq!(rc.release("Mid"), None, "first consumer must not free");
        assert_eq!(rc.release("Mid"), Some(77), "last consumer frees");
        assert_eq!(rc.release("Mid"), None, "no double free");
    }

    #[test]
    fn unpersisted_anchor_never_returns_id() {
        let rc = two_consumer_counts();
        assert_eq!(rc.release("In"), None);
        assert_eq!(rc.release("Unknown"), None);
    }

    #[test]
    fn late_persist_after_all_released_returns_immediately() {
        let rc = two_consumer_counts();
        rc.release("Mid");
        rc.release("Mid");
        // persisting after the consumers already finished hands the id back
        assert_eq!(rc.register_persisted("Mid", 5), Some(5));
    }

    #[test]
    fn concurrent_release_frees_exactly_once() {
        let mut consumers = BTreeMap::new();
        consumers.insert("A".to_string(), (0..16usize).collect::<Vec<_>>());
        let rc = Arc::new(AnchorRefCounts::from_consumers(&consumers));
        rc.register_persisted("A", 9);
        let freed = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let rc = rc.clone();
                let freed = freed.clone();
                std::thread::spawn(move || {
                    if rc.release("A").is_some() {
                        freed.fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(freed.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn drain_collects_leftovers() {
        let rc = two_consumer_counts();
        rc.register_persisted("Mid", 3);
        let mut ids = rc.drain_persisted();
        ids.sort_unstable();
        assert_eq!(ids, vec![3]);
        assert!(rc.drain_persisted().is_empty());
    }
}
