//! Pipeline visualization (paper §3.6, Fig. 3): renders the analyzed data
//! DAG as GraphViz DOT with the paper's palette —
//!
//! * pipes carry their execution order as a `[k]` prefix;
//! * data nodes are colored by location: orange = S3, yellow = memory,
//!   dotted orange outline = cached in memory, blue = table store (kv);
//! * progress: green = completed, yellow = in progress, white = pending;
//! * purple info blocks attach per-pipe metrics (e.g. `model_latency`).

use super::dag::DataDag;
use super::driver::PipeState;
use crate::config::{DataLocation, PipelineSpec};
use crate::metrics::MetricsSnapshot;
use std::collections::HashMap;

/// Render options.
#[derive(Default)]
pub struct VizOptions {
    /// pipe states (defaults to all pending)
    pub states: HashMap<usize, PipeState>,
    /// metrics snapshot for info blocks
    pub metrics: Option<MetricsSnapshot>,
}

/// Render the pipeline to DOT.
pub fn to_dot(spec: &PipelineSpec, dag: &DataDag, opts: &VizOptions) -> String {
    let mut out = String::new();
    out.push_str("digraph pipeline {\n");
    out.push_str("  rankdir=TB;\n  node [fontname=\"Helvetica\"];\n");
    // live progress summary: the stage-parallel driver updates pipe
    // states concurrently, so mid-run renders show several Running pipes
    let (mut done, mut running, mut failed) = (0usize, 0usize, 0usize);
    for i in 0..spec.pipes.len() {
        match opts.states.get(&i).copied().unwrap_or(PipeState::Pending) {
            PipeState::Done => done += 1,
            PipeState::Running => running += 1,
            PipeState::Failed => failed += 1,
            PipeState::Pending => {}
        }
    }
    let progress = format!(
        "{done}/{} done, {running} running, {failed} failed",
        spec.pipes.len()
    );
    out.push_str(&format!(
        "  label=\"{}\\n{}\";\n  labelloc=t;\n",
        esc(&spec.name),
        esc(&progress)
    ));

    // data nodes
    for (id, decl) in &spec.data {
        let (fill, style, outline) = match &decl.location {
            DataLocation::Stored(loc) if loc.starts_with("s3://") => {
                ("#f59e42", "filled", "#b36b1f") // orange: S3
            }
            DataLocation::Stored(loc) if loc.starts_with("kv://") => {
                ("#7ab8f5", "filled", "#2c6fb3") // blue: table store
            }
            DataLocation::Stored(_) => ("#d9d9d9", "filled", "#888888"), // generic storage
            DataLocation::Memory if decl.cache => ("#fff2b3", "filled,dashed", "#f59e42"), // dotted orange: cached
            DataLocation::Memory => ("#fff2b3", "filled", "#c9b458"), // yellow: memory
        };
        out.push_str(&format!(
            "  \"data_{}\" [label=\"{}\\n({})\" shape=cylinder style=\"{}\" fillcolor=\"{}\" color=\"{}\"];\n",
            esc(id),
            esc(id),
            esc(decl.location.as_str()),
            style,
            fill,
            outline
        ));
    }

    // pipe nodes with execution-order prefix + progress color
    let exec_rank: HashMap<usize, usize> = dag
        .order
        .iter()
        .enumerate()
        .map(|(rank, &pipe)| (pipe, rank))
        .collect();
    for (i, pipe) in spec.pipes.iter().enumerate() {
        let state = opts.states.get(&i).copied().unwrap_or(PipeState::Pending);
        let fill = match state {
            PipeState::Done => "#9fdf9f",    // green
            PipeState::Running => "#ffe066", // yellow
            PipeState::Pending => "#ffffff", // white
            PipeState::Failed => "#f28b82",  // red (extension beyond Fig 3)
        };
        out.push_str(&format!(
            "  \"pipe_{}\" [label=\"[{}] {}\" shape=box style=\"filled,rounded\" fillcolor=\"{}\"];\n",
            esc(&pipe.name),
            exec_rank.get(&i).copied().unwrap_or(usize::MAX),
            esc(&pipe.name),
            fill
        ));

        // purple info block with this pipe's metrics (prefix match
        // `pipe.<name>.`), as in Fig 3's `model_latency` tag
        if let Some(snapshot) = &opts.metrics {
            let prefix = format!("pipe.{}.", pipe.name);
            let mut lines: Vec<String> = Vec::new();
            for (k, v) in &snapshot.counters {
                if let Some(short) = k.strip_prefix(&prefix) {
                    lines.push(format!("{short}={v}"));
                }
            }
            for (k, v) in &snapshot.gauges {
                if let Some(short) = k.strip_prefix(&prefix) {
                    lines.push(format!("{short}={v:.3}"));
                }
            }
            for (k, h) in &snapshot.histograms {
                if let Some(short) = k.strip_prefix(&prefix) {
                    lines.push(format!("{short}: p50={:.1}ms p95={:.1}ms", h.p50 * 1e3, h.p95 * 1e3));
                }
            }
            if !lines.is_empty() {
                out.push_str(&format!(
                    "  \"info_{}\" [label=\"info\\n{}\" shape=note style=filled fillcolor=\"#c59df5\" fontsize=9];\n",
                    esc(&pipe.name),
                    esc(&lines.join("\\n"))
                ));
                out.push_str(&format!(
                    "  \"info_{}\" -> \"pipe_{}\" [style=dotted arrowhead=none color=\"#8458c9\"];\n",
                    esc(&pipe.name),
                    esc(&pipe.name)
                ));
            }
        }
    }

    // edges: data -> pipe (inputs), pipe -> data (outputs)
    for pipe in &spec.pipes {
        for inp in &pipe.input_data_ids {
            out.push_str(&format!(
                "  \"data_{}\" -> \"pipe_{}\";\n",
                esc(inp),
                esc(&pipe.name)
            ));
        }
        for outp in &pipe.output_data_ids {
            out.push_str(&format!(
                "  \"pipe_{}\" -> \"data_{}\";\n",
                esc(&pipe.name),
                esc(outp)
            ));
        }
    }
    out.push_str("}\n");
    out
}

fn esc(s: &str) -> String {
    s.replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PipelineSpec, PAPER_EXAMPLE};
    use crate::ddp::dag::DataDag;

    fn render(states: HashMap<usize, PipeState>) -> String {
        let spec = PipelineSpec::parse(PAPER_EXAMPLE).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        to_dot(&spec, &dag, &VizOptions { states, metrics: None })
    }

    #[test]
    fn contains_all_nodes_and_edges() {
        let dot = render(HashMap::new());
        assert!(dot.starts_with("digraph pipeline {"));
        for id in ["InputData", "IntermediateData", "FeatureData", "PredictionData", "OutputData"] {
            assert!(dot.contains(&format!("data_{id}")), "missing data node {id}");
        }
        assert!(dot.contains("[0] PreprocessTransformer"));
        assert!(dot.contains("[3] PostProcessTransformer"));
        assert!(dot.contains("\"data_InputData\" -> \"pipe_PreprocessTransformer\""));
        assert!(dot.contains("\"pipe_ModelPredictionTransformer\" -> \"data_PredictionData\""));
    }

    #[test]
    fn progress_colors() {
        let mut states = HashMap::new();
        states.insert(0, PipeState::Done);
        states.insert(1, PipeState::Running);
        let dot = render(states);
        assert!(dot.contains("#9fdf9f"), "done = green");
        assert!(dot.contains("#ffe066"), "running = yellow");
        assert!(dot.contains("#ffffff"), "pending = white");
    }

    #[test]
    fn progress_summary_in_label() {
        let mut states = HashMap::new();
        states.insert(0, PipeState::Done);
        states.insert(1, PipeState::Running);
        states.insert(2, PipeState::Running);
        let dot = render(states);
        assert!(dot.contains("1/4 done, 2 running, 0 failed"), "{dot}");
    }

    #[test]
    fn metrics_info_blocks() {
        let spec = PipelineSpec::parse(PAPER_EXAMPLE).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        let reg = crate::metrics::MetricsRegistry::new();
        reg.observe("pipe.ModelPredictionTransformer.model_latency", 0.005);
        reg.counter_add("pipe.PreprocessTransformer.rows_out", 100);
        let dot = to_dot(
            &spec,
            &dag,
            &VizOptions { states: HashMap::new(), metrics: Some(reg.snapshot()) },
        );
        assert!(dot.contains("model_latency"));
        assert!(dot.contains("rows_out=100"));
        assert!(dot.contains("#c59df5"), "purple info block");
    }

    #[test]
    fn location_palette() {
        let text = r#"{
          "data": [
            {"id": "A", "location": "s3://b/a"},
            {"id": "B", "location": "kv://t/b"},
            {"id": "C", "cache": true}
          ],
          "pipes": [
            {"inputDataId": ["A", "B"], "transformerType": "X", "outputDataId": "C"}
          ]
        }"#;
        let spec = PipelineSpec::parse(text).unwrap();
        let dag = DataDag::build(&spec).unwrap();
        let dot = to_dot(&spec, &dag, &VizOptions::default());
        assert!(dot.contains("#f59e42"), "s3 orange");
        assert!(dot.contains("#7ab8f5"), "kv blue");
        assert!(dot.contains("filled,dashed"), "cached dotted");
    }
}
