//! The Pipe abstraction — the paper's core contribution (§3.1):
//! `Inputs → Pipe (Transformation Logic) → Outputs`.
//!
//! A pipe is a standalone logic unit with a declared input/output
//! contract. Unlike a microservice it exchanges data through memory
//! ([`crate::engine::Dataset`] handles), not the network; unlike raw Spark
//! code it never touches I/O, encryption, metrics plumbing or execution
//! order — the driver owns all of that.

use super::context::PipeContext;
use crate::engine::dataset::Dataset;
use crate::engine::row::SchemaRef;
use crate::util::error::Result;

/// Contract metadata for validation and the self-service ecosystem
/// (§3.8): what a pipe requires of its inputs and guarantees of its
/// outputs. `None` = schema-agnostic.
#[derive(Debug, Clone, Default)]
pub struct PipeContract {
    /// required input schemas, by position (None = any)
    pub input_schemas: Vec<Option<SchemaRef>>,
    /// produced output schemas, by position (None = same as input 0)
    pub output_schemas: Vec<Option<SchemaRef>>,
    /// expected number of inputs (None = variadic)
    pub arity: Option<usize>,
}

/// A logic unit. Implementations should be pure transformations over the
/// input datasets; all side effects (persist, metrics, temp objects) go
/// through the [`PipeContext`].
pub trait Pipe: Send + Sync {
    /// Stable type name (matches `transformerType` in configs).
    fn type_name(&self) -> &str;

    /// Input/output contract for connection validation.
    fn contract(&self) -> PipeContract {
        PipeContract::default()
    }

    /// The transformation. `inputs` arrive in `inputDataId` order; the
    /// returned datasets map to `outputDataId` order.
    fn transform(&self, ctx: &PipeContext, inputs: &[Dataset]) -> Result<Vec<Dataset>>;

    /// Metric names this pipe emits (documentation + viz info tags).
    fn declared_metrics(&self) -> Vec<String> {
        vec![]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Doubler;

    impl Pipe for Doubler {
        fn type_name(&self) -> &str {
            "Doubler"
        }

        fn transform(&self, _ctx: &PipeContext, inputs: &[Dataset]) -> Result<Vec<Dataset>> {
            let ds = &inputs[0];
            Ok(vec![ds.map(ds.schema.clone(), |r| {
                crate::row!(r.get(0).as_i64().unwrap() * 2)
            })])
        }
    }

    #[test]
    fn pipe_object_safety_and_transform() {
        use crate::engine::row::{FieldType, Schema};
        let pipe: Box<dyn Pipe> = Box::new(Doubler);
        assert_eq!(pipe.type_name(), "Doubler");
        let ctx = PipeContext::for_tests();
        let schema = Schema::new(vec![("x", FieldType::I64)]);
        let ds = Dataset::from_rows(
            "in",
            schema,
            (0..5).map(|i| crate::row!(i as i64)).collect(),
            2,
        );
        let out = pipe.transform(&ctx, &[ds]).unwrap();
        let rows = ctx.engine.collect_rows(&out[0]).unwrap();
        let mut vals: Vec<i64> = rows.iter().map(|r| r.get(0).as_i64().unwrap()).collect();
        vals.sort_unstable();
        assert_eq!(vals, vec![0, 2, 4, 6, 8]);
    }
}
