//! Dynamic pipe discovery (paper §3.4): pipes register a factory under
//! their `transformerType`; pipelines instantiate them from declarative
//! configs at run time, dependency-injection style. A process-global
//! registry holds the built-in pipe library; local registries support
//! isolated tests and plugins.

use super::pipe::Pipe;
use crate::json::Value;
use crate::util::error::{DdpError, Result};
use once_cell::sync::Lazy;
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// Factory: params (from `TransformerDeclare.params`) → pipe instance.
pub type PipeFactory = Arc<dyn Fn(&Value) -> Result<Box<dyn Pipe>> + Send + Sync>;

/// A pipe factory registry.
#[derive(Clone, Default)]
pub struct PipeRegistry {
    factories: Arc<RwLock<BTreeMap<String, PipeFactory>>>,
}

impl PipeRegistry {
    pub fn new() -> PipeRegistry {
        PipeRegistry::default()
    }

    /// Register (or replace) a factory for a transformer type.
    pub fn register<F>(&self, type_name: &str, factory: F)
    where
        F: Fn(&Value) -> Result<Box<dyn Pipe>> + Send + Sync + 'static,
    {
        self.factories
            .write()
            .unwrap()
            .insert(type_name.to_string(), Arc::new(factory));
    }

    /// Instantiate a pipe from its type name and params.
    pub fn create(&self, type_name: &str, params: &Value) -> Result<Box<dyn Pipe>> {
        let factory = self
            .factories
            .read()
            .unwrap()
            .get(type_name)
            .cloned()
            .ok_or_else(|| {
                DdpError::config(format!(
                    "unknown transformerType '{type_name}' (registered: {})",
                    self.type_names().join(", ")
                ))
            })?;
        factory(params)
    }

    pub fn contains(&self, type_name: &str) -> bool {
        self.factories.read().unwrap().contains_key(type_name)
    }

    /// Registered type names, sorted (the §3.8 "pipe repository" listing).
    pub fn type_names(&self) -> Vec<String> {
        self.factories.read().unwrap().keys().cloned().collect()
    }
}

/// Process-global registry preloaded with the standard pipe library
/// (populated by [`crate::pipes::install_standard_pipes`] on first use).
pub static GLOBAL: Lazy<PipeRegistry> = Lazy::new(|| {
    let reg = PipeRegistry::new();
    crate::pipes::install_standard_pipes(&reg);
    reg
});

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ddp::context::PipeContext;
    use crate::engine::dataset::Dataset;

    struct Nop;

    impl Pipe for Nop {
        fn type_name(&self) -> &str {
            "Nop"
        }
        fn transform(&self, _: &PipeContext, inputs: &[Dataset]) -> Result<Vec<Dataset>> {
            Ok(vec![inputs[0].clone()])
        }
    }

    #[test]
    fn register_and_create() {
        let reg = PipeRegistry::new();
        assert!(!reg.contains("Nop"));
        reg.register("Nop", |_| Ok(Box::new(Nop)));
        assert!(reg.contains("Nop"));
        let pipe = reg.create("Nop", &Value::Null).unwrap();
        assert_eq!(pipe.type_name(), "Nop");
    }

    #[test]
    fn unknown_type_lists_known() {
        let reg = PipeRegistry::new();
        reg.register("Alpha", |_| Ok(Box::new(Nop)));
        let err = reg.create("Beta", &Value::Null).err().unwrap().to_string();
        assert!(err.contains("Beta"));
        assert!(err.contains("Alpha"));
    }

    #[test]
    fn factory_sees_params() {
        let reg = PipeRegistry::new();
        reg.register("Check", |params| {
            if params.f64_or("threshold", 0.0) > 0.0 {
                Ok(Box::new(Nop))
            } else {
                Err(DdpError::config("threshold required"))
            }
        });
        assert!(reg.create("Check", &Value::Null).is_err());
        let params = crate::json::parse(r#"{"threshold": 0.5}"#).unwrap();
        assert!(reg.create("Check", &params).is_ok());
    }
}
