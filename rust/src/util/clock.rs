//! Wall-clock vs virtual-clock abstraction.
//!
//! The paper's scalability results (Fig 5, Table 3 "Scalability Limit",
//! §4.4 cluster latencies) were measured on 48-vCPU Glue clusters and
//! 100-node EMR fleets. This container has one physical core, so the
//! simulated-cluster executor (`engine::cluster`) advances a [`VirtualClock`]
//! by *measured* per-task costs instead of sleeping. Everything else shares
//! the same [`Clock`] trait so pipes and metrics are agnostic to which world
//! they run in.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Seconds-since-start time source.
pub trait Clock: Send + Sync {
    /// Current time in seconds since the clock's epoch.
    fn now(&self) -> f64;
    /// Advance the clock (no-op for wall clocks).
    fn advance(&self, _secs: f64) {}
    /// True if this clock is simulated.
    fn is_virtual(&self) -> bool {
        false
    }
}

/// Real wall-clock backed by `Instant`.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Simulated clock advanced explicitly by the cluster simulator.
/// Stores nanoseconds in an atomic so it is cheap and `Sync`.
pub struct VirtualClock {
    nanos: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { nanos: AtomicU64::new(0) }
    }

    /// Set the clock to an absolute time (used by the simulator when it
    /// fast-forwards to the next event).
    pub fn set(&self, secs: f64) {
        self.nanos.store((secs * 1e9) as u64, Ordering::SeqCst);
    }

    /// Monotonic max-set: only moves the clock forward.
    pub fn advance_to(&self, secs: f64) {
        let target = (secs * 1e9) as u64;
        self.nanos.fetch_max(target, Ordering::SeqCst);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 / 1e9
    }

    fn advance(&self, secs: f64) {
        self.nanos.fetch_add((secs * 1e9) as u64, Ordering::SeqCst);
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

/// Shared clock handle.
pub type ClockRef = Arc<dyn Clock>;

/// Convenience constructors.
pub fn wall() -> ClockRef {
    Arc::new(WallClock::new())
}

pub fn virt() -> Arc<VirtualClock> {
    Arc::new(VirtualClock::new())
}

/// A simple stopwatch over any clock.
pub struct Stopwatch {
    clock: ClockRef,
    start: f64,
}

impl Stopwatch {
    pub fn start(clock: ClockRef) -> Self {
        let start = clock.now();
        Stopwatch { clock, start }
    }

    pub fn elapsed(&self) -> f64 {
        self.clock.now() - self.start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_monotonic() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_advance() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(1.0); // must not move backwards
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance_to(3.0);
        assert!((c.now() - 3.0).abs() < 1e-9);
        assert!(c.is_virtual());
    }

    #[test]
    fn stopwatch_over_virtual() {
        let c = virt();
        let sw = Stopwatch::start(c.clone());
        c.advance(2.0);
        assert!((sw.elapsed() - 2.0).abs() < 1e-9);
    }
}
