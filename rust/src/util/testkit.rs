//! Tiny property-testing kit (proptest is not in the offline vendor set).
//!
//! Usage:
//! ```ignore
//! use crate::util::testkit::*;
//! #[test]
//! fn prop_roundtrip() {
//!     property(200, |g| {
//!         let s = g.string(0, 64);
//!         assert_eq!(decode(&encode(&s)), s);
//!     });
//! }
//! ```
//!
//! Each case runs with a deterministic per-case seed; on failure the seed is
//! printed so the case can be replayed with `DDP_PROP_SEED`.

use super::rng::Rng64;

/// Generator handle passed to property bodies.
pub struct Gen {
    rng: Rng64,
    pub case: u64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng64 {
        &mut self.rng
    }

    pub fn u64(&mut self, bound: u64) -> u64 {
        self.rng.gen_range(bound.max(1))
    }

    pub fn usize(&mut self, bound: usize) -> usize {
        self.rng.gen_range(bound.max(1) as u64) as usize
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.rng.gen_range((hi - lo).max(1) as u64) as i64
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.gen_f64_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.gen_bool(0.5)
    }

    /// ASCII-ish string with occasional multibyte chars to stress UTF-8
    /// handling.
    pub fn string(&mut self, min: usize, max: usize) -> String {
        let len = min + self.usize(max - min + 1);
        let mut s = String::with_capacity(len);
        for _ in 0..len {
            let c = match self.u64(20) {
                0 => 'é',
                1 => 'ß',
                2 => '中',
                3 => ' ',
                4 => '"',
                5 => '\\',
                6 => '\n',
                _ => (b'a' + self.u64(26) as u8) as char,
            };
            s.push(c);
        }
        s
    }

    /// Plain lowercase identifier.
    pub fn ident(&mut self, min: usize, max: usize) -> String {
        let len = (min + self.usize(max - min + 1)).max(1);
        (0..len).map(|_| (b'a' + self.u64(26) as u8) as char).collect()
    }

    pub fn vec<T>(&mut self, min: usize, max: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = min + self.usize(max - min + 1);
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `cases` deterministic random cases. Set `DDP_PROP_SEED` to replay a
/// single failing case.
pub fn property(cases: u64, mut body: impl FnMut(&mut Gen)) {
    if let Ok(seed) = std::env::var("DDP_PROP_SEED") {
        let seed: u64 = seed.parse().expect("DDP_PROP_SEED must be u64");
        let mut g = Gen { rng: Rng64::new(seed), case: 0 };
        body(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = 0x5eed_0000_0000_0000u64 ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng64::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            eprintln!("property failed at case {case}; replay with DDP_PROP_SEED={seed}");
            std::panic::resume_unwind(e);
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "element {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut n = 0;
        property(50, |_| n += 1);
        assert_eq!(n, 50);
    }

    #[test]
    fn gen_string_len_bounds() {
        property(100, |g| {
            let s = g.string(2, 10);
            let chars = s.chars().count();
            assert!((2..=12).contains(&chars));
        });
    }

    #[test]
    fn allclose_basic() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-5);
    }

    #[test]
    #[should_panic]
    fn allclose_detects_mismatch() {
        assert_allclose(&[1.0], &[2.0], 1e-5, 1e-5);
    }
}
