//! Unified error type for the DDP stack.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DdpError>;

/// Every failure mode in the stack, from config parsing to PJRT execution.
#[derive(Error, Debug)]
pub enum DdpError {
    #[error("config error: {0}")]
    Config(String),

    #[error("json error at offset {offset}: {msg}")]
    Json { offset: usize, msg: String },

    #[error("dag error: {0}")]
    Dag(String),

    #[error("validation error: {0}")]
    Validation(String),

    #[error("pipe '{pipe}' failed: {msg}")]
    Pipe { pipe: String, msg: String },

    #[error("engine error: {0}")]
    Engine(String),

    #[error("shuffle error: {0}")]
    Shuffle(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("storage error [{backend}]: {msg}")]
    Storage { backend: String, msg: String },

    #[error("format error [{format}]: {msg}")]
    Format { format: String, msg: String },

    #[error("security error: {0}")]
    Security(String),

    #[error("schema mismatch: {0}")]
    Schema(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("model error: {0}")]
    Model(String),

    #[error("metrics error: {0}")]
    Metrics(String),

    #[error("task failed after {attempts} attempts: {msg}")]
    TaskFailed { attempts: u32, msg: String },

    #[error("{0}")]
    Other(String),
}

impl DdpError {
    pub fn config(msg: impl Into<String>) -> Self {
        DdpError::Config(msg.into())
    }
    pub fn dag(msg: impl Into<String>) -> Self {
        DdpError::Dag(msg.into())
    }
    pub fn validation(msg: impl Into<String>) -> Self {
        DdpError::Validation(msg.into())
    }
    pub fn pipe(pipe: impl Into<String>, msg: impl Into<String>) -> Self {
        DdpError::Pipe { pipe: pipe.into(), msg: msg.into() }
    }
    pub fn engine(msg: impl Into<String>) -> Self {
        DdpError::Engine(msg.into())
    }
    pub fn storage(backend: impl Into<String>, msg: impl Into<String>) -> Self {
        DdpError::Storage { backend: backend.into(), msg: msg.into() }
    }
    pub fn format(format: impl Into<String>, msg: impl Into<String>) -> Self {
        DdpError::Format { format: format.into(), msg: msg.into() }
    }
    pub fn security(msg: impl Into<String>) -> Self {
        DdpError::Security(msg.into())
    }
    pub fn schema(msg: impl Into<String>) -> Self {
        DdpError::Schema(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        DdpError::Runtime(msg.into())
    }
    pub fn model(msg: impl Into<String>) -> Self {
        DdpError::Model(msg.into())
    }
    pub fn other(msg: impl Into<String>) -> Self {
        DdpError::Other(msg.into())
    }
}

impl From<xla::Error> for DdpError {
    fn from(e: xla::Error) -> Self {
        DdpError::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = DdpError::pipe("Dedup", "boom");
        assert_eq!(e.to_string(), "pipe 'Dedup' failed: boom");
        let e = DdpError::Json { offset: 12, msg: "bad token".into() };
        assert!(e.to_string().contains("offset 12"));
    }
}
