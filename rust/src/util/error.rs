//! Unified error type for the DDP stack.
//!
//! Hand-rolled `Display`/`Error` impls (the `thiserror` derive is not in
//! the offline vendor set); the rendered messages are part of the public
//! contract and are asserted by tests.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DdpError>;

/// Every failure mode in the stack, from config parsing to PJRT execution.
#[derive(Debug)]
pub enum DdpError {
    Config(String),
    Json { offset: usize, msg: String },
    Dag(String),
    Validation(String),
    Pipe { pipe: String, msg: String },
    Engine(String),
    Shuffle(String),
    Io(std::io::Error),
    Storage { backend: String, msg: String },
    Format { format: String, msg: String },
    Security(String),
    Schema(String),
    Runtime(String),
    Model(String),
    Metrics(String),
    TaskFailed { attempts: u32, msg: String },
    Other(String),
}

impl fmt::Display for DdpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DdpError::Config(m) => write!(f, "config error: {m}"),
            DdpError::Json { offset, msg } => write!(f, "json error at offset {offset}: {msg}"),
            DdpError::Dag(m) => write!(f, "dag error: {m}"),
            DdpError::Validation(m) => write!(f, "validation error: {m}"),
            DdpError::Pipe { pipe, msg } => write!(f, "pipe '{pipe}' failed: {msg}"),
            DdpError::Engine(m) => write!(f, "engine error: {m}"),
            DdpError::Shuffle(m) => write!(f, "shuffle error: {m}"),
            DdpError::Io(e) => write!(f, "io error: {e}"),
            DdpError::Storage { backend, msg } => write!(f, "storage error [{backend}]: {msg}"),
            DdpError::Format { format, msg } => write!(f, "format error [{format}]: {msg}"),
            DdpError::Security(m) => write!(f, "security error: {m}"),
            DdpError::Schema(m) => write!(f, "schema mismatch: {m}"),
            DdpError::Runtime(m) => write!(f, "runtime (PJRT) error: {m}"),
            DdpError::Model(m) => write!(f, "model error: {m}"),
            DdpError::Metrics(m) => write!(f, "metrics error: {m}"),
            DdpError::TaskFailed { attempts, msg } => {
                write!(f, "task failed after {attempts} attempts: {msg}")
            }
            DdpError::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for DdpError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DdpError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DdpError {
    fn from(e: std::io::Error) -> Self {
        DdpError::Io(e)
    }
}

impl DdpError {
    pub fn config(msg: impl Into<String>) -> Self {
        DdpError::Config(msg.into())
    }
    pub fn dag(msg: impl Into<String>) -> Self {
        DdpError::Dag(msg.into())
    }
    pub fn validation(msg: impl Into<String>) -> Self {
        DdpError::Validation(msg.into())
    }
    pub fn pipe(pipe: impl Into<String>, msg: impl Into<String>) -> Self {
        DdpError::Pipe { pipe: pipe.into(), msg: msg.into() }
    }
    pub fn engine(msg: impl Into<String>) -> Self {
        DdpError::Engine(msg.into())
    }
    pub fn storage(backend: impl Into<String>, msg: impl Into<String>) -> Self {
        DdpError::Storage { backend: backend.into(), msg: msg.into() }
    }
    pub fn format(format: impl Into<String>, msg: impl Into<String>) -> Self {
        DdpError::Format { format: format.into(), msg: msg.into() }
    }
    pub fn security(msg: impl Into<String>) -> Self {
        DdpError::Security(msg.into())
    }
    pub fn schema(msg: impl Into<String>) -> Self {
        DdpError::Schema(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        DdpError::Runtime(msg.into())
    }
    pub fn model(msg: impl Into<String>) -> Self {
        DdpError::Model(msg.into())
    }
    pub fn other(msg: impl Into<String>) -> Self {
        DdpError::Other(msg.into())
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for DdpError {
    fn from(e: xla::Error) -> Self {
        DdpError::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = DdpError::pipe("Dedup", "boom");
        assert_eq!(e.to_string(), "pipe 'Dedup' failed: boom");
        let e = DdpError::Json { offset: 12, msg: "bad token".into() };
        assert!(e.to_string().contains("offset 12"));
    }

    #[test]
    fn io_source_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: DdpError = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
