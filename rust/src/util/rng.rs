//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**) used everywhere
//! randomness is needed: corpus generation, fault injection, microservice
//! latency sampling, property tests. `rand`/`proptest` are not in the
//! offline vendor set, so this is the house RNG.

/// xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng64 {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng64 {
    /// Create from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng64 { s }
    }

    /// Derive an independent stream (e.g. per-partition RNG).
    pub fn fork(&mut self, stream: u64) -> Rng64 {
        Rng64::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n). Unbiased via rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire-style multiply-shift with rejection.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; simple, fine for
    /// workload generation).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Sample an index from a discrete cumulative distribution (cdf must be
    /// non-decreasing, last element ~1.0).
    pub fn sample_cdf(&mut self, cdf: &[f64]) -> usize {
        let u = self.gen_f64();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(cdf.len() - 1),
            Err(i) => i.min(cdf.len() - 1),
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(items.len() as u64) as usize]
    }
}

/// Zipf(n, s) sampler over ranks 1..=n via a precomputed CDF and binary
/// search — O(n) setup, O(log n) per sample, exact distribution. Used for
/// doc-length and popularity sampling in the workload generators.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n as usize);
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
            cdf.push(total);
        }
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in [1, n].
    pub fn sample(&self, rng: &mut Rng64) -> u64 {
        let u = rng.gen_f64();
        let idx = match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i + 1,
            Err(i) => i,
        };
        (idx.min(self.cdf.len() - 1) + 1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng64::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn range_roughly_uniform() {
        let mut r = Rng64::new(9);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.gen_range(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng64::new(3);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng64::new(11);
        let z = Zipf::new(100, 1.1);
        let mut ones = 0;
        for _ in 0..5_000 {
            let v = z.sample(&mut r);
            assert!((1..=100).contains(&v));
            if v == 1 {
                ones += 1;
            }
        }
        // rank-1 should dominate under zipf: p(1) ≈ 1/H_{100,1.1} ≈ 0.19
        assert!(ones > 500, "zipf not skewed: {ones}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng64::new(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
