//! Minimal CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::HashMap;

/// Parsed command line: positionals + options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        // NOTE: a bare `--name` greedily consumes a following non-`--` token
        // as its value; boolean flags must therefore come last or use `=`.
        let a = parse(&["run", "extra", "--workers", "8", "--config=c.json", "--verbose"]);
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.opt("workers"), Some("8"));
        assert_eq!(a.opt("config"), Some("c.json"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.opt_usize("workers", 1), 8);
        assert_eq!(a.opt_usize("missing", 3), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--dry-run"]);
        assert!(a.has_flag("dry-run"));
        assert!(a.positional.is_empty());
    }
}
