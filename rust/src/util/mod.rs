//! Utility substrate: PRNG, clocks, thread pool, errors, logging, and a
//! small property-testing kit. These replace crates (tokio, rayon,
//! proptest) that are unavailable in the offline vendor set.

pub mod rng;
pub mod clock;
pub mod threadpool;
pub mod error;
pub mod logger;
pub mod testkit;
pub mod cli;

pub use clock::{Clock, VirtualClock, WallClock};
pub use error::{DdpError, Result};
pub use rng::Rng64;
pub use threadpool::ThreadPool;

/// FNV-1a 64-bit hash — the canonical hash used across the repo for
/// feature hashing and shuffle partitioning. Must stay bit-identical to
/// `python/compile/featurize.py::fnv1a64`.
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Human-readable duration (e.g. "1.23s", "45.6ms").
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.2}h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.2}min", secs / 60.0)
    } else if secs >= 1.0 {
        format!("{:.3}s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{:.1}us", secs * 1e6)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{}B", n)
    } else {
        format!("{:.2}{}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Reference values from the FNV spec (also asserted in python tests
        // for cross-language parity).
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_duration(7200.0), "2.00h");
        assert_eq!(fmt_duration(90.0), "1.50min");
        assert_eq!(fmt_duration(1.5), "1.500s");
        assert_eq!(fmt_duration(0.0015), "1.500ms");
        assert_eq!(fmt_bytes(10), "10B");
        assert_eq!(fmt_bytes(2048), "2.00KiB");
    }
}
