//! Fixed-size thread pool with panic containment — the engine's task
//! execution substrate (tokio/rayon are unavailable offline; a Spark-like
//! stage executor only needs fork/join over blocking tasks anyway).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming from a shared queue.
///
/// The submit side is a `Mutex<Sender>` so the pool is `Sync` and can be
/// driven from many threads at once (the stage-parallel pipe scheduler
/// submits engine stages concurrently); sends are brief, so contention
/// on the lock is negligible.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Mutex<mpsc::Sender<Message>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            workers.push(
                thread::Builder::new()
                    .name(format!("ddp-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Message::Run(job)) => {
                                // Contain panics: a panicking task must not
                                // take the worker down; the scope() caller
                                // observes the failure via its channel.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Message::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { workers, tx: Mutex::new(tx), size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget task.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .lock()
            .unwrap()
            .send(Message::Run(Box::new(f)))
            .expect("pool closed");
    }

    /// Run `tasks` and collect results in input order. Panicking tasks
    /// yield `None` in their slot.
    pub fn map<T, F>(&self, tasks: Vec<F>) -> Vec<Option<T>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = tasks.len();
        let (rtx, rrx) = mpsc::channel::<(usize, Option<T>)>();
        for (i, task) in tasks.into_iter().enumerate() {
            let rtx = rtx.clone();
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(task)).ok();
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            if let Ok((i, v)) = rrx.recv() {
                results[i] = v;
            }
        }
        results
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let tx = self.tx.lock().unwrap();
            for _ in &self.workers {
                let _ = tx.send(Message::Shutdown);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn runs_all_tasks() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU32::new(0));
        let tasks: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    1u32
                }
            })
            .collect();
        let results = pool.map(tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert!(results.iter().all(|r| r == &Some(1)));
    }

    #[test]
    fn preserves_order() {
        let pool = ThreadPool::new(3);
        let tasks: Vec<_> = (0..50).map(|i| move || i * 2).collect();
        let results = pool.map(tasks);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(*r, Some(i * 2));
        }
    }

    #[test]
    fn panic_contained() {
        let pool = ThreadPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
            Box::new(|| 3),
        ];
        let results = pool.map(tasks);
        assert_eq!(results[0], Some(1));
        assert_eq!(results[1], None);
        assert_eq!(results[2], Some(3));
        // pool still alive
        let again = pool.map(vec![|| 7u32]);
        assert_eq!(again[0], Some(7));
    }
}
