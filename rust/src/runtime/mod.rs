//! Model runtime: loads AOT-compiled HLO-text artifacts and executes them
//! natively from the Rust request path (the embedded-model half of the
//! paper's "ML inside the cluster" claim — the architectural analogue of
//! the ONNX-in-JVM technique, with zero Python at run time).
//!
//! Two backends behind one API:
//!
//! * **`pjrt` feature on** ([`pjrt_backend`]) — the real PJRT/XLA path.
//!   Interchange is HLO *text*: `HloModuleProto::from_text_file`
//!   reassigns instruction ids, avoiding the 64-bit-id protos jax ≥ 0.5
//!   emits that xla_extension 0.5.1 rejects. Requires a real `xla` crate
//!   in place of the vendored API stub.
//! * **default (feature off)** ([`disabled`]) — a graceful stub:
//!   [`ModelRuntime::cpu`] returns a runtime error, so model pipes fail
//!   attributably while everything else builds and tests green without
//!   AOT artifacts.

/// Tensor argument for execution (shared by both backends).
pub enum Tensor<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

#[cfg(feature = "pjrt")]
mod pjrt_backend;
#[cfg(feature = "pjrt")]
pub use pjrt_backend::{LoadedModel, ModelRuntime};

#[cfg(not(feature = "pjrt"))]
mod disabled;
#[cfg(not(feature = "pjrt"))]
pub use disabled::{LoadedModel, ModelRuntime};
