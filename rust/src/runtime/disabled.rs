//! No-PJRT backend: same API as the real runtime, every entry point
//! reporting that the `pjrt` feature is disabled. Model pipes surface
//! this as an attributable pipe failure instead of a link error.

use super::Tensor;
use crate::util::error::{DdpError, Result};
use std::path::Path;
use std::sync::Arc;

fn unavailable() -> DdpError {
    DdpError::runtime(
        "model runtime unavailable: built without the `pjrt` feature \
         (rebuild with `--features pjrt` and a real xla crate in rust/vendor/xla)",
    )
}

/// Stub PJRT client + executable cache.
pub struct ModelRuntime {
    _private: (),
}

impl ModelRuntime {
    /// Always fails in this build; see the module docs.
    pub fn cpu() -> Result<ModelRuntime> {
        Err(unavailable())
    }

    pub fn load(&self, _path: impl AsRef<Path>) -> Result<Arc<LoadedModel>> {
        Err(unavailable())
    }

    pub fn loaded_count(&self) -> usize {
        0
    }
}

/// Stub compiled executable (never constructible — `load` always fails).
pub struct LoadedModel {
    pub name: String,
}

impl LoadedModel {
    pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        Err(unavailable())
    }

    pub fn execution_count(&self) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_fails_gracefully() {
        let err = ModelRuntime::cpu().err().unwrap().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
