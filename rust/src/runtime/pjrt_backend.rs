//! PJRT-backed runtime (feature `pjrt`): compiles HLO-text artifacts
//! through the `xla` bindings and executes them on the CPU client.

use super::Tensor;
use crate::util::error::{DdpError, Result};
use once_cell::sync::Lazy;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// The `xla` crate's handles hold non-atomic refcounts (`Rc`) and raw
/// PJRT pointers, so they are neither `Send` nor `Sync`. The engine runs
/// pipe tasks on a thread pool, and instance-scope model sharing (§3.7)
/// requires crossing threads. We make that sound by funnelling EVERY xla
/// call — client construction, compilation, execution, and the temporary
/// literals they create/drop — through one global mutex, so no two
/// threads ever touch an `Rc` refcount or PJRT object concurrently.
/// Inference is thereby serialized process-wide, which matches this
/// container (1 physical core) and is documented in README.md.
static XLA_GUARD: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

struct Unsend<T>(T);
// SAFETY: all access goes through XLA_GUARD (see above).
unsafe impl<T> Send for Unsend<T> {}
unsafe impl<T> Sync for Unsend<T> {}

/// A PJRT client + executable cache. One per process (instance-level
/// lifecycle, §3.7): compiling an HLO module is expensive, so loaded
/// models are cached by path.
pub struct ModelRuntime {
    client: Unsend<xla::PjRtClient>,
    cache: Mutex<std::collections::HashMap<String, Arc<LoadedModel>>>,
}

impl ModelRuntime {
    /// CPU PJRT client.
    pub fn cpu() -> Result<ModelRuntime> {
        let _g = XLA_GUARD.lock().unwrap();
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(ModelRuntime {
            client: Unsend(client),
            cache: Mutex::new(std::collections::HashMap::new()),
        })
    }

    /// Load + compile an HLO text file, caching by path.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<LoadedModel>> {
        let key = path.as_ref().to_string_lossy().to_string();
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Ok(hit.clone());
        }
        let _g = XLA_GUARD.lock().unwrap();
        let proto = xla::HloModuleProto::from_text_file(&key)
            .map_err(|e| DdpError::runtime(format!("parse {key}: {e:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| DdpError::runtime(format!("compile {key}: {e:?}")))?;
        let model = Arc::new(LoadedModel {
            exe: Unsend(exe),
            name: Path::new(&key)
                .file_stem()
                .map(|s| s.to_string_lossy().to_string())
                .unwrap_or_else(|| key.clone()),
            executions: AtomicU64::new(0),
        });
        self.cache.lock().unwrap().insert(key, model.clone());
        Ok(model)
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// A compiled executable.
pub struct LoadedModel {
    exe: Unsend<xla::PjRtLoadedExecutable>,
    pub name: String,
    executions: AtomicU64,
}

impl LoadedModel {
    /// Execute with the given inputs; returns every tuple element as a
    /// flat f32 vector (all our models output f32).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let _g = XLA_GUARD.lock().unwrap();
        let mut literals = Vec::with_capacity(inputs.len());
        for t in inputs {
            let lit = match t {
                Tensor::F32(data, dims) => {
                    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| DdpError::runtime(format!("reshape f32 input: {e:?}")))?
                }
                Tensor::I32(data, dims) => {
                    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| DdpError::runtime(format!("reshape i32 input: {e:?}")))?
                }
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .0
            .execute::<xla::Literal>(&literals)
            .map_err(|e| DdpError::runtime(format!("execute {}: {e:?}", self.name)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| DdpError::runtime(format!("fetch result: {e:?}")))?;
        self.executions.fetch_add(1, Ordering::Relaxed);
        // jax lowering uses return_tuple=True -> output is a tuple
        let elements = out
            .to_tuple()
            .map_err(|e| DdpError::runtime(format!("untuple: {e:?}")))?;
        let mut vecs = Vec::with_capacity(elements.len());
        for el in elements {
            vecs.push(
                el.to_vec::<f32>()
                    .map_err(|e| DdpError::runtime(format!("to_vec f32: {e:?}")))?,
            );
        }
        Ok(vecs)
    }

    /// Number of completed executions (metrics).
    pub fn execution_count(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("langdetect.hlo.txt").exists()
    }

    /// A runtime, or None when only the API stub is linked (cpu() errors).
    fn runtime() -> Option<ModelRuntime> {
        match ModelRuntime::cpu() {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: no PJRT backend ({e})");
                None
            }
        }
    }

    #[test]
    fn langdetect_loads_and_runs() {
        let Some(rt) = runtime() else { return };
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let model = rt.load(artifacts_dir().join("langdetect.hlo.txt")).unwrap();
        let x = vec![0.0f32; 64 * 2048];
        let out = model.run(&[Tensor::F32(&x, &[64, 2048])]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 64 * 16);
        assert_eq!(model.execution_count(), 1);
    }

    #[test]
    fn model_cache_by_path() {
        let Some(rt) = runtime() else { return };
        if !have_artifacts() {
            return;
        }
        let a = rt.load(artifacts_dir().join("langdetect.hlo.txt")).unwrap();
        let b = rt.load(artifacts_dir().join("langdetect.hlo.txt")).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(rt.loaded_count(), 1);
    }

    #[test]
    fn pairwise_identity_diagonal() {
        let Some(rt) = runtime() else { return };
        if !have_artifacts() {
            return;
        }
        let model = rt.load(artifacts_dir().join("pairwise.hlo.txt")).unwrap();
        // two identical batches of unit vectors -> diagonal 1.0
        let mut a = vec![0.0f32; 128 * 64];
        for i in 0..128 {
            a[i * 64 + (i % 64)] = 1.0;
        }
        let out = model
            .run(&[Tensor::F32(&a, &[128, 64]), Tensor::F32(&a, &[128, 64])])
            .unwrap();
        let s = &out[0];
        assert_eq!(s.len(), 128 * 128);
        for i in 0..128 {
            assert!((s[i * 128 + i] - 1.0).abs() < 1e-5, "diag {i} = {}", s[i * 128 + i]);
        }
    }

    #[test]
    fn missing_file_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.load("/nonexistent/model.hlo.txt").is_err());
    }
}
